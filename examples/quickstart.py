"""Quickstart: a SUPG query with statistical guarantees in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's Beta(0.01, 1) synthetic dataset (1M records, ~1%
positives), runs a recall-target and a precision-target query, and prints
the achieved metrics — the guarantee holds with probability >= 95%.
"""
import jax
import numpy as np

from repro.core import (SUPGQuery, array_oracle, precision_of, recall_of,
                        run_query)
from repro.data.synthetic import make_beta


def main():
    ds = make_beta(n=1_000_000, alpha=0.01, beta=1.0, seed=0)
    truth = ds.truth_mask()
    print(f"dataset: 1M records, {truth.sum()} positives "
          f"(TPR {ds.tpr:.3%})")

    for target, gamma in (("recall", 0.9), ("precision", 0.9)):
        query = SUPGQuery(target=target, gamma=gamma, delta=0.05,
                          budget=10_000, method="is")
        res = run_query(jax.random.PRNGKey(0), ds.scores,
                        array_oracle(ds.labels), query)
        p = precision_of(res.selected, truth)
        r = recall_of(res.selected, truth)
        print(f"{target}-target {gamma:.0%}: |R|={len(res.selected)} "
              f"tau={res.tau:.4f} oracle_calls={res.oracle_calls} "
              f"-> precision={p:.3f} recall={r:.3f}")


if __name__ == "__main__":
    main()
