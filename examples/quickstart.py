"""Quickstart: a SUPG query with statistical guarantees in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's Beta(0.01, 1) synthetic dataset (1M records, ~1%
positives), runs a recall-target and a precision-target query, and prints
the achieved metrics — the guarantee holds with probability >= 95%.

Part 2 runs the same query through the sharded SelectionEngine's
*streaming* path: the selection is emitted shard-by-shard in fixed-size
chunks into a sink (here the default in-memory IndexSink), so the query
scales to corpora where a full boolean mask can never be materialized.
"""
import jax
import numpy as np

from repro.core import (SUPGQuery, array_oracle, precision_of, recall_of,
                        run_query)
from repro.core.engine import SelectionEngine
from repro.data.synthetic import make_beta


def main():
    ds = make_beta(n=1_000_000, alpha=0.01, beta=1.0, seed=0)
    truth = ds.truth_mask()
    print(f"dataset: 1M records, {truth.sum()} positives "
          f"(TPR {ds.tpr:.3%})")

    for target, gamma in (("recall", 0.9), ("precision", 0.9)):
        query = SUPGQuery(target=target, gamma=gamma, delta=0.05,
                          budget=10_000, method="is")
        res = run_query(jax.random.PRNGKey(0), ds.scores,
                        array_oracle(ds.labels), query)
        p = precision_of(res.selected, truth)
        r = recall_of(res.selected, truth)
        print(f"{target}-target {gamma:.0%}: |R|={len(res.selected)} "
              f"tau={res.tau:.4f} oracle_calls={res.oracle_calls} "
              f"-> precision={p:.3f} recall={r:.3f}")

    # -- streaming path: sharded engine, chunked emission, lazy view --------
    # The context manager releases the engine's worker pool even if the
    # query raises (same leak-on-error audit as selection_service.py).
    with SelectionEngine(np.array_split(ds.scores, 4),
                         num_bins=4096) as engine:
        query = SUPGQuery(target="recall", gamma=0.9, delta=0.05,
                          budget=10_000, method="is")
        sel = engine.run(jax.random.PRNGKey(0), array_oracle(ds.labels),
                         query)
        # total_selected comes from per-shard counts the sink accumulated
        # while streaming — no full-corpus mask was ever allocated.
        r = recall_of(np.concatenate([engine.offsets[i] + sel.indices(i)
                                      for i in range(sel.num_shards)]),
                      truth)
    print(f"streamed recall-target 90%: |R|={sel.total_selected} "
          f"tau={sel.tau:.4f} shard_counts={sel.shard_counts.tolist()} "
          f"-> recall={r:.3f}")


if __name__ == "__main__":
    main()
