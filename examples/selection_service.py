"""End-to-end selection service — the paper's full pipeline, served.

    PYTHONPATH=src python examples/selection_service.py

1. TRAIN a small proxy LM to detect a planted marker n-gram (the filter
   predicate) from batched token streams.
2. SERVE: run batched prefill scoring over the whole corpus with the
   pjit-able serve_prefill step, writing A(x) into a memory-mapped
   ScoreStore (the production scoring plane in miniature).
3. SELECT: build a SelectionEngine directly on the memory-mapped
   ScoreStore shard and serve a *batch* of RT / PT / JT SUPG queries
   through a `SelectionServer` daemon — one cached sketch + sampling
   state AND one shared, batched labeling channel amortized across every
   client (concurrent query plans coalesce their oracle requests into
   micro-batches; records labeled for one tenant's query answer the
   others from the cache for free), with admission control, per-tenant
   oracle quotas, and a token bucket pacing the labeling channel —
   verifying the statistical guarantees and comparing against the U-NoCI
   baseline used by prior systems, then printing the server's
   observability snapshot (`ServerStats`).
   The first query is served *streamed*: results reach the client
   incrementally through a SelectionStream (chunked shard-parallel
   emission; no full-corpus mask is ever materialized), which is how a
   service would page results out of a billion-record store.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import (SUPGQuery, array_oracle, precision_of, recall_of)
from repro.core.engine import SelectionEngine
from repro.core.queries import JointSUPGQuery
from repro.data import synthetic
from repro.data.pipeline import ScoreStore, SelectionStream
from repro.serve import SelectionServer
from repro.launch import serve as servelib
from repro.launch import train as trainlib
from repro.models import model
from repro.optim import adamw

CFG = ModelConfig(name="selector-proxy", family="dense", num_layers=2,
                  d_model=96, num_heads=4, num_kv_heads=2, d_ff=256,
                  vocab_size=128, dtype="float32")
CORPUS, SEQ = 20_000, 48


def train_proxy(tokens, labels, steps=120):
    params = model.init(jax.random.PRNGKey(0), CFG)
    opts = trainlib.TrainOptions(adamw=adamw.AdamWConfig(
        lr=3e-3, warmup_steps=10, total_steps=steps, weight_decay=0.0))
    step_fn = jax.jit(trainlib.make_train_step(CFG, opts))
    opt = adamw.init(params)
    rng = np.random.default_rng(0)
    pos_pool = np.nonzero(labels > 0.5)[0]
    neg_pool = np.nonzero(labels <= 0.5)[0]
    for i in range(steps):
        # class-balanced batches: at 2% TPR an unbalanced stream collapses
        # the proxy to the majority class (the standard practitioner fix)
        idx = np.concatenate([rng.choice(pos_pool, 32),
                              rng.choice(neg_pool, 32)])
        bt = tokens[idx]
        y = labels[idx].astype(np.int32)
        # class label at every position: post-marker positions carry signal
        lab = np.broadcast_to(y[:, None], bt.shape).astype(np.int32)
        params, opt, m = step_fn(params, opt,
                                 {"tokens": jnp.asarray(bt),
                                  "labels": jnp.asarray(lab)})
        if (i + 1) % 40 == 0:
            print(f"  train step {i+1}: loss {float(m['loss']):.4f}")
    return params


def main():
    print("[1/3] building corpus + training proxy")
    tokens, labels = synthetic.make_token_corpus(CORPUS, SEQ, CFG.vocab_size,
                                                 positive_rate=0.02, seed=1)
    params = train_proxy(tokens, labels)

    print("[2/3] batched scoring service over the corpus")
    serve_fn = jax.jit(servelib.make_serve_prefill(CFG, target_token=1))
    store = ScoreStore(tempfile.mktemp(suffix=".scores"), CORPUS,
                       create=True)
    bs = 512
    for off in range(0, CORPUS, bs):
        scores = serve_fn(params, {"tokens": jnp.asarray(
            tokens[off:off + bs])})
        store.write(off, np.asarray(scores))
    scores = store.read()
    truth = labels > 0.5
    print(f"  scored {store.num_scored} records; "
          f"mean A(x) pos={scores[truth].mean():.3f} "
          f"neg={scores[~truth].mean():.3f}")

    print("[3/3] SUPG queries via the SelectionServer daemon "
          "(budget=1500, delta=5%)")
    # The engine consumes the memory-mapped store directly (zero-copy) and
    # builds its sketch + chunk-level sampling state exactly once for the
    # whole service lifetime; workers=2 drives the chunked sketch/emission
    # walks through the thread pool (results are identical at any worker
    # count). The context managers guarantee the worker pool, session
    # pool, and drain thread are released even if a query blows up —
    # the original version leaked the engine on the error path.
    oracle = array_oracle(labels)
    with SelectionEngine([store], num_bins=4096, workers=2) as engine:
        # Streamed serving: the client consumes selection chunks as the
        # engine emits them, long before the query finishes — at
        # production scale this is the only shape that works (no
        # full-corpus mask exists to return).
        stream_q = SUPGQuery(target="recall", gamma=0.9, delta=0.05,
                             budget=1500, method="is")
        stream = SelectionStream(
            lambda sink: engine.run(jax.random.PRNGKey(3), oracle,
                                    stream_q, sink=sink,
                                    chunk_records=4096))
        streamed = 0
        for i, (shard_id, gids, folded) in enumerate(stream):
            streamed += gids.size
            kind = "folded-positives" if folded else "chunk"
            print(f"  stream[{i}] shard={shard_id} {kind:16s} "
                  f"+{gids.size:5d} (total {streamed})")
        print(f"  streamed selection done: {streamed} records, "
              f"tau={stream.result.tau:.4f} (counts held by the sink; "
              f"no mask materialized)")

        # Serve the batch through the daemon: concurrent clients submit
        # on behalf of tenants, admission control bounds in-flight plans,
        # per-tenant BudgetLedger quotas meter the oracle, and a token
        # bucket paces the shared labeling channel (the paper's §4.1
        # rate-limited-oracle model, made literal). All plans' oracle
        # requests funnel into one BatchingOracle, so a record labeled
        # for one tenant answers the others from the cache for free.
        batch = [SUPGQuery(target=target, gamma=gamma, delta=0.05,
                           budget=1500, method=method)
                 for target, gamma in (("recall", 0.9),
                                       ("precision", 0.75))
                 for method in ("is", "noci")]
        batch.append(JointSUPGQuery(gamma_recall=0.9, stage_budget=1500))
        keys = jax.random.split(jax.random.PRNGKey(3), len(batch))
        tenants = ["supg", "baseline", "supg", "baseline", "joint"]
        with SelectionServer(engine, oracle, own_engine=False,
                             max_inflight=4, max_batch=4096,
                             rate=500_000, burst=50_000,
                             quotas={"supg": 10_000, "baseline": 10_000,
                                     "joint": 40_000}) as server:
            handles = [server.submit(q, tenant=t, key=k)
                       for q, t, k in zip(batch, tenants, keys)]
            results = [h.result(timeout=600) for h in handles]
            stats = server.stats()
    print("  --- ServerStats ---")
    for line in stats.format().splitlines():
        print(f"  {line}")
    for q, sel in zip(batch, results):
        mask = np.concatenate(sel.masks)
        selected = np.nonzero(mask)[0]
        p = precision_of(selected, truth)
        r = recall_of(selected, truth)
        if isinstance(q, JointSUPGQuery):
            ok = ("MET " if r >= q.gamma_recall
                  and p >= q.gamma_precision else "MISS")
            print(f"  joint r>={q.gamma_recall:.0%} p>="
                  f"{q.gamma_precision:.0%} [JT    ] {ok} "
                  f"precision={p:.3f} recall={r:.3f} "
                  f"|R|={len(selected)} calls={sel.oracle_calls}")
            continue
        a = r if q.target == "recall" else p
        tag = "SUPG" if q.method == "is" else "U-NoCI"
        ok = "MET " if a >= q.gamma else "MISS"
        print(f"  {q.target:9s}>= {q.gamma:.0%} [{tag:6s}] {ok} "
              f"precision={p:.3f} recall={r:.3f} "
              f"|R|={len(selected)} calls={sel.oracle_calls}")


if __name__ == "__main__":
    main()
