"""End-to-end training driver: train a proxy LM for a few hundred steps
with the full production stack — pjit'd train_step, deterministic resumable
data pipeline, fault-tolerant loop with atomic async checkpoints.

    PYTHONPATH=src python examples/train_proxy.py [--steps 200] [--arch smoke]

Uses a reduced-width config of the smollm family (the zoo's cheap-proxy
tier) sized so a few hundred steps run on CPU in minutes. `--arch` accepts
any registry id to train its smoke variant instead.
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.configs.base import ModelConfig
from repro.data.pipeline import DeterministicSource
from repro.launch import train as trainlib
from repro.launch.fault import LoopConfig, TrainLoop
from repro.models import model
from repro.optim import adamw


def proxy_config():
    # ~1.1M params: 4L x 128d — trains to visible loss decrease in minutes.
    return ModelConfig(name="proxy-small", family="dense", num_layers=4,
                       d_model=128, num_heads=4, num_kv_heads=2, d_ff=512,
                       vocab_size=512, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--arch", default=None,
                    help="registry id -> train its smoke config instead")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.arch else proxy_config()
    print(f"training {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    params = model.init(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"parameters: {n_params/1e6:.2f}M")

    opts = trainlib.TrainOptions(adamw=adamw.AdamWConfig(
        lr=1e-3, warmup_steps=20, total_steps=args.steps))
    step_fn = jax.jit(trainlib.make_train_step(cfg, opts))
    opt_state = adamw.init(params)

    # markov-chain-ish synthetic stream: learnable next-token structure
    def make_batch(rng, step):
        start = rng.integers(0, cfg.vocab_size, (args.batch, 1))
        steps = rng.integers(1, 7, (args.batch, args.seq))
        toks = (np.cumsum(np.concatenate([start, steps], axis=1), axis=1)
                % cfg.vocab_size).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    source = DeterministicSource(make_batch, seed=0)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="proxy_ckpt_")
    ckpt = CheckpointManager(ckpt_dir, keep=2)

    losses = []

    def on_step(step, metrics):
        losses.append(float(metrics["loss"]))
        if step % 25 == 0:
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e}")

    loop = TrainLoop(step_fn, source, ckpt,
                     LoopConfig(total_steps=args.steps, ckpt_every=50),
                     on_step=on_step)
    params, opt_state, step = loop.run(params, opt_state)

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"done: step={step} loss {first:.3f} -> {last:.3f} "
          f"(ckpts at {ckpt_dir}: steps {ckpt.all_steps()})")
    assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()
