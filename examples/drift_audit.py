"""Model-drift audit (Section 6.2 / Table 4 as an operational procedure).

Shows why fixed proxy thresholds (the NoScope/PP deployment pattern) are
unsafe in production, and how the live plane's `DriftSentinel` turns the
paper's answer into a standing procedure: watch a certified query's
importance-weighted match rate, and when an appended epoch moves it past
the drift statistic's threshold, auto re-validate with a fresh (small)
oracle budget — the re-validated tau carries a fresh guarantee over the
corpus as of that epoch (see "What re-validation re-guarantees" in
docs/guarantees.md).

    PYTHONPATH=src python examples/drift_audit.py
"""
import jax
import numpy as np

from repro.core import array_oracle, recall_of
from repro.core.engine import SelectionEngine
from repro.core.queries import SUPGQuery
from repro.core.thresholds import tau_unoci_r
from repro.data.synthetic import make_beta, make_drift_pair
from repro.live import DriftSentinel, IngestPlane


def main():
    train, shifted = make_drift_pair(n=500_000, seed=0)
    print(f"train TPR={train.tpr:.3%}  shifted TPR={shifted.tpr:.3%}")

    gamma = 0.95
    # --- deployment pattern of prior systems: threshold fit once ---------
    tau_fixed = float(tau_unoci_r(train.scores, train.labels, gamma).tau)
    sel = np.nonzero(shifted.scores >= tau_fixed)[0]
    r_fixed = recall_of(sel, shifted.truth_mask())
    print(f"fixed threshold (fit on train, tau={tau_fixed:.4f}): "
          f"recall on shifted = {r_fixed:.3f} "
          f"{'VIOLATES' if r_fixed < gamma else 'meets'} {gamma:.0%} target")

    # --- the sentinel: watch, append the drifted epoch, auto-revalidate --
    labels = np.concatenate([train.labels, shifted.labels])
    q = SUPGQuery(target="recall", gamma=gamma, delta=0.05,
                  budget=10_000, method="is")
    with SelectionEngine(np.array_split(train.scores, 4), num_bins=4096,
                         use_kernel=False) as eng:
        sentinel = DriftSentinel(eng, array_oracle(labels),
                                 probe_budget=4096, sigma=4.0)
        watch = sentinel.watch(q, key=jax.random.PRNGKey(0))
        print(f"\ncertified on train epoch: tau={watch.tau:.4f} "
              f"(reference match rate {watch.ref_rate:.5f})")

        IngestPlane(eng).append(shifted.scores)
        report = sentinel.audit(watch, key=jax.random.PRNGKey(1))
        print(report.format())

        # The re-validated tau re-earns the guarantee on the grown corpus.
        sel = eng.run(jax.random.PRNGKey(2), array_oracle(labels), q)
        truth = labels > 0.5
        got = np.concatenate([np.flatnonzero(m) + off for m, off in
                              zip(sel.masks, eng.offsets)])
        print(f"re-validated query on the grown corpus: recall = "
              f"{recall_of(got, truth):.3f} (target {gamma:.0%})")

    # --- control: a same-distribution append stays quiet -----------------
    control = make_beta(500_000, 0.01, 1.0, seed=99)
    labels_c = np.concatenate([train.labels, control.labels])
    with SelectionEngine(np.array_split(train.scores, 4), num_bins=4096,
                         use_kernel=False) as eng:
        sentinel = DriftSentinel(eng, array_oracle(labels_c),
                                 probe_budget=4096, sigma=4.0)
        watch = sentinel.watch(q, key=jax.random.PRNGKey(0))
        IngestPlane(eng).append(control.scores)
        report = sentinel.audit(watch, key=jax.random.PRNGKey(1))
        print(f"\nundrifted control append: z = {report.z:.2f} "
              f"-> {'DRIFTED' if report.drifted else 'calibrated'} "
              f"(no re-validation spent)")


if __name__ == "__main__":
    main()
