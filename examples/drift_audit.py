"""Model-drift audit (Section 6.2 / Table 4 as an operational procedure).

Shows why fixed proxy thresholds (the NoScope/PP deployment pattern) are
unsafe in production, and how SUPG's query-time sampling makes selections
drift-proof: the same query is re-run against the drifted corpus with a
fresh (small) oracle budget, and the guarantee carries over automatically.

    PYTHONPATH=src python examples/drift_audit.py
"""
import jax
import numpy as np

from repro.core import SUPGQuery, array_oracle, recall_of, run_query
from repro.core.thresholds import tau_unoci_r
from repro.data.synthetic import make_drift_pair


def main():
    train, shifted = make_drift_pair(n=500_000, seed=0)
    print(f"train TPR={train.tpr:.3%}  shifted TPR={shifted.tpr:.3%}")

    gamma = 0.95
    # --- deployment pattern of prior systems: threshold fit once ---------
    tau_fixed = float(tau_unoci_r(train.scores, train.labels, gamma).tau)
    sel = np.nonzero(shifted.scores >= tau_fixed)[0]
    r_fixed = recall_of(sel, shifted.truth_mask())
    print(f"fixed threshold (fit on train, tau={tau_fixed:.4f}): "
          f"recall on shifted = {r_fixed:.3f} "
          f"{'VIOLATES' if r_fixed < gamma else 'meets'} {gamma:.0%} target")

    # --- SUPG: re-estimate at query time on the shifted corpus -----------
    vals = []
    for t in range(5):
        q = SUPGQuery(target="recall", gamma=gamma, delta=0.05,
                      budget=10_000, method="is")
        res = run_query(jax.random.PRNGKey(t), shifted.scores,
                        array_oracle(shifted.labels), q)
        vals.append(recall_of(res.selected, shifted.truth_mask()))
    print(f"SUPG at query time: recall on shifted = "
          f"{np.mean(vals):.3f} (min {np.min(vals):.3f} over 5 runs) "
          f"-> guarantee holds under drift")


if __name__ == "__main__":
    main()
