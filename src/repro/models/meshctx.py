"""Ambient mesh context for model code that needs explicit shard_map blocks.

The launchers (dryrun/train/serve) trace step functions inside
`with mesh_context(mesh):`; model modules that host shard_map regions (the
expert-parallel MoE path) fetch it here. Falls back to None — pure-GSPMD
paths — when no mesh is installed (CPU unit tests).
"""
from __future__ import annotations

import contextlib
import contextvars

_MESH = contextvars.ContextVar("repro_mesh", default=None)


@contextlib.contextmanager
def mesh_context(mesh):
    token = _MESH.set(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _MESH.reset(token)


def current_mesh():
    return _MESH.get()
