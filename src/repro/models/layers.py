"""Foundational layers: norms, embeddings, MLPs, RoPE, initializers.

Parameters are plain nested dicts of jnp arrays (pytrees). Every layer is a
pair of functions `init_*(key, ...) -> params` and `apply(params, x) -> y`,
kept pure so pjit/shard_map/scan compose without a module framework.

dtype policy: parameters are stored in cfg.dtype (bf16 in production
configs); matmuls accumulate in fp32 via `preferred_element_type`; norms and
softmax always run in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


def truncated_normal(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return truncated_normal(key, (d_in, d_out), scale, dtype)


def matmul(x, w):
    """fp32-accumulating matmul over the last dim of x."""
    return jnp.einsum("...d,df->...f", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def matmul_rowparallel(x, w, cfg):
    """Row-parallel (partial-sum) matmul: under TP the output needs a
    cross-shard all-reduce. With shard_activations (production meshes) the
    local result is emitted in the model dtype so GSPMD's all-reduce moves
    bf16, not fp32 — halving the dominant TP wire bytes (§Perf it. 5). The
    MXU still accumulates each local product in fp32; only the <=16-term
    cross-shard sum runs at bf16 (standard Megatron bf16-reduce mode)."""
    if cfg is not None and cfg.shard_activations and x.dtype != jnp.float32:
        return jnp.einsum("...d,df->...f", x, w,
                          preferred_element_type=x.dtype)
    return matmul(x, w)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# Embedding + output head
# --------------------------------------------------------------------------

def init_embedding(key, vocab, d, dtype):
    return {"table": truncated_normal(key, (vocab, d), 1.0, dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    """Logits via the (optionally tied) embedding table, fp32 accumulation."""
    return jnp.einsum("...d,vd->...v", x, params["table"],
                      preferred_element_type=jnp.float32)


def init_lm_head(key, d, vocab, dtype):
    return {"w": dense_init(key, d, vocab, dtype)}


def lm_head(params, x):
    return jnp.einsum("...d,dv->...v", x, params["w"],
                      preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------
# Gated MLP (SwiGLU family)
# --------------------------------------------------------------------------

def init_mlp(key, d, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff, dtype),
        "w_up": dense_init(k2, d, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d, dtype),
    }


def mlp(params, x, act="silu", cfg=None):
    g = matmul(x, params["w_gate"])
    u = matmul(x, params["w_up"])
    if act == "silu":
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    elif act == "gelu":
        h = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        raise ValueError(act)
    return matmul_rowparallel(h, params["w_down"], cfg)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_frequencies(head_dim, theta):
    exponents = np.arange(0, head_dim, 2, dtype=np.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim/2,)


def rope_angles(positions, head_dim, theta):
    """positions: (...,) int -> (..., head_dim/2) angles, fp32."""
    freqs = jnp.asarray(rope_frequencies(head_dim, theta))
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x, positions, theta):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    half = x.shape[-1] // 2
    ang = rope_angles(positions, x.shape[-1], theta)  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------

def softmax_cross_entropy(logits, labels, mask=None):
    """Mean CE over tokens; logits fp32 (..., vocab), labels int (...,).

    The label logit is picked with a where/iota reduction instead of
    take_along_axis — elementwise over the vocab dim, so it stays local when
    logits are vocab-sharded over the "model" mesh axis (GSPMD then emits a
    single small psum for the reduction instead of a gather).
    """
    logits = logits.astype(jnp.float32)
    vocab = logits.shape[-1]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    ll = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                 axis=-1)
    nll = logz - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
