"""Top-level model API: init / train logits / prefill scoring / decode.

    params = init(key, cfg)
    logits, aux = apply_train(params, cfg, tokens)          (B,S,V) or (B,S,K,V)
    scores      = proxy_scores(params, cfg, tokens, target) (B,) in [0,1]
    logits, caches = apply_decode(params, cfg, tokens, caches, pos)
    caches      = init_caches(cfg, batch, seq_len)

The proxy-score head is how the SUPG plane consumes a model: the score of a
record is the model's probability mass on a designated predicate token at
the last position — calibrated-ish, in [0,1], exactly the A(x) the paper
assumes (Sec 4.1: "executes the proxy model over the complete set of
records").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mamba, rwkv, transformer


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def init(key, cfg):
    k_emb, k_body, k_head = jax.random.split(key, 3)
    dt = layers.dtype_of(cfg)
    if cfg.num_codebooks > 1:
        emb = {"table": jax.vmap(
            lambda k: layers.init_embedding(k, cfg.vocab_size, cfg.d_model,
                                            dt)["table"])(
            jax.random.split(k_emb, cfg.num_codebooks))}
    else:
        emb = layers.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dt)
    params = {
        "embed": emb,
        "body": transformer.init_body(k_body, cfg),
        "ln_f": layers.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        if cfg.num_codebooks > 1:
            params["head"] = {"w": jax.vmap(
                lambda k: layers.dense_init(k, cfg.d_model, cfg.vocab_size,
                                            dt))(
                jax.random.split(k_head, cfg.num_codebooks))}
        else:
            params["head"] = layers.init_lm_head(
                k_head, cfg.d_model, cfg.vocab_size, dt)
    return params


def _embed(params, cfg, tokens):
    if cfg.num_codebooks > 1:
        # tokens: (B, S, K) — sum the K codebook embeddings (MusicGen).
        embs = jnp.einsum("bskd->bsd", jax.vmap(
            lambda t, tab: jnp.take(tab, t, axis=0),
            in_axes=(2, 0), out_axes=2)(tokens, params["embed"]["table"]))
        return embs
    return layers.embed(params["embed"], tokens)


def _head(params, cfg, x):
    if cfg.tie_embeddings:
        return layers.unembed(params["embed"], x)
    if cfg.num_codebooks > 1:
        return jnp.einsum("bsd,kdv->bskv", x, params["head"]["w"],
                          preferred_element_type=jnp.float32)
    return layers.lm_head(params["head"], x)


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------

def _constrain_vocab(cfg, logits):
    """Vocab-shard the logits over the 'model' axis (fp32 logits at 32k+
    vocab dominate train-step HBM otherwise). UNCONSTRAINED elsewhere so
    GSPMD keeps the batch layout it propagated."""
    if not cfg.shard_activations:
        return logits
    from jax.sharding import PartitionSpec as P
    u = P.UNCONSTRAINED
    spec = P(*([u] * (logits.ndim - 1) + ["model"]))
    return jax.lax.with_sharding_constraint(logits, spec)


def apply_train(params, cfg, tokens, q_chunk=1024, kv_chunk=1024):
    """Training/prefill logits over the full sequence."""
    b = tokens.shape[0]
    s = tokens.shape[1]
    if cfg.unroll_layers:
        # cost-probe mode: no attention chunk scans either — XLA's cost
        # model counts while bodies once, so probes must be loop-free.
        q_chunk = kv_chunk = s
    x = _embed(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x, aux = transformer.body_prefill(params["body"], cfg, x, positions,
                                      q_chunk=q_chunk, kv_chunk=kv_chunk)
    x = layers.rms_norm(params["ln_f"], x, cfg.norm_eps)
    return _constrain_vocab(cfg, _head(params, cfg, x)), aux


def loss_fn(params, cfg, tokens, labels, mask=None):
    logits, aux = apply_train(params, cfg, tokens)
    if cfg.num_codebooks > 1:
        ce = layers.softmax_cross_entropy(
            logits.reshape(-1, cfg.vocab_size), labels.reshape(-1))
    else:
        ce = layers.softmax_cross_entropy(logits, labels, mask)
    return ce + aux, (ce, aux)


def proxy_scores(params, cfg, tokens, target_token=1):
    """A(x) in [0,1]: probability of the predicate token at the last step."""
    logits, _ = apply_train(params, cfg, tokens)
    last = logits[:, -1]
    if cfg.num_codebooks > 1:
        last = last.mean(axis=1)
    p = jax.nn.softmax(last.astype(jnp.float32), axis=-1)
    return p[..., target_token]


def apply_decode(params, cfg, tokens, caches, pos):
    """tokens: (B,1) or (B,1,K); pos: (B,). Returns (logits, new_caches)."""
    x = _embed(params, cfg, tokens)
    x, new_caches = transformer.body_decode(params["body"], cfg, x,
                                            caches, pos)
    x = layers.rms_norm(params["ln_f"], x, cfg.norm_eps)
    return _head(params, cfg, x), new_caches


# --------------------------------------------------------------------------
# Cache construction
# --------------------------------------------------------------------------

def _attn_cache(cfg, batch, seq_len, dtype):
    spec = (attention.mla_cache_spec if cfg.use_mla
            else attention.gqa_cache_spec)(cfg, batch, seq_len, dtype)
    return {k: jnp.zeros(shape, dt) for k, (shape, dt) in spec.items()}


def _stack(n, tree):
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape),
                        tree)


def init_caches(cfg, batch, seq_len, dtype=jnp.bfloat16):
    """Zeroed decode caches matching body_decode's expected structure."""
    if cfg.block == "rwkv":
        return {"blocks": _stack(cfg.num_layers,
                                 rwkv.init_rwkv_state(cfg, batch, dtype))}
    if cfg.block == "mamba":
        n_super = cfg.num_layers // cfg.shared_attn_every if \
            cfg.shared_attn_every else 0
        per = cfg.shared_attn_every
        tail = cfg.num_layers - n_super * per
        out = {
            "mamba_super": _stack(max(n_super, 1), _stack(
                per or 1, mamba.init_mamba_state(cfg, batch, dtype))),
            "shared_attn": _stack(max(n_super, 1),
                                  _attn_cache(cfg, batch, seq_len, dtype)),
        }
        if tail:
            out["mamba_tail"] = _stack(
                tail, mamba.init_mamba_state(cfg, batch, dtype))
        return out
    if cfg.moe and cfg.moe_layer_step > 1:
        n_pairs = cfg.num_layers // cfg.moe_layer_step
        return {"dense": _stack(n_pairs,
                                _attn_cache(cfg, batch, seq_len, dtype)),
                "moe": _stack(n_pairs,
                              _attn_cache(cfg, batch, seq_len, dtype))}
    if cfg.moe:
        n_moe = cfg.num_layers - cfg.first_k_dense
        return {
            "dense_prefix": _stack(max(cfg.first_k_dense, 1),
                                   _attn_cache(cfg, batch, seq_len, dtype)),
            "moe_blocks": _stack(n_moe,
                                 _attn_cache(cfg, batch, seq_len, dtype)),
        }
    return {"blocks": _stack(cfg.num_layers,
                             _attn_cache(cfg, batch, seq_len, dtype))}


# --------------------------------------------------------------------------
# Analytic parameter / FLOP counts (roofline denominators)
# --------------------------------------------------------------------------

def count_params_analytic(cfg, active_only=False):
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    total = V * d * cfg.num_codebooks          # embedding
    if not cfg.tie_embeddings:
        total += d * V * cfg.num_codebooks     # head

    if cfg.block == "rwkv":
        per = 5 * d * d + d * cfg.d_ff * 2 + d * d   # tm + cm projections
        per += 5 * cfg.rwkv_lora_dim * d * 2 + 2 * cfg.rwkv_lora_dim * d * 2
        return total + L * per

    if cfg.block == "mamba":
        d_in = cfg.ssm_expand * d
        n = cfg.ssm_state_dim
        h = d_in // cfg.ssm_head_dim
        per = d * (2 * d_in + 2 * n + h) + d_in * d
        n_super = L // cfg.shared_attn_every if cfg.shared_attn_every else 0
        shared = 0
        if cfg.shared_attn_every:
            hd = cfg.head_dim
            shared = d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads) \
                + cfg.num_heads * hd * d + 3 * d * cfg.d_ff
        return total + L * per + shared

    # attention params
    if cfg.use_mla:
        attn = d * (cfg.q_lora_rank or 0)
        q_in = cfg.q_lora_rank if cfg.q_lora_rank else d
        attn += q_in * cfg.num_heads * (cfg.qk_nope_head_dim
                                        + cfg.qk_rope_head_dim)
        attn += d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
        attn += cfg.kv_lora_rank * cfg.num_heads * (
            cfg.qk_nope_head_dim + cfg.v_head_dim)
        attn += cfg.num_heads * cfg.v_head_dim * d
    else:
        hd = cfg.head_dim
        attn = d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads) \
            + cfg.num_heads * hd * d

    mlp_dense = 3 * d * (cfg.dense_d_ff or cfg.d_ff)

    if cfg.moe:
        expert = 3 * d * cfg.moe_d_ff
        shared = 3 * d * cfg.moe_d_ff * cfg.num_shared_experts
        router = d * cfg.num_experts
        if cfg.moe_layer_step > 1:
            n_moe = L // cfg.moe_layer_step
            n_dense = L - n_moe
        else:
            n_moe = L - cfg.first_k_dense
            n_dense = cfg.first_k_dense
        e_count = (cfg.num_experts_per_tok if active_only
                   else cfg.num_experts)
        return total + L * attn + n_dense * mlp_dense \
            + n_moe * (expert * e_count + shared + router)

    return total + L * (attn + mlp_dense)


def train_flops_analytic(cfg, batch, seq):
    """6·N_active·D (+ attention quadratic term) — the §Roofline MODEL_FLOPS."""
    n_active = count_params_analytic(cfg, active_only=True)
    flops = 6.0 * n_active * batch * seq
    if cfg.num_heads and cfg.block == "attn":
        hd = cfg.head_dim if not cfg.use_mla else (
            cfg.qk_nope_head_dim + cfg.qk_rope_head_dim + cfg.v_head_dim)
        # causal: 2 matmuls * S^2/2 * heads * hd, *3 for fwd+bwd, per layer
        flops += 3.0 * 2.0 * batch * seq * seq * cfg.num_heads * hd \
            * cfg.num_layers / 2.0
    return flops
