"""Attention: GQA/MHA (+QKV-bias, qk-norm) and MLA (DeepSeek-V2), with
chunked-online-softmax prefill and KV-cache decode.

Prefill uses a two-level blocked online-softmax scan (`chunked_causal_attention`)
— mathematically exact, bounded intermediates (never materializes S x S), and
the jnp analogue of the Pallas flash_attention kernel (kernels/flash_attention
is the TPU hot path; this path is what the dry-run lowers).

Decode attends a single new token against a (B, S, KV, hd) cache. MLA decode
uses the *absorbed* formulation: scores and outputs live in the compressed
latent space (kv_lora + rope dims per token — MQA-grade cache traffic), which
is the technique's entire point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import dense_init, matmul, matmul_rowparallel

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Blocked causal attention (exact, online softmax)
# --------------------------------------------------------------------------

def chunked_causal_attention(q, k, v, *, q_chunk=1024, kv_chunk=1024):
    """q: (B,S,H,dh), k/v: (B,S,KV,dh) -> (B,S,H,dh). Causal, GQA-aware.

    Two-level lax.scan with online softmax: outer over query chunks, inner
    over kv chunks (only chunks at-or-before the query chunk contribute).
    Exact — matches plain softmax attention to fp32 tolerance.
    """
    b, s, h, dh = q.shape
    kv = k.shape[2]
    dv = v.shape[3]                      # may differ from dh (MLA)
    g = h // kv
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    nq, nk = s // q_chunk, s // kv_chunk
    assert s % q_chunk == 0 and s % kv_chunk == 0, "seq not chunk-divisible"

    qc = q.reshape(b, nq, q_chunk, kv, g, dh)
    kc = k.reshape(b, nk, kv_chunk, kv, dh)
    vc = v.reshape(b, nk, kv_chunk, kv, dv)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    def outer(_, qi):
        qblk, qidx = qi                      # (b, qc, kv, g, dh), scalar
        q_pos = qidx * q_chunk + jnp.arange(q_chunk)

        @jax.checkpoint
        def inner(carry, ki):
            acc, m_run, l_run = carry
            kblk, vblk, kidx = ki
            k_pos = kidx * kv_chunk + jnp.arange(kv_chunk)
            scores = jnp.einsum("bqkgd,bpkd->bkgqp", qblk, kblk,
                                preferred_element_type=jnp.float32) * scale
            mask = q_pos[:, None] >= k_pos[None, :]
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(scores, axis=-1))
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqp,bpkd->bkgqd", p,
                            vblk.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l_new), None

        # Skip fully-masked kv chunks: static slice bound via dynamic trip
        # count is not scannable, so mask handles causality; XLA still
        # executes all chunks — the Pallas kernel skips them for real.
        acc0 = jnp.zeros((b, kv, g, q_chunk, dv), jnp.float32)
        m0 = jnp.full((b, kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
        (acc, _, l), _ = jax.lax.scan(
            inner, (acc0, m0, l0),
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(
        outer, None, (qc.transpose(1, 0, 2, 3, 4, 5), jnp.arange(nq)))
    # blocks: (nq, b, kv, g, q_chunk, dv) -> (b, s, h, dv)
    out = blocks.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, h, dv)
    return out


def context_parallel_attention(q, k, v, *, m_size, q_chunk=None,
                               kv_chunk=1024):
    """Causal attention with the query-chunk axis BATCHED (not scanned) and
    sharded over the "model" mesh axis — context parallelism.

    Motivation (§Perf iteration 2): archs whose head counts don't divide
    TP-16 (smollm 15H, qwen 20H, musicgen 24H) get their attention fully
    replicated across the model axis by GSPMD — 16x wasted FLOPs at 32k
    prefill. Sharding the *sequence* instead is head-count-agnostic: each
    model shard owns nq/16 query chunks and attends them against the full
    K/V (which GQA keeps small). The kv-chunk loop stays an online-softmax
    scan, so peak memory per device matches the scanned form once the nq
    axis is sharded.
    """
    b, s, h, dh = q.shape
    kv = k.shape[2]
    dv = v.shape[3]
    g = h // kv
    nq = m_size * max(1, s // (1024 * m_size))
    if q_chunk is None:
        q_chunk = s // nq
    nq = s // q_chunk
    kv_chunk = min(kv_chunk, s)
    nk = s // kv_chunk
    assert s % q_chunk == 0 and s % kv_chunk == 0

    from jax.sharding import PartitionSpec as P
    u = P.UNCONSTRAINED
    qc = q.reshape(b, nq, q_chunk, kv, g, dh)
    if nq % m_size == 0:
        qc = jax.lax.with_sharding_constraint(
            qc, P(u, "model", u, u, u, u))
    kc = k.reshape(b, nk, kv_chunk, kv, dh)
    vc = v.reshape(b, nk, kv_chunk, kv, dv)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    q_pos = (jnp.arange(nq)[:, None] * q_chunk
             + jnp.arange(q_chunk)[None, :])          # (nq, qc)

    @jax.checkpoint
    def inner(carry, ki):
        acc, m_run, l_run = carry
        kblk, vblk, kidx = ki
        k_pos = kidx * kv_chunk + jnp.arange(kv_chunk)
        scores = jnp.einsum("bnckgd,bpkd->bnkgcp", qc, kblk,
                            preferred_element_type=jnp.float32) * scale
        mask = q_pos[:, :, None] >= k_pos[None, None, :]   # (nq, qc, kvc)
        scores = jnp.where(mask[None, :, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bnkgcp,bpkd->bnkgcd", p, vblk.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, nq, kv, g, q_chunk, dv), jnp.float32)
    m0 = jnp.full((b, nq, kv, g, q_chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nq, kv, g, q_chunk), jnp.float32)
    (acc, _, l), _ = jax.lax.scan(
        inner, (acc0, m0, l0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
         jnp.arange(nk)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # (b, nq, kv, g, qc, dv) -> (b, s, h, dv)
    return out.transpose(0, 1, 4, 2, 3, 5).reshape(b, s, h, dv).astype(
        q.dtype)


def _attention_dispatch(cfg, q, k, v, q_chunk, kv_chunk):
    """Pick scanned (memory-lean default) vs context-parallel (production
    mesh) blocked attention."""
    if cfg.shard_activations:
        from repro.models import meshctx
        mesh = meshctx.current_mesh()
        if mesh is not None and "model" in mesh.axis_names:
            m_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
            s = q.shape[1]
            if m_size > 1 and s % (m_size * 128) == 0:
                # probe mode: loop-free (kv unchunked) so costs are counted
                kvc = s if cfg.unroll_layers else kv_chunk
                return context_parallel_attention(q, k, v, m_size=m_size,
                                                  kv_chunk=kvc)
    return chunked_causal_attention(q, k, v, q_chunk=q_chunk,
                                    kv_chunk=kv_chunk)


def decode_attention(q, cache_k, cache_v, pos):
    """q: (B,1,H,dh); cache: (B,S,KV,dh); pos: (B,) current index.

    Attends over cache positions <= pos. Returns (B,1,H,dh).
    """
    b, _, h, dh = q.shape
    s, kv = cache_k.shape[1], cache_k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, dh)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k,
                        preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(s)[None] <= pos[:, None]          # (B,S)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, cache_v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention layer
# --------------------------------------------------------------------------

def init_gqa(key, cfg):
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = layers.dtype_of(cfg)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, kvh * hd, dt),
        "wv": dense_init(ks[2], d, kvh * hd, dt),
        "wo": dense_init(ks[3], h * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kvh * hd,), dt)
        p["bv"] = jnp.zeros((kvh * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rmsnorm(hd)
        p["k_norm"] = layers.init_rmsnorm(hd)
    return p


def _gqa_qkv(p, cfg, x, positions):
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = matmul(x, p["wq"])
    k = matmul(x, p["wk"])
    v = matmul(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = layers.rms_norm(p["k_norm"], k, cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_prefill(p, cfg, x, positions, q_chunk=1024, kv_chunk=1024):
    b, s, _ = x.shape
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    o = _attention_dispatch(cfg, q, k, v, q_chunk, kv_chunk)
    return matmul_rowparallel(o.reshape(b, s, -1), p["wo"], cfg)


def gqa_decode(p, cfg, x, cache, pos):
    """x: (B,1,d); cache: {'k','v'}: (B,S,KV,hd); pos: (B,)."""
    b = x.shape[0]
    q, k_new, v_new = _gqa_qkv(p, cfg, x, pos[:, None])
    upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
        c, n, (i, 0, 0)))
    cache_k = upd(cache["k"], k_new, pos)
    cache_v = upd(cache["v"], v_new, pos)
    o = decode_attention(q, cache_k, cache_v, pos)
    y = matmul(o.reshape(b, 1, -1), p["wo"])
    return y, {"k": cache_k, "v": cache_v}


def gqa_cache_spec(cfg, batch, seq_len, dtype):
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, seq_len, kvh, hd)
    return {"k": (shape, dtype), "v": (shape, dtype)}


# --------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2)
# --------------------------------------------------------------------------

def init_mla(key, cfg):
    d = cfg.d_model
    h = cfg.num_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = layers.dtype_of(cfg)
    ks = jax.random.split(key, 7)
    p = {
        "w_dkv": dense_init(ks[2], d, r_kv + dr, dt),   # latent + shared rope
        "kv_norm": layers.init_rmsnorm(r_kv),
        "w_uk": dense_init(ks[3], r_kv, h * dn, dt),
        "w_uv": dense_init(ks[4], r_kv, h * dv, dt),
        "wo": dense_init(ks[5], h * dv, d, dt),
    }
    if r_q:
        p["w_dq"] = dense_init(ks[0], d, r_q, dt)
        p["q_norm"] = layers.init_rmsnorm(r_q)
        p["w_uq"] = dense_init(ks[1], r_q, h * (dn + dr), dt)
    else:
        p["wq"] = dense_init(ks[0], d, h * (dn + dr), dt)
    return p


def _mla_q(p, cfg, x, positions):
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        q = matmul(layers.rms_norm(p["q_norm"], matmul(x, p["w_dq"]),
                                   cfg.norm_eps), p["w_uq"])
    else:
        q = matmul(x, p["wq"])
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, cfg, x, positions):
    dkv = matmul(x, p["w_dkv"])
    c = layers.rms_norm(p["kv_norm"], dkv[..., :cfg.kv_lora_rank],
                        cfg.norm_eps)
    k_rope = dkv[..., cfg.kv_lora_rank:][..., None, :]   # shared single head
    k_rope = layers.apply_rope(k_rope, positions, cfg.rope_theta)[..., 0, :]
    return c, k_rope


def mla_prefill(p, cfg, x, positions, q_chunk=1024, kv_chunk=1024):
    """Materialized (training-style) MLA attention."""
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c, k_rope = _mla_latent(p, cfg, x, positions)
    k_nope = matmul(c, p["w_uk"]).reshape(b, s, h, dn)
    v = matmul(c, p["w_uv"]).reshape(b, s, h, dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))],
        axis=-1)
    o = _attention_dispatch(cfg, q, k, v, q_chunk, kv_chunk)
    return matmul_rowparallel(o.reshape(b, s, -1), p["wo"], cfg)


def mla_decode(p, cfg, x, cache, pos):
    """Absorbed-latent decode: cache = {'c': (B,S,r_kv), 'k_rope': (B,S,dr)}.

    Per-token score: q_nope W_uk . c_s  +  q_rope . k_rope_s, computed
    without materializing per-head K/V — the cache line per token is
    (r_kv + dr) = 576 floats regardless of the 128 heads.
    """
    b = x.shape[0]
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r_kv = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(p, cfg, x, pos[:, None])     # (B,1,H,*)
    c_new, kr_new = _mla_latent(p, cfg, x, pos[:, None])
    upd2 = jax.vmap(lambda cch, n, i: jax.lax.dynamic_update_slice(
        cch, n, (i, 0)))
    cache_c = upd2(cache["c"], c_new, pos)
    cache_kr = upd2(cache["k_rope"], kr_new, pos)

    w_uk = p["w_uk"].reshape(r_kv, h, dn)
    # Absorb W_uk into the query: (B,H,r_kv)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk,
                       preferred_element_type=jnp.float32)
    s_lat = jnp.einsum("bhr,bsr->bhs", q_lat,
                       cache_c.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                        cache_kr.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(dn + dr))
    scores = (s_lat + s_rope) * scale
    valid = jnp.arange(cache_c.shape[1])[None] <= pos[:, None]
    scores = jnp.where(valid[:, None], scores, NEG_INF)
    pattn = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", pattn, cache_c.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    w_uv = p["w_uv"].reshape(r_kv, h, dv)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    y = matmul(o.reshape(b, 1, -1).astype(x.dtype), p["wo"])
    return y, {"c": cache_c, "k_rope": cache_kr}


def mla_cache_spec(cfg, batch, seq_len, dtype):
    return {"c": ((batch, seq_len, cfg.kv_lora_rank), dtype),
            "k_rope": ((batch, seq_len, cfg.qk_rope_head_dim), dtype)}
