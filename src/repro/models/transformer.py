"""Block composition and full-model forward passes for all 10 architectures.

Layer stacking uses `jax.lax.scan` over parameter pytrees stacked on a
leading L axis — HLO size (and XLA compile time) stays independent of depth,
which is what makes the 48-60-layer production configs compilable in the
dry-run. Heterogeneous archs scan over their repeating unit:

  dense / vlm / audio : scan over L identical (attn + MLP) blocks
  deepseek-v2         : 1 unscanned dense block + scan over 59 MLA+MoE blocks
  llama4-maverick     : scan over 24 (attn+MLP, attn+MoE) pairs (interleaved)
  rwkv6               : scan over 32 RWKV blocks
  zamba2              : scan over 6 super-blocks [6 Mamba2 + shared attn+MLP]
                        + a scanned tail of 2 Mamba2 blocks; the shared
                        block's weights are reused at every invocation
                        (per-invocation KV caches, stacked on the superblock
                        axis)

Activation checkpointing: cfg.remat == 'block' wraps each scanned body in
jax.checkpoint so the backward pass recomputes block internals.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mamba, moe, rwkv


def _split_stack(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _maybe_remat(fn, cfg):
    if cfg.remat == "block":
        return jax.checkpoint(fn)
    return fn


def _scan_layers(body, carry, xs, cfg):
    """lax.scan over stacked layer params — or an unrolled python loop when
    cfg.unroll_layers (used by the dry-run's per-layer cost probes, since
    XLA's cost model counts a while body once regardless of trip count)."""
    if not cfg.unroll_layers:
        return jax.lax.scan(_maybe_remat(body, cfg), carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    return carry, jax.tree.map(lambda *a: jnp.stack(a), *ys)


# --------------------------------------------------------------------------
# Standard transformer block (attn or MLA, MLP or MoE)
# --------------------------------------------------------------------------

def init_attn_block(key, cfg, ffn="mlp"):
    k1, k2 = jax.random.split(key)
    p = {"ln1": layers.init_rmsnorm(cfg.d_model),
         "ln2": layers.init_rmsnorm(cfg.d_model)}
    p["attn"] = (attention.init_mla(k1, cfg) if cfg.use_mla
                 else attention.init_gqa(k1, cfg))
    if ffn == "mlp":
        d_ff = cfg.dense_d_ff or cfg.d_ff
        p["mlp"] = layers.init_mlp(k2, cfg.d_model, d_ff, layers.dtype_of(cfg))
    else:
        p["moe"] = moe.init_moe(k2, cfg)
    return p


def attn_block_prefill(p, cfg, x, positions, ffn="mlp", gate_fn="softmax",
                       q_chunk=1024, kv_chunk=1024):
    xn = layers.rms_norm(p["ln1"], x, cfg.norm_eps)
    attn_fn = attention.mla_prefill if cfg.use_mla else attention.gqa_prefill
    x = x + attn_fn(p["attn"], cfg, xn, positions,
                    q_chunk=q_chunk, kv_chunk=kv_chunk)
    xn = layers.rms_norm(p["ln2"], x, cfg.norm_eps)
    if ffn == "mlp":
        x = x + layers.mlp(p["mlp"], xn, cfg.act, cfg)
        aux = jnp.float32(0.0)
    else:
        h, aux = moe.moe_apply(p["moe"], cfg, xn, gate_fn)
        x = x + h
    return x, aux


def attn_block_decode(p, cfg, x, cache, pos, ffn="mlp", gate_fn="softmax"):
    xn = layers.rms_norm(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        h, new_cache = attention.mla_decode(p["attn"], cfg, xn, cache, pos)
    else:
        h, new_cache = attention.gqa_decode(p["attn"], cfg, xn, cache, pos)
    x = x + h
    xn = layers.rms_norm(p["ln2"], x, cfg.norm_eps)
    if ffn == "mlp":
        x = x + layers.mlp(p["mlp"], xn, cfg.act, cfg)
    else:
        h, _ = moe.moe_apply(p["moe"], cfg, xn, gate_fn)
        x = x + h
    return x, new_cache


# --------------------------------------------------------------------------
# Architecture bodies: init + prefill/train forward + decode forward
# --------------------------------------------------------------------------

def init_body(key, cfg):
    fam = cfg.family
    if cfg.block == "rwkv":
        return {"blocks": _split_stack(
            key, cfg.num_layers, lambda k: rwkv.init_rwkv_block(k, cfg))}
    if cfg.block == "mamba":
        return _init_zamba_body(key, cfg)
    if cfg.moe and cfg.moe_layer_step > 1:      # llama4: interleaved pairs
        n_pairs = cfg.num_layers // cfg.moe_layer_step
        k1, k2 = jax.random.split(key)
        return {
            "pairs_dense": _split_stack(
                k1, n_pairs, lambda k: init_attn_block(k, cfg, "mlp")),
            "pairs_moe": _split_stack(
                k2, n_pairs, lambda k: init_attn_block(k, cfg, "moe")),
        }
    if cfg.moe:                                  # deepseek-v2: dense prefix
        k1, k2 = jax.random.split(key)
        n_moe = cfg.num_layers - cfg.first_k_dense
        return {
            "dense_prefix": _split_stack(
                k1, max(cfg.first_k_dense, 1),
                lambda k: init_attn_block(k, cfg, "mlp")),
            "moe_blocks": _split_stack(
                k2, n_moe, lambda k: init_attn_block(k, cfg, "moe")),
        }
    return {"blocks": _split_stack(
        key, cfg.num_layers, lambda k: init_attn_block(k, cfg, "mlp"))}


def _init_zamba_body(key, cfg):
    n_super = cfg.num_layers // cfg.shared_attn_every if \
        cfg.shared_attn_every else 0
    per_super = cfg.shared_attn_every
    tail = cfg.num_layers - n_super * per_super
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"mamba_super": _split_stack(
        k1, max(n_super, 1),
        lambda k: _split_stack(k, per_super or 1,
                               lambda kk: mamba.init_mamba_block(kk, cfg)))}
    if tail:
        p["mamba_tail"] = _split_stack(
            k2, tail, lambda k: mamba.init_mamba_block(k, cfg))
    if cfg.shared_attn_every:
        p["shared_attn"] = init_attn_block(k3, cfg, "mlp")
    return p


# ---- prefill / train forward ----------------------------------------------

def body_prefill(params, cfg, x, positions, q_chunk=1024, kv_chunk=1024):
    """x: (B,S,d) -> (B,S,d), aux_loss. Scan-over-layers everywhere."""
    aux_total = jnp.float32(0.0)
    gate_fn = "sigmoid" if cfg.moe_layer_step > 1 else "softmax"

    if cfg.block == "rwkv":
        state = rwkv.init_rwkv_state(cfg, x.shape[0], x.dtype)

        def body(h, blk):
            out, _ = rwkv.rwkv_block(blk, cfg, h, state)
            return out, None
        x, _ = _scan_layers(body, x, params["blocks"], cfg)
        return x, aux_total

    if cfg.block == "mamba":
        return _zamba_prefill(params, cfg, x, positions, q_chunk, kv_chunk)

    if cfg.moe and cfg.moe_layer_step > 1:
        def pair_body(carry, blks):
            h, aux = carry
            dense_p, moe_p = blks
            h, _ = attn_block_prefill(dense_p, cfg, h, positions, "mlp",
                                      q_chunk=q_chunk, kv_chunk=kv_chunk)
            h, a = attn_block_prefill(moe_p, cfg, h, positions, "moe",
                                      gate_fn, q_chunk, kv_chunk)
            return (h, aux + a), None
        (x, aux_total), _ = _scan_layers(
            pair_body, (x, aux_total),
            (params["pairs_dense"], params["pairs_moe"]), cfg)
        return x, aux_total

    if cfg.moe:
        def dense_body(carry, blk):
            h, _ = attn_block_prefill(blk, cfg, carry, positions, "mlp",
                                      q_chunk=q_chunk, kv_chunk=kv_chunk)
            return h, None
        x, _ = _scan_layers(dense_body, x, params["dense_prefix"], cfg)

        def moe_body(carry, blk):
            h, aux = carry
            h, a = attn_block_prefill(blk, cfg, h, positions, "moe",
                                      gate_fn, q_chunk, kv_chunk)
            return (h, aux + a), None
        (x, aux_total), _ = _scan_layers(
            moe_body, (x, aux_total), params["moe_blocks"], cfg)
        return x, aux_total

    def body(h, blk):
        out, _ = attn_block_prefill(blk, cfg, h, positions, "mlp",
                                    q_chunk=q_chunk, kv_chunk=kv_chunk)
        return out, None
    x, _ = _scan_layers(body, x, params["blocks"], cfg)
    return x, aux_total


def _zamba_prefill(params, cfg, x, positions, q_chunk, kv_chunk):
    state = mamba.init_mamba_state(cfg, x.shape[0], x.dtype)
    shared = params.get("shared_attn")

    def super_body(h, super_blks):
        def inner(hh, blk):
            out, _ = mamba.mamba_block(blk, cfg, hh, state)
            return out, None
        h, _ = jax.lax.scan(inner, h, super_blks)
        if shared is not None:
            h, _ = attn_block_prefill(shared, cfg, h, positions, "mlp",
                                      q_chunk=q_chunk, kv_chunk=kv_chunk)
        return h, None

    x, _ = _scan_layers(super_body, x, params["mamba_super"], cfg)
    if "mamba_tail" in params:
        def tail_body(h, blk):
            out, _ = mamba.mamba_block(blk, cfg, h, state)
            return out, None
        x, _ = _scan_layers(tail_body, x, params["mamba_tail"], cfg)
    return x, jnp.float32(0.0)


# ---- decode forward --------------------------------------------------------

def body_decode(params, cfg, x, caches, pos):
    """x: (B,1,d); caches as produced by init_caches. Returns (x, caches)."""
    gate_fn = "sigmoid" if cfg.moe_layer_step > 1 else "softmax"

    if cfg.block == "rwkv":
        def body(h, blk_cache):
            blk, st = blk_cache
            out, new_st = rwkv.rwkv_block(blk, cfg, h, st)
            return out, new_st
        x, new_states = _scan_layers(body, x,
                                     (params["blocks"], caches["blocks"]), cfg)
        return x, {"blocks": new_states}

    if cfg.block == "mamba":
        return _zamba_decode(params, cfg, x, caches, pos)

    if cfg.moe and cfg.moe_layer_step > 1:
        def pair_body(h, xs):
            dense_p, moe_p, c_d, c_m = xs
            h, nc_d = attn_block_decode(dense_p, cfg, h, c_d, pos, "mlp")
            h, nc_m = attn_block_decode(moe_p, cfg, h, c_m, pos, "moe",
                                        gate_fn)
            return h, (nc_d, nc_m)
        x, (nc_d, nc_m) = _scan_layers(
            pair_body, x, (params["pairs_dense"], params["pairs_moe"],
                           caches["dense"], caches["moe"]), cfg)
        return x, {"dense": nc_d, "moe": nc_m}

    if cfg.moe:
        def dense_body(h, xs):
            blk, c = xs
            h, nc = attn_block_decode(blk, cfg, h, c, pos, "mlp")
            return h, nc
        x, nc_prefix = _scan_layers(
            dense_body, x, (params["dense_prefix"], caches["dense_prefix"]), cfg)

        def moe_body(h, xs):
            blk, c = xs
            h, nc = attn_block_decode(blk, cfg, h, c, pos, "moe", gate_fn)
            return h, nc
        x, nc_moe = _scan_layers(
            moe_body, x, (params["moe_blocks"], caches["moe_blocks"]), cfg)
        return x, {"dense_prefix": nc_prefix, "moe_blocks": nc_moe}

    def body(h, xs):
        blk, c = xs
        h, nc = attn_block_decode(blk, cfg, h, c, pos, "mlp")
        return h, nc
    x, ncs = _scan_layers(body, x, (params["blocks"], caches["blocks"]), cfg)
    return x, {"blocks": ncs}


def _zamba_decode(params, cfg, x, caches, pos):
    shared = params.get("shared_attn")

    def super_body(h, xs):
        super_blks, m_state, attn_cache = xs

        def inner(carry, blk_state):
            hh = carry
            blk, st = blk_state
            out, new_st = mamba.mamba_block(blk, cfg, hh, st)
            return out, new_st
        h, new_m = jax.lax.scan(inner, h, (super_blks, m_state))
        if shared is not None:
            h, new_attn = attn_block_decode(shared, cfg, h, attn_cache, pos)
        else:
            new_attn = attn_cache
        return h, (new_m, new_attn)

    x, (new_m, new_attn) = _scan_layers(
        super_body, x,
        (params["mamba_super"], caches["mamba_super"], caches["shared_attn"]), cfg)
    out_caches = {"mamba_super": new_m, "shared_attn": new_attn}
    if "mamba_tail" in params:
        def tail_body(h, xs):
            blk, st = xs
            out, new_st = mamba.mamba_block(blk, cfg, h, st)
            return out, new_st
        x, new_tail = _scan_layers(
            tail_body, x, (params["mamba_tail"], caches["mamba_tail"]), cfg)
        out_caches["mamba_tail"] = new_tail
    return x, out_caches
