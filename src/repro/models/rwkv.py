"""RWKV6 "Finch" — attention-free time-mix with data-dependent decay.

Faithful block structure (arXiv:2404.05892):
  time-mix : token-shift ddlerp (low-rank data-dependent interpolation) into
             r/k/v/g/w projections; per-channel, per-token decay
             w_t = exp(-exp(w0 + lora_w(x_w))) — the Finch contribution —
             and bonus u for the current token; wkv linear recurrence
             (models/scan_ops chunked form; kernels/linear_scan on TPU);
             per-head group-norm, silu(g) gate, output projection.
  channel-mix : token-shift squared-relu MLP with receptance gate.

State per layer for decode: shift_tm (B,d), shift_cm (B,d), wkv (B,H,hd,hd).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, scan_ops
from repro.models.layers import dense_init, matmul

_MIX_NAMES = ("w", "k", "v", "r", "g")


def init_rwkv_block(key, cfg):
    d = cfg.d_model
    hd = cfg.ssm_head_dim
    h = d // hd
    lora = cfg.rwkv_lora_dim
    dt = layers.dtype_of(cfg)
    ks = jax.random.split(key, 14)
    p = {
        "ln_tm": layers.init_rmsnorm(d),
        "ln_cm": layers.init_rmsnorm(d),
        # ddlerp mixing parameters
        "mu_x": jnp.zeros((d,), jnp.float32),
        "mu": jnp.zeros((len(_MIX_NAMES), d), jnp.float32),
        "maa_w1": dense_init(ks[0], d, len(_MIX_NAMES) * lora, dt),
        "maa_w2": (jax.random.normal(ks[1], (len(_MIX_NAMES), lora, d),
                                     jnp.float32) * 0.01).astype(dt),
        # projections
        "wr": dense_init(ks[2], d, d, dt),
        "wk": dense_init(ks[3], d, d, dt),
        "wv": dense_init(ks[4], d, d, dt),
        "wg": dense_init(ks[5], d, d, dt),
        "wo": dense_init(ks[6], d, d, dt),
        # data-dependent decay (lora dim 2x)
        "w0": jnp.zeros((d,), jnp.float32) - 6.0,
        "wd1": dense_init(ks[7], d, 2 * lora, dt),
        "wd2": (jax.random.normal(ks[8], (2 * lora, d), jnp.float32)
                * 0.01).astype(dt),
        "u": (jax.random.normal(ks[9], (h, hd), jnp.float32) * 0.1),
        "ln_x": layers.init_rmsnorm(d),   # per-head group norm (flattened)
        # channel mix
        "cm_mu_k": jnp.zeros((d,), jnp.float32),
        "cm_mu_r": jnp.zeros((d,), jnp.float32),
        "cm_wk": dense_init(ks[10], d, cfg.d_ff, dt),
        "cm_wv": dense_init(ks[11], cfg.d_ff, d, dt),
        "cm_wr": dense_init(ks[12], d, d, dt),
    }
    return p


def _shift(x, state):
    """Token shift: previous token's activation (state carries t = -1)."""
    prev = jnp.concatenate([state[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def _ddlerp(p, x, xx):
    """Data-dependent interpolation producing the 5 mixed inputs."""
    base = x + xx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(matmul(base, p["maa_w1"]).astype(jnp.float32))
    lora = lora.reshape(*lora.shape[:-1], len(_MIX_NAMES), -1)
    delta = jnp.einsum("...nl,nld->...nd", lora,
                       p["maa_w2"].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    mixed = []
    for i in range(len(_MIX_NAMES)):
        mu_i = p["mu"][i] + delta[..., i, :]
        mixed.append(x + xx * mu_i.astype(x.dtype))
    return mixed  # order: w, k, v, r, g


def time_mix(p, cfg, x, shift_state, wkv_state=None, chunk=64):
    """x: (B,S,d). Returns (y, new_shift_state, new_wkv_state)."""
    b, s, d = x.shape
    hd = cfg.ssm_head_dim
    h = d // hd
    prev = _shift(x, shift_state)
    xx = prev - x
    x_w, x_k, x_v, x_r, x_g = _ddlerp(p, x, xx)

    r = matmul(x_r, p["wr"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = matmul(x_k, p["wk"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = matmul(x_v, p["wv"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    g = jax.nn.silu(matmul(x_g, p["wg"]).astype(jnp.float32))

    dw = jnp.einsum("...l,ld->...d", jnp.tanh(
        matmul(x_w, p["wd1"]).astype(jnp.float32)),
        p["wd2"].astype(jnp.float32))
    logw = -jnp.exp(jnp.clip(p["w0"] + dw, -20.0, 8.0))   # <= 0
    w = jnp.exp(logw).reshape(b, s, h, hd).transpose(0, 2, 1, 3)

    u = p["u"]
    if s == 1 and wkv_state is not None:
        new_state, o = scan_ops.step(
            wkv_state, r[:, :, 0], k[:, :, 0], v[:, :, 0], w[:, :, 0], u)
        o = o[:, :, None, :]
    else:
        o, new_state = scan_ops.linear_scan_chunked(
            r, k, v, w, u, initial_state=wkv_state, chunk=chunk)
    # per-head group norm (RWKV's GroupNorm(n_heads)) — normalizes over hd
    # within each head, so it stays local under head-sharded TP.
    o = o.transpose(0, 2, 1, 3)                        # (b, s, h, hd)
    of = o.astype(jnp.float32)
    var = jnp.mean(of * of, axis=-1, keepdims=True)
    o = (of * jax.lax.rsqrt(var + cfg.norm_eps)
         * p["ln_x"]["scale"].reshape(h, hd)).reshape(b, s, d)
    y = matmul((o * g).astype(x.dtype), p["wo"])
    return y, x[:, -1, :], new_state


def channel_mix(p, cfg, x, shift_state):
    prev = _shift(x, shift_state)
    xx = prev - x
    xk = x + xx * p["cm_mu_k"].astype(x.dtype)
    xr = x + xx * p["cm_mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(matmul(xk, p["cm_wk"]).astype(jnp.float32)))
    vv = matmul(kk.astype(x.dtype), p["cm_wv"])
    rr = jax.nn.sigmoid(matmul(xr, p["cm_wr"]).astype(jnp.float32))
    return (rr * vv.astype(jnp.float32)).astype(x.dtype), x[:, -1, :]


def rwkv_block(p, cfg, x, state, chunk=64):
    """Full pre-norm RWKV6 block. state = dict(shift_tm, shift_cm, wkv)."""
    h_tm, new_shift_tm, new_wkv = time_mix(
        p, cfg, layers.rms_norm(p["ln_tm"], x, cfg.norm_eps),
        state["shift_tm"], state["wkv"], chunk=chunk)
    x = x + h_tm
    h_cm, new_shift_cm = channel_mix(
        p, cfg, layers.rms_norm(p["ln_cm"], x, cfg.norm_eps),
        state["shift_cm"])
    x = x + h_cm
    return x, {"shift_tm": new_shift_tm, "shift_cm": new_shift_cm,
               "wkv": new_wkv}


def init_rwkv_state(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.ssm_head_dim
    h = d // hd
    return {
        "shift_tm": jnp.zeros((batch, d), dtype),
        "shift_cm": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
    }
