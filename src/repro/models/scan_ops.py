"""Diagonal-decay linear recurrences — shared substrate for RWKV6 and Mamba2.

The recurrence (state S in R^{dk x dv} per head):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = q_t (S_{t-1} + diag(u) k_t^T v_t)      [RWKV6: current-token bonus u]
    o_t = q_t S_t                                 [Mamba2 / plain GLA: u = None]

with per-channel decays w_t in (0,1]^{dk} (Mamba2's scalar-per-head decay is
the broadcast special case). Three implementations:

  linear_scan_recurrent : exact jax.lax.scan over time — the oracle; also the
                          O(1)-state decode path (single-step form below).
  linear_scan_chunked   : GLA-style chunked parallel form — what training and
                          long-context prefill lower to; the jnp analogue of
                          kernels/linear_scan (Pallas/MXU is the TPU hot path).
  step                  : one decode step given carried state.

Shapes: q,k: (B, H, S, dk); v: (B, H, S, dv); w: (B, H, S, dk) in (0,1];
u: (H, dk) or None. Output: (B, H, S, dv); state: (B, H, dk, dv).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def linear_scan_recurrent(q, k, v, w, u=None, initial_state=None):
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    state0 = (jnp.zeros((b, h, dk, dv), jnp.float32)
              if initial_state is None else initial_state.astype(jnp.float32))

    def body(state, inp):
        qt, kt, vt, wt = inp  # (b,h,dk),(b,h,dk),(b,h,dv),(b,h,dk)
        kv = kt[..., :, None] * vt[..., None, :]           # (b,h,dk,dv)
        if u is not None:
            att = state + u[None, :, :, None] * kv
        else:
            att = state * wt[..., None] + kv               # post-update read
        out = jnp.einsum("bhk,bhkv->bhv", qt, att,
                         preferred_element_type=jnp.float32)
        new_state = state * wt[..., None] + kv
        return new_state, out

    xs = (q.transpose(2, 0, 1, 3).astype(jnp.float32),
          k.transpose(2, 0, 1, 3).astype(jnp.float32),
          v.transpose(2, 0, 1, 3).astype(jnp.float32),
          w.transpose(2, 0, 1, 3).astype(jnp.float32))
    state, outs = jax.lax.scan(body, state0, xs)
    return outs.transpose(1, 2, 0, 3).astype(v.dtype), state


def step(state, qt, kt, vt, wt, u=None):
    """Single decode step. state: (B,H,dk,dv); qt/kt/wt: (B,H,dk); vt: (B,H,dv)."""
    state = state.astype(jnp.float32)
    kv = kt[..., :, None] * vt[..., None, :]
    if u is not None:
        att = state + u[None, :, :, None] * kv
    else:
        att = state * wt[..., None] + kv
    out = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32),
                     att.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    new_state = state * wt[..., None] + kv
    return new_state, out.astype(vt.dtype)


@functools.partial(jax.jit, static_argnames=("chunk",))
def linear_scan_chunked(q, k, v, w, u=None, initial_state=None, chunk=64):
    """Chunked (GLA-style) parallel form — exact up to fp accumulation.

    Within a chunk of length c, with cumulative decay L_t = prod_{i<=t} w_i:
      intra: A[t,s] = (q_t . L_t) . (k_s / L_s) for s < t  (s = t uses bonus u
             or the undeycayed k_t when reading post-update)
      inter: o_t += (q_t . L_t) S_in;   S_out = diag(L_c) S_in + sum decayed kv
    Decay ratios are formed inside a chunk only (c = 64) which bounds the
    dynamic range; inputs are fp32 inside.
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    assert s % c == 0, "sequence must divide the chunk size"
    n = s // c
    f32 = jnp.float32
    qc = q.reshape(b, h, n, c, dk).astype(f32)
    kc = k.reshape(b, h, n, c, dk).astype(f32)
    vc = v.reshape(b, h, n, c, dv).astype(f32)
    wc = jnp.clip(w.reshape(b, h, n, c, dk).astype(f32), 1e-6, 1.0)

    logw = jnp.log(wc)
    clog = jnp.cumsum(logw, axis=-2)                      # L_t (log), incl. t
    L = jnp.exp(clog)                                     # (b,h,n,c,dk)
    L_total = jnp.exp(clog[..., -1, :])                   # (b,h,n,dk)

    # Read convention: post-update (Mamba2/GLA, u=None) reads S_t so the
    # strict-lower decay ratio is L_t/L_s; pre-update + bonus (RWKV6) reads
    # S_{t-1} so the ratio excludes w_t: L_{t-1}/L_s = exp(clog - logw)/L_s.
    q_tilde = qc * (L if u is None else jnp.exp(clog - logw))
    # k decayed forward to the chunk end: k_s * L_total / L_s
    k_hat = kc * jnp.exp(clog[..., -1:, :] - clog)
    k_div = kc * jnp.exp(-clog)                           # k_s / L_s
    attn = jnp.einsum("bhntk,bhnsk->bhnts", q_tilde, k_div,
                      preferred_element_type=f32)
    tri = jnp.tril(jnp.ones((c, c), f32), k=-1)           # strictly causal
    attn_strict = attn * tri
    if u is not None:
        diag_val = jnp.einsum("bhntk,hk,bhntk->bhnt", qc, u.astype(f32), kc,
                              preferred_element_type=f32)
    else:
        # post-update read: s = t term with no decay ratio = q_t . k_t
        diag_val = jnp.einsum("bhntk,bhntk->bhnt", qc, kc,
                              preferred_element_type=f32)
    o_intra = jnp.einsum("bhnts,bhnsv->bhntv", attn_strict, vc,
                         preferred_element_type=f32) \
        + diag_val[..., None] * vc

    # inter-chunk: carry state across chunks with a scan over n.
    kv_in = jnp.einsum("bhnsk,bhnsv->bhnkv", k_hat, vc,
                       preferred_element_type=f32)        # decayed to chunk end

    state0 = (jnp.zeros((b, h, dk, dv), f32)
              if initial_state is None else initial_state.astype(f32))

    def body(state, inp):
        qt, ltot, kv_c = inp  # (b,h,c,dk), (b,h,dk), (b,h,dk,dv)
        o_inter = jnp.einsum("bhtk,bhkv->bhtv", qt, state,
                             preferred_element_type=f32)
        new_state = state * ltot[..., None] + kv_c
        return new_state, o_inter

    xs = (q_tilde.transpose(2, 0, 1, 3, 4),
          L_total.transpose(2, 0, 1, 3),
          kv_in.transpose(2, 0, 1, 3, 4))
    state, o_inter = jax.lax.scan(body, state0, xs)
    o = o_intra + o_inter.transpose(1, 2, 0, 3, 4)
    return o.reshape(b, h, s, dv).astype(v.dtype), state
