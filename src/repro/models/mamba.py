"""Mamba2 (SSD) block — selective state space with scalar-per-head decay.

Block (arXiv:2405.21060, as used by Zamba2):
  in_proj -> [z | x | B | C | dt]     (d_inner, d_inner, n_g*N, n_g*N, H)
  causal depthwise conv (width 4) over [x|B|C]
  dt = softplus(dt + dt_bias);  a_t = exp(-exp(A_log) * dt)   (per head)
  SSD recurrence  h_t = a_t h_{t-1} + B_t^T (dt_t x_t);  y_t = C_t h_t + D x_t
    -> mapped onto scan_ops.linear_scan_chunked with q=C, k=B, v=dt*x and
       the scalar decay broadcast over the state dim (n_groups = 1).
  gate y * silu(z), RMSNorm, out_proj.

Decode state: conv tail (B, width-1, conv_ch) + ssm state (B, H, N, hd).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, scan_ops
from repro.models.layers import dense_init, matmul


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    hd = cfg.ssm_head_dim
    h = d_inner // hd
    n = cfg.ssm_state_dim
    conv_ch = d_inner + 2 * n           # x | B | C
    return d_inner, hd, h, n, conv_ch


def init_mamba_block(key, cfg):
    d = cfg.d_model
    d_inner, hd, h, n, conv_ch = _dims(cfg)
    dt = layers.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * n + h
    return {
        "ln": layers.init_rmsnorm(d),
        "in_proj": dense_init(ks[0], d, proj_out, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),   # A = -exp(a_log) = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": layers.init_rmsnorm(d_inner),
        "out_proj": dense_init(ks[2], d_inner, d, dt),
    }


def _causal_conv(x, w, b, tail=None):
    """Depthwise causal conv. x: (B,S,C), w: (W,C). tail: (B,W-1,C) or None.

    Returns (y, new_tail). Implemented as a sum of shifted scalings — width
    is 4, so this is 4 fused multiply-adds, no im2col.
    """
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    new_tail = xp[:, x.shape[1]:, :] if x.shape[1] < width - 1 else \
        xp[:, -(width - 1):, :]
    return jax.nn.silu((y + b).astype(jnp.float32)).astype(x.dtype), new_tail


def mamba_block(p, cfg, x, state, chunk=64):
    """x: (B,S,d); state = {conv: (B,W-1,C), ssm: (B,H,N,hd)} or zeros."""
    b, s, d = x.shape
    d_inner, hd, h, n, conv_ch = _dims(cfg)
    xn = layers.rms_norm(p["ln"], x, cfg.norm_eps)
    zxbcdt = matmul(xn, p["in_proj"])
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_ch]
    dt_raw = zxbcdt[..., -h:].astype(jnp.float32)

    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                 state["conv"])
    xs = xbc[..., :d_inner].reshape(b, s, h, hd)
    bb = xbc[..., d_inner:d_inner + n]                    # (B,S,N) group=1
    cc = xbc[..., d_inner + n:]

    dt_v = jax.nn.softplus(dt_raw + p["dt_bias"])          # (B,S,H)
    a = jnp.exp(-jnp.exp(p["a_log"])[None, None] * dt_v)   # (B,S,H) in (0,1)

    # map onto the generic diagonal-decay scan: heads axis first; B/C are
    # shared across heads (n_groups = 1) so they broadcast over H.
    q = jnp.broadcast_to(cc[:, None], (b, h, s, n))
    k = jnp.broadcast_to(bb[:, None], (b, h, s, n))
    v = (xs * dt_v[..., None]).transpose(0, 2, 1, 3)       # (B,H,S,hd)
    w = jnp.broadcast_to(
        a.transpose(0, 2, 1)[..., None], (b, h, s, n))     # scalar -> N

    if s == 1 and state["ssm"] is not None:
        new_ssm, o = scan_ops.step(
            state["ssm"], q[:, :, 0], k[:, :, 0], v[:, :, 0], w[:, :, 0])
        o = o[:, :, None, :]
    else:
        o, new_ssm = scan_ops.linear_scan_chunked(
            q, k, v, w, initial_state=state["ssm"], chunk=chunk)

    y = o.transpose(0, 2, 1, 3) + xs * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_inner)
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y = layers.rms_norm(p["out_norm"], y.astype(x.dtype), cfg.norm_eps)
    out = matmul(y, p["out_proj"])
    return x + out, {"conv": new_conv, "ssm": new_ssm}


def init_mamba_state(cfg, batch, dtype=jnp.float32):
    d_inner, hd, h, n, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, h, n, hd), jnp.float32),
    }
