"""Mixture-of-Experts: top-k routing, capacity-based sort dispatch, shared
experts. Covers deepseek-v2 (160 routed top-6 + 2 shared, softmax gates) and
llama4-maverick (128 routed top-1 + 1 shared, sigmoid gate).

Dispatch is the sort-based capacity scheme (GShard/MaxText style):
tokens -> argsort by expert id -> positions within expert -> scatter into an
(E, C, d) buffer -> batched per-expert SwiGLU -> gather/combine. FLOPs are
the *active* compute N·k·d·ff (plus router), not the dense N·E all-experts
product — this is what makes the 236B/400B configs trainable. With experts
sharded over the "model" mesh axis the scatter/gather pair lowers to an
all-to-all (token shuffle), the canonical EP pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import dense_init, matmul


def init_moe(key, cfg):
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    dt = layers.dtype_of(cfg)
    ks = jax.random.split(key, 5)
    # Expert weights carry a leading E axis (shardable over "model").
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": _stacked_init(ks[1], e, d, ff, dt),
        "w_up": _stacked_init(ks[2], e, d, ff, dt),
        "w_down": _stacked_init(ks[3], e, ff, d, dt),
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.init_mlp(
            ks[4], d, cfg.moe_d_ff * cfg.num_shared_experts, dt)
    return p


def _stacked_init(key, e, d_in, d_out, dt):
    keys = jax.random.split(key, e)
    return jax.vmap(
        lambda k: dense_init(k, d_in, d_out, dt))(keys)


def top_k_routing(router_logits, k, gate_fn="softmax"):
    """(N, E) logits -> (N, k) expert ids + normalized gates (fp32)."""
    logits = router_logits.astype(jnp.float32)
    gates_all = (jax.nn.softmax(logits, axis=-1) if gate_fn == "softmax"
                 else jax.nn.sigmoid(logits))
    gate_vals, expert_ids = jax.lax.top_k(gates_all, k)
    if gate_fn == "softmax" and k > 1:
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    return expert_ids, gate_vals, gates_all


def moe_apply(p, cfg, x, gate_fn="softmax"):
    """x: (B, S, d) -> (B, S, d), plus router aux loss (load balancing).

    Two paths:
      * pure-GSPMD dense path (CPU tests / no mesh): sort-based dispatch
        with global token indices. GSPMD cannot localize the combine
        scatter and emits a full (N*k, d) fp32 all-reduce per layer —
        measured at 2x128 GB/layer on deepseek-v2 (see EXPERIMENTS.md §Perf
        iteration 1) — so production meshes use:
      * shard_map EP path: activations are replicated across the "model"
        axis under TP, so every expert shard dispatches/combines its own
        experts LOCALLY; the only collective is one bf16 psum of the
        (N_local, d) partial outputs — the same all-reduce a dense TP MLP
        pays. Requires num_experts % model-axis == 0.
    """
    from repro.models import meshctx
    mesh = meshctx.current_mesh()
    if (cfg.shard_activations and mesh is not None
            and "model" in mesh.axis_names):
        m_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        if m_size > 1 and cfg.num_experts % m_size == 0:
            return _moe_apply_shardmap(p, cfg, x, gate_fn, mesh)
    return _moe_apply_dense(p, cfg, x, gate_fn)


def _moe_apply_dense(p, cfg, x, gate_fn="softmax"):
    b, s, d = x.shape
    n = b * s
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = int(cfg.capacity_factor * n * k / e)
    cap = max(8, min(cap, n))

    xt = x.reshape(n, d)
    router_logits = matmul(xt.astype(jnp.float32), p["router"])
    expert_ids, gate_vals, gates_all = top_k_routing(
        router_logits, k, gate_fn)

    # ---- sort-based dispatch -------------------------------------------
    flat_e = expert_ids.reshape(n * k)                  # (Nk,)
    flat_g = gate_vals.reshape(n * k)
    flat_tok = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    g_sorted = flat_g[order]
    # position within expert group = index - first index of the group
    group_start = jnp.searchsorted(e_sorted, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(n * k) - group_start[e_sorted]
    keep = pos_in_e < cap                                # drop overflow
    slot = jnp.where(keep, e_sorted * cap + pos_in_e, e * cap)  # overflow slot

    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(xt[tok_sorted], mode="drop")
    buf = buf[:-1].reshape(e, cap, d)

    # ---- batched per-expert SwiGLU --------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"],
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"],
                   preferred_element_type=jnp.float32).astype(x.dtype)

    # ---- combine ---------------------------------------------------------
    y_flat = y.reshape(e * cap, d)
    contrib = jnp.where(keep, g_sorted, 0.0)[:, None] * \
        y_flat[jnp.minimum(slot, e * cap - 1)].astype(jnp.float32)
    out = jnp.zeros((n, d), jnp.float32).at[tok_sorted].add(
        jnp.where(keep[:, None], contrib, 0.0))

    if cfg.num_shared_experts:
        out = out + layers.mlp(p["shared"], xt, cfg.act,
                               cfg).astype(jnp.float32)

    # Switch-style load-balancing aux loss.
    density = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32), axis=0)
    prob_mass = jnp.mean(gates_all, axis=0)
    aux = e * jnp.sum(density * prob_mass) * cfg.router_aux_coef
    return out.reshape(b, s, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Expert-parallel shard_map path (production meshes)
# ---------------------------------------------------------------------------

def _local_expert_ffn(x_loc, router, wg, wu, wd, *, cfg, gate_fn, e_total,
                      dp_axes):
    """Per-device body: dispatch MY experts locally, psum partial outputs.

    x_loc: (B_loc, S, d) — the device's data shard, replicated over "model".
    wg/wu/wd: (E_loc, ...) — this model-shard's experts (FSDP pre-gathered).
    """
    b_loc, s, d = x_loc.shape
    n = b_loc * s
    e_loc = wg.shape[0]
    k = cfg.num_experts_per_tok
    cap = max(8, min(int(cfg.capacity_factor * n * k / e_total), n))

    xt = x_loc.reshape(n, d)
    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), router,
                        preferred_element_type=jnp.float32)
    expert_ids, gate_vals, gates_all = top_k_routing(logits, k, gate_fn)

    my_first = jax.lax.axis_index("model") * e_loc
    flat_e = expert_ids.reshape(n * k)
    flat_g = gate_vals.reshape(n * k)
    flat_tok = jnp.repeat(jnp.arange(n), k)
    mine = (flat_e >= my_first) & (flat_e < my_first + e_loc)
    e_rel = jnp.where(mine, flat_e - my_first, e_loc)      # e_loc = discard

    order = jnp.argsort(e_rel, stable=True)
    e_sorted = e_rel[order]
    tok_sorted = flat_tok[order]
    g_sorted = flat_g[order]
    group_start = jnp.searchsorted(e_sorted, jnp.arange(e_loc + 1),
                                   side="left")
    pos_in_e = jnp.arange(n * k) - group_start[jnp.minimum(e_sorted, e_loc)]
    keep = (e_sorted < e_loc) & (pos_in_e < cap)
    slot = jnp.where(keep, e_sorted * cap + pos_in_e, e_loc * cap)

    buf = jnp.zeros((e_loc * cap + 1, d), x_loc.dtype)
    buf = buf.at[slot].set(xt[tok_sorted], mode="drop")
    buf = buf[:-1].reshape(e_loc, cap, d)

    g = jnp.einsum("ecd,edf->ecf", buf, wg,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", buf, wu,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x_loc.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, wd,
                   preferred_element_type=jnp.float32).astype(x_loc.dtype)

    y_flat = y.reshape(e_loc * cap, d)
    contrib = jnp.where(keep, g_sorted, 0.0)[:, None].astype(x_loc.dtype) \
        * y_flat[jnp.minimum(slot, e_loc * cap - 1)]
    partial = jnp.zeros((n, d), x_loc.dtype).at[tok_sorted].add(
        jnp.where(keep[:, None], contrib, jnp.zeros_like(contrib)))

    # THE collective: one bf16-width psum of the partial outputs.
    out = jax.lax.psum(partial, "model")

    # load-balance aux (Switch): local stats, pmean'd over the data axes
    # (identical across "model" by construction: x and router are
    # model-replicated, so every model shard routes identically).
    density = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], e_total, dtype=jnp.float32), axis=0)
    prob_mass = jnp.mean(gates_all, axis=0)
    aux = e_total * jnp.sum(density * prob_mass) * cfg.router_aux_coef
    aux = jax.lax.pmean(aux, dp_axes)
    return out.reshape(b_loc, s, d), aux


def _moe_apply_shardmap(p, cfg, x, gate_fn, mesh):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_entry = dp if len(dp) > 1 else dp[0]
    e_total = cfg.num_experts

    # FSDP pre-gather: force expert weights to model-sharded-only layout so
    # the shard_map body sees whole (E_loc, d, ff) experts.
    wg = jax.lax.with_sharding_constraint(
        p["w_gate"], P("model", None, None))
    wu = jax.lax.with_sharding_constraint(p["w_up"], P("model", None, None))
    wd = jax.lax.with_sharding_constraint(
        p["w_down"], P("model", None, None))
    router = jax.lax.with_sharding_constraint(p["router"], P(None, None))

    body = functools.partial(_local_expert_ffn, cfg=cfg, gate_fn=gate_fn,
                             e_total=e_total, dp_axes=dp)
    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_entry, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(dp_entry, None, None), P()),
        check_rep=False,
    )(x, router, wg, wu, wd)

    if cfg.num_shared_experts:
        out = out + layers.mlp(p["shared"], x, cfg.act, cfg)
    return out, aux
