"""Distributed training driver: pjit'd train_step with microbatching,
remat, ZeRO-1 optimizer sharding, and hierarchical/compressed gradient
reduction across pods.

`make_train_step(cfg, mesh, ...)` returns (step_fn, in_shardings,
out_shardings) ready for jax.jit — the dry-run lowers exactly this function;
examples/train_proxy.py executes it for real on a 1-device mesh.

Gradient flow at scale:
  * params are TP/EP-sharded ("model"), replicated over ("pod","data");
    pjit's partitioner emits the gradient all-reduce over the data axes.
  * with grad_accum > 1, the batch is split into microbatches consumed by a
    lax.scan — activation peak memory drops by the accumulation factor while
    the weight gradients stay resident (classic pipeline-free accumulation).
  * optional int8-compressed cross-pod reduction lives in
    optim/grad_compress.py and is applied by the fault-tolerant outer loop
    (launch/fault.py) when the mesh has a "pod" axis.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import sharding as shardlib
from repro.models import model as modellib
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    grad_accum: int = 1
    zero1: bool = True
    adamw: adamw.AdamWConfig = adamw.AdamWConfig()


def make_loss_fn(cfg):
    def loss(params, tokens, labels):
        total, (ce, aux) = modellib.loss_fn(params, cfg, tokens, labels)
        return total, {"ce": ce, "aux": aux}
    return loss


def make_train_step(cfg, options: TrainOptions = TrainOptions()):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    loss_fn = make_loss_fn(cfg)

    def train_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]

        if options.grad_accum > 1:
            mb_tok = tokens.reshape((options.grad_accum,
                                     tokens.shape[0] // options.grad_accum)
                                    + tokens.shape[1:])
            mb_lab = labels.reshape(mb_tok.shape[:2] + labels.shape[1:])

            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb[0], mb[1])
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss_sum), metrics = jax.lax.scan(
                micro, (g0, jnp.float32(0.0)), (mb_tok, mb_lab))
            grads = jax.tree.map(lambda g: g / options.grad_accum, grads)
            loss_val = loss_sum / options.grad_accum
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss_val, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, tokens, labels)

        params2, opt2, opt_metrics = adamw.apply(
            options.adamw, params, grads, opt_state)
        metrics = dict(metrics, loss=loss_val, **opt_metrics)
        return params2, opt2, metrics

    return train_step


def shardings_for_train(cfg, params, opt_state, mesh, batch_ndim=2,
                        zero1=True, fsdp=False, batch_size=None):
    """(in_shardings, out_shardings) for jax.jit over train_step."""
    strategy = cfg.train_parallelism
    pspecs = shardlib.param_specs(cfg, params, mesh, fsdp=fsdp,
                                  strategy=strategy)
    ospecs_tree = pspecs if strategy == "dp" else (
        shardlib.zero1_specs(cfg, params, mesh, fsdp=fsdp)
        if zero1 else pspecs)
    to_shard = functools.partial(jax.tree.map,
                                 lambda s: NamedSharding(mesh, s))
    p_shard = to_shard(pspecs)
    opt_shard = adamw.AdamWState(
        step=NamedSharding(mesh, P()),
        mu=to_shard(ospecs_tree), nu=to_shard(ospecs_tree))
    bspec = NamedSharding(mesh, shardlib.batch_spec(
        mesh, batch_ndim - 1, batch=batch_size,
        axes="all" if strategy == "dp" else "data"))
    batch_shard = {"tokens": bspec, "labels": bspec}
    metrics_shard = None  # replicated scalars
    return (p_shard, opt_shard, batch_shard), \
        (p_shard, opt_shard, metrics_shard)


def input_specs_train(cfg, shape):
    """ShapeDtypeStruct stand-ins for one global training batch."""
    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, s, cfg.num_codebooks) if cfg.num_codebooks > 1 else (b, s)
    return {
        "tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
        "labels": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
    }
