import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import — jax locks the
# device count at first initialization. Hence no `from __future__` here.

DOC = """Multi-pod dry-run: prove the distribution config is coherent for every
(architecture x input shape) cell on the production meshes.

For each cell this driver:
  1. lowers + compiles the full config (scan-over-layers keeps the HLO depth-
     independent) on the single-pod (16,16) mesh AND the 2-pod (2,16,16)
     mesh — success proves the shardings, the collectives, and (via
     memory_analysis) that the per-device buffers fit;
  2. compiles width-preserved reduced-depth variants (1 and 2 repeating
     units) whose cost_analysis difference gives exact per-unit HLO FLOPs /
     bytes / collective-bytes — XLA's cost model does NOT multiply while-
     loop bodies by trip count, so the full-graph numbers must be
     reconstructed as  F(total) = F(L1) + (units_total - units_L1) * dF;
  3. parses collective operations (all-gather / all-reduce / reduce-scatter /
     all-to-all / collective-permute) with result byte-sizes and replica
     group sizes out of the compiled HLO;
  4. appends everything to results/dryrun.json (incremental — safe to
     restart; finished cells are skipped).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch ID] [--shape NAME]
      [--mesh single|multi|both] [--out results/dryrun.json] [--skip-full]
"""

import argparse
import functools
import json
import pathlib
import re
import time

import jax

from repro.configs import (ARCH_IDS, SHAPES, get_config, shape_applicable)
from repro.launch import serve as servelib
from repro.launch import train as trainlib
from repro.launch.mesh import make_production_mesh
from repro.models import model as modellib
from repro.models.meshctx import mesh_context
from repro.optim import adamw

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]))\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64)"
                       r"\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def parse_collectives(hlo_text):
    """Sum result bytes per collective kind, bucketed by replica-group size."""
    out = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shapes_blob, kind = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes_blob):
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        g = _GROUP_RE.search(line)
        gsize = int(g.group(2)) if g else 0
        key = f"{kind}/g{gsize}"
        ent = out.setdefault(key, {"count": 0, "bytes": 0})
        ent["count"] += 1
        ent["bytes"] += nbytes
    return out


def reduced_config(cfg, units):
    """Width-preserved config with `units` repeating units, layers unrolled
    so cost_analysis sees every layer (see DESIGN.md §Roofline method)."""
    import dataclasses
    cfg = dataclasses.replace(cfg, unroll_layers=True, remat="none")
    if cfg.block == "mamba" and cfg.shared_attn_every:
        return dataclasses.replace(
            cfg, num_layers=units * cfg.shared_attn_every)
    if cfg.moe and cfg.moe_layer_step > 1:
        return dataclasses.replace(
            cfg, num_layers=units * cfg.moe_layer_step)
    if cfg.moe and cfg.first_k_dense:
        return dataclasses.replace(
            cfg, num_layers=cfg.first_k_dense + units)
    return dataclasses.replace(cfg, num_layers=units)


def unit_counts(cfg):
    """(units_total, units_in_reduced_1) for the extrapolation formula."""
    if cfg.block == "mamba" and cfg.shared_attn_every:
        return cfg.num_layers / cfg.shared_attn_every, 1
    if cfg.moe and cfg.moe_layer_step > 1:
        return cfg.num_layers // cfg.moe_layer_step, 1
    if cfg.moe and cfg.first_k_dense:
        return cfg.num_layers - cfg.first_k_dense, 1
    return cfg.num_layers, 1


def _cost_dict(compiled):
    ca = compiled.cost_analysis()
    if not isinstance(ca, dict):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def _mem_dict(compiled):
    ma = compiled.memory_analysis()
    return {k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")}


def _fsdp_needed(cfg, mesh):
    """TP-16 alone must leave headroom on 16 GB HBM; otherwise FSDP."""
    from repro.models.model import count_params_analytic
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    return count_params_analytic(cfg) * 2 / tp > 12e9


def lower_cell(cfg, shape, mesh, donate=True, grad_accum=8):
    """Build and lower the step function for one cell. Returns `lowered`."""
    import dataclasses as _dc
    if shape.kind == "train" and cfg.train_parallelism == "dp":
        # pure-DP training: the model axis carries batch — model-axis
        # activation constraints (vocab sharding, CP attention) must be off
        cfg = _dc.replace(cfg, shard_activations=False)
    params = jax.eval_shape(functools.partial(modellib.init, cfg=cfg),
                            jax.random.PRNGKey(0))
    fsdp = _fsdp_needed(cfg, mesh)
    if shape.kind == "train":
        if cfg.train_parallelism == "dp":
            grad_accum = 1   # batch already spread over every device
        step = trainlib.make_train_step(
            cfg, trainlib.TrainOptions(grad_accum=grad_accum))
        opt = jax.eval_shape(adamw.init, params)
        batch = trainlib.input_specs_train(cfg, shape)
        in_sh, out_sh = trainlib.shardings_for_train(
            cfg, params, opt, mesh, fsdp=fsdp,
            batch_size=shape.global_batch)
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1) if donate else ())
        return fn.lower(params, opt, batch)
    if shape.kind == "prefill":
        step = servelib.make_serve_prefill(cfg)
        batch = servelib.input_specs_prefill(cfg, shape)
        in_sh, out_sh = servelib.shardings_for_serve(cfg, params, mesh,
                                                     shape, "prefill",
                                                     fsdp=fsdp)
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        return fn.lower(params, batch)
    # decode
    step = servelib.make_serve_decode(cfg)
    batch = servelib.input_specs_decode(cfg, shape)
    caches = servelib.cache_specs_struct(cfg, shape)
    in_sh, out_sh = servelib.shardings_for_serve(cfg, params, mesh, shape,
                                                 "decode", fsdp=fsdp)
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(2,) if donate else ())
    return fn.lower(params, batch, caches)


def run_cell(arch, shape, mesh, mesh_name, *, skip_full=False,
             with_reduced=True):
    import dataclasses
    cfg = dataclasses.replace(get_config(arch), shard_activations=True)
    rec = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
           "kind": shape.kind, "ok": False}
    t0 = time.time()
    try:
        if not skip_full:
            with mesh_context(mesh):
                lowered = lower_cell(cfg, shape, mesh)
                compiled = lowered.compile()
            rec["memory"] = _mem_dict(compiled)
            rec["full_cost_raw"] = _cost_dict(compiled)
            del lowered, compiled

        if with_reduced:
            units_total, u1 = unit_counts(cfg)
            c1 = reduced_config(cfg, 1)
            c2 = reduced_config(cfg, 2)
            costs, colls = [], []
            for c in (c1, c2):
                with mesh_context(mesh):
                    # accum=1: microbatch scan bodies are cost-counted once
                    # by XLA, so probes must run the whole batch in one shot
                    lo = lower_cell(c, shape, mesh, donate=False,
                                    grad_accum=1)
                    comp = lo.compile()
                costs.append(_cost_dict(comp))
                colls.append(parse_collectives(comp.as_text()))
                del lo, comp
            d_flops = costs[1]["flops"] - costs[0]["flops"]
            d_bytes = costs[1]["bytes"] - costs[0]["bytes"]
            extra_units = units_total - u1
            rec["hlo_flops_per_device"] = costs[0]["flops"] \
                + extra_units * d_flops
            rec["hlo_bytes_per_device"] = costs[0]["bytes"] \
                + extra_units * d_bytes
            rec["unit_costs"] = {"c1": costs[0], "c2": costs[1],
                                 "units_total": units_total}
            # collective bytes: per-kind extrapolation
            coll_total = {}
            keys = set(colls[0]) | set(colls[1])
            for k in keys:
                b1 = colls[0].get(k, {"bytes": 0})["bytes"]
                b2 = colls[1].get(k, {"bytes": 0})["bytes"]
                n1 = colls[0].get(k, {"count": 0})["count"]
                n2 = colls[1].get(k, {"count": 0})["count"]
                coll_total[k] = {
                    "bytes": b1 + extra_units * (b2 - b1),
                    "count": n1 + extra_units * (n2 - n1),
                }
            rec["collectives"] = coll_total
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["error"] = f"{type(e).__name__}: {str(e)[:500]}"
    rec["elapsed_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--skip-full", action="store_true",
                    help="only reduced-depth roofline compiles")
    ap.add_argument("--no-reduced", action="store_true")
    ap.add_argument("--redo-reduced", action="store_true",
                    help="refresh the reduced-depth cost probes of finished "
                         "cells, keeping their memory-fit records")
    args = ap.parse_args()

    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = []
    done = set()
    if out_path.exists():
        results = json.loads(out_path.read_text())
        done = {(r["arch"], r["shape"], r["mesh"]) for r in results
                if r.get("ok")}

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [s for s in SHAPES if args.shape is None or
              s.name == args.shape]

    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            ok, why = shape_applicable(cfg, shape)
            for mesh_name, mesh in meshes:
                key = (arch, shape.name, mesh_name)
                if key in done and not args.redo_reduced:
                    continue
                if key in done and args.redo_reduced:
                    old = next(r for r in results
                               if (r["arch"], r["shape"], r["mesh"]) == key)
                    if old.get("skipped") or not mesh_name.startswith(
                            "single") or "unit_costs" not in old:
                        continue
                    print(f"[dryrun:redo] {arch} x {shape.name}", flush=True)
                    rec = run_cell(arch, shape, mesh, mesh_name,
                                   skip_full=True, with_reduced=True)
                    rec["memory"] = old.get("memory")
                    rec["full_cost_raw"] = old.get("full_cost_raw")
                    results = [r for r in results
                               if (r["arch"], r["shape"], r["mesh"]) != key]
                    results.append(rec)
                    out_path.write_text(json.dumps(results, indent=1))
                    continue
                if not ok:
                    rec = {"arch": arch, "shape": shape.name,
                           "mesh": mesh_name, "ok": True, "skipped": why}
                else:
                    print(f"[dryrun] {arch} x {shape.name} x {mesh_name}",
                          flush=True)
                    rec = run_cell(
                        arch, shape, mesh, mesh_name,
                        skip_full=args.skip_full,
                        with_reduced=(not args.no_reduced
                                      and mesh_name.startswith("single")))
                    status = "OK" if rec["ok"] else \
                        f"FAIL {rec.get('error', '')[:120]}"
                    print(f"    -> {status} ({rec.get('elapsed_s', 0)}s)",
                          flush=True)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                out_path.write_text(json.dumps(results, indent=1))
    n_ok = sum(r["ok"] for r in results)
    print(f"[dryrun] {n_ok}/{len(results)} cells OK -> {out_path}")


if __name__ == "__main__":
    main()
