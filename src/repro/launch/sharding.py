"""Parameter / activation PartitionSpecs — the TP/EP/DP layout rules.

`param_specs(cfg, params, mesh)` walks the parameter pytree and assigns a
PartitionSpec by (path, shape) pattern — the MaxText "logical axis rules"
approach, collapsed to the patterns this model zoo actually produces:

  embedding table (V, d)           -> vocab-sharded  ("model", None)
  column-parallel producers        -> last dim "model"   (wq/wk/wv/w_gate/...)
  row-parallel consumers           -> first matrix dim "model" (wo/w_down/...)
  MoE expert stacks (E, d, ff)     -> expert-parallel: E over "model"
  MLA latent down-projections      -> replicated (tiny, avoids resharding)
  norms / biases-1D / scalars      -> replicated

Every rule is divisibility-checked against the mesh (jax rejects uneven
explicit shardings); non-divisible dims fall back to replication on that
dim. Data parallelism is expressed on the batch dim of inputs; gradients
reduce over ("pod","data") via pjit's partitioner.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes

# leaf names (last path component) -> role
_COLUMN = {"wq", "wk", "wv", "wg", "wr", "w_gate", "w_up", "cm_wk", "cm_wr",
           "w_uq", "w_uk", "w_uv", "maa_w1", "wd1"}
_ROW = {"wo", "w_down", "cm_wv", "out_proj", "w"}
_REPLICATED = {"router", "w_dq", "w_dkv", "in_proj", "conv_w", "conv_b",
               "maa_w2", "wd2"}
_BIAS_MODEL = {"bq", "bk", "bv"}


def _path_names(path):
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(p.key)
        elif hasattr(p, "name"):
            out.append(p.name)
    return out


def _check(spec, shape, mesh):
    """Drop mesh axes that do not divide the corresponding dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fixed = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            fixed.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= sizes[a]
        fixed.append(entry if dim % total == 0 else None)
    return P(*fixed)


def _leaf_spec(names, shape, cfg):
    """PartitionSpec pattern for one leaf. Leading stack axes (scan-layer,
    expert, codebook, superblock) are recognized by rank surplus."""
    name = names[-1]
    rank = len(shape)

    if name == "table":                       # embedding (maybe (K,) V, d)
        base = ("model", None)
        lead = rank - 2
        return P(*([None] * lead + list(base)))
    if name == "scale" or rank <= 1:
        if name in _BIAS_MODEL and rank >= 1:
            return P(*([None] * (rank - 1) + ["model"]))
        return P(*([None] * rank))
    if name in _BIAS_MODEL:
        return P(*([None] * (rank - 1) + ["model"]))
    if "moe" in names and name in ("w_gate", "w_up", "w_down"):
        # expert stack: (L?, E, d, ff) -> EP on E
        lead = rank - 3
        return P(*([None] * lead + ["model", None, None]))
    if name == "w" and "head" in names:       # LM head (maybe (K,) d, V)
        lead = rank - 2
        return P(*([None] * lead + [None, "model"]))
    if name in _COLUMN:
        return P(*([None] * (rank - 1) + ["model"]))
    if name in _ROW:
        return P(*([None] * (rank - 2) + ["model", None]))
    if name in _REPLICATED:
        return P(*([None] * rank))
    return P(*([None] * rank))


_FSDP_MIN_ELEMS = 1 << 22  # 4M — don't bother FSDP-sharding small leaves


def param_specs(cfg, params, mesh, fsdp=False, strategy="tp"):
    """TP/EP specs; with fsdp=True additionally shard big leaves over the
    data axes (ZeRO-3 / FSDP — GSPMD all-gathers each layer's weights at
    use inside the scan). Required for the 236B/400B configs: TP-16 alone
    leaves ~29 GB of bf16 params per device.

    strategy="dp": pure ZeRO-3 — no "model"-axis tensor parallelism at all;
    every big leaf is sharded over ALL mesh axes on its largest divisible
    dim and gathered at use. Right for small / attention-free archs whose
    activation TP would pay tens of full-activation collectives per layer
    (§Perf iteration 3)."""
    dp = data_axes(mesh)
    lead = dp if len(dp) > 1 else dp[0]
    all_axes = tuple(mesh.axis_names)

    def assign(path, leaf):
        names = _path_names(path)
        if strategy == "dp":
            if leaf.ndim < 1 or leaf.size < (1 << 16):
                return P(*([None] * leaf.ndim))
            for _, i in sorted(((leaf.shape[i], i)
                                for i in range(leaf.ndim)), reverse=True):
                trial = [None] * leaf.ndim
                trial[i] = all_axes
                fixed = _check(P(*trial), leaf.shape, mesh)
                if fixed[i] is not None:
                    return fixed
            return P(*([None] * leaf.ndim))
        spec = _check(_leaf_spec(names, leaf.shape, cfg), leaf.shape, mesh)
        if fsdp and leaf.ndim >= 2 and leaf.size >= _FSDP_MIN_ELEMS:
            entries = list(spec)
            cand = [(leaf.shape[i], i) for i, e in enumerate(entries)
                    if e is None]
            for _, i in sorted(cand, reverse=True):
                trial = list(entries)
                trial[i] = lead
                fixed = _check(P(*trial), leaf.shape, mesh)
                if fixed[i] is not None:
                    return fixed
        return spec

    return jax.tree_util.tree_map_with_path(assign, params)


def param_shardings(cfg, params, mesh, fsdp=False):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, params, mesh, fsdp=fsdp))


def zero1_specs(cfg, params, mesh, fsdp=False):
    """Optimizer-state specs: param spec + data-axis sharding on the largest
    dim not already sharded (ZeRO-1). Falls back to the param spec."""
    dp = data_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_total = 1
    for a in dp:
        dp_total *= sizes[a]

    def extend(spec, leaf):
        if leaf.ndim < 2:
            return spec
        entries = list(spec)
        used = set()
        for e in entries:
            if e is None:
                continue
            used.update(e if isinstance(e, tuple) else (e,))
        if used & set(dp):       # already data-sharded (FSDP) — nothing to add
            return spec
        # largest unsharded dim divisible by the dp size
        cand = [(leaf.shape[i], i) for i, e in enumerate(entries)
                if e is None and leaf.shape[i] % dp_total == 0]
        if not cand:
            return spec
        _, i = max(cand)
        entries[i] = dp if len(dp) > 1 else dp[0]
        return P(*entries)

    return jax.tree.map(extend, param_specs(cfg, params, mesh, fsdp=fsdp),
                        params)


# ---------------------------------------------------------------------------
# Input / activation / cache specs
# ---------------------------------------------------------------------------

def batch_spec(mesh, extra_dims=1, batch=None, axes="data"):
    """(B, ...) sharded over the data axes (or ALL axes for the pure-DP
    training strategy). With `batch` given, cascades to smaller axis sets
    when B doesn't divide (long_500k's global_batch=1 ends replicated)."""
    dp = data_axes(mesh)
    candidates = []
    if axes == "all":
        candidates.append(tuple(mesh.axis_names))
    candidates.append(dp if len(dp) > 1 else dp[0])
    if len(dp) > 1:
        candidates.append(dp[-1])
    for lead in candidates:
        spec = P(*([lead] + [None] * extra_dims))
        if batch is None:
            return spec
        fixed = _check(spec, (batch,) + (1,) * extra_dims, mesh)
        if fixed[0] is not None:
            return fixed
    return P(*([None] * (1 + extra_dims)))


def cache_specs(cfg, caches, mesh, batch):
    """Decode caches: batch-sharded over data axes; KV-head dim over 'model'
    when divisible (GQA kv >= 16) else replicated on that dim."""
    dp = data_axes(mesh)
    lead = dp if len(dp) > 1 else dp[0]

    def assign(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        rank = len(shape)
        name = names[-1]
        if name in ("k", "v") and rank >= 4:        # (L?, B, S, KV, hd)
            lead_n = rank - 4
            spec = _check(P(*([None] * lead_n + [lead, None, "model", None])),
                          shape, mesh)
            if spec[lead_n + 2] is None:
                # KV heads don't divide the model axis (GQA kv < 16):
                # flash-decode layout — shard the cache *sequence* instead;
                # the partial-softmax combine is GSPMD's to emit.
                spec = _check(
                    P(*([None] * lead_n + [lead, "model", None, None])),
                    shape, mesh)
            return spec
        if name in ("c", "k_rope") and rank >= 3:   # MLA latent (L?, B, S, r)
            lead_n = rank - 3
            return _check(P(*([None] * lead_n + [lead, "model", None])),
                          shape, mesh)
        if name in ("wkv", "ssm") and rank >= 4:    # (L?, B, H, dk, dv)
            lead_n = rank - 4
            spec = [None] * lead_n + [lead, "model", None, None]
            return _check(P(*spec), shape, mesh)
        # shift / conv states (L?, B, ...): shard the first dim whose extent
        # equals the batch size (stack prefixes are layer counts).
        spec = [None] * rank
        for i, d in enumerate(shape):
            if d == batch:
                cand = _check(
                    P(*([None] * i + [lead] + [None] * (rank - i - 1))),
                    shape, mesh)
                if cand[i] is not None:
                    spec = list(cand)
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(assign, caches)
