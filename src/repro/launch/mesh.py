"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device;
only launch/dryrun.py forces the 512-device host platform.

Topology: TPU v5e pods of 256 chips in a 16x16 ICI torus. Single-pod mesh
(16, 16) = ("data", "model"); multi-pod (2, 16, 16) = ("pod", "data",
"model") where the "pod" axis crosses the slower DCN links (gradient
all-reduce over it is hierarchical + optionally int8-compressed, see
optim/grad_compress.py).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 explicit-sharding API; absent on older installs
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many devices exist (tests / CPU)."""
    return _make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"


def axis_size(mesh, name) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]
