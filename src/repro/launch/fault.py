"""Fault-tolerant training loop: checkpoint/restart, heartbeats, straggler
mitigation, elastic scaling.

This is the single-controller outer loop a production deployment wraps
around the pjit'd train_step. The distributed-systems mechanics that need a
real fleet (process liveness, pod re-provisioning) are expressed as explicit
hooks with in-process reference implementations, so the policy logic — what
to do on a miss — is real, tested code:

  * HeartbeatMonitor  — workers report per-step latencies; the monitor flags
    stragglers by robust z-score (median + k*MAD) and missing heartbeats by
    deadline. On a real fleet the transport is the coordination service; the
    detection policy is identical.
  * TrainLoop         — drives step -> heartbeat -> periodic async checkpoint;
    on RestartRequired (preemption / flagged worker) it restores the last
    durable checkpoint, possibly onto a *different mesh* (elastic), and
    replays the deterministic data stream from the restored step.
  * Elastic rescale   — checkpoints store logical specs (ckpt/checkpoint.py),
    so restore(mesh') reshards; batch is re-split across the new data axis.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.ckpt.checkpoint import CheckpointManager


class RestartRequired(RuntimeError):
    """Raised when the fleet must roll back to the last checkpoint."""


@dataclasses.dataclass
class HeartbeatConfig:
    deadline_s: float = 300.0      # missing heartbeat => dead worker
    straggler_mad_k: float = 5.0   # flag if latency > median + k * MAD
    min_history: int = 8


class HeartbeatMonitor:
    def __init__(self, num_workers: int, cfg: HeartbeatConfig = HeartbeatConfig()):
        self.cfg = cfg
        self.last_seen = {w: time.monotonic() for w in range(num_workers)}
        self.latency_hist: dict[int, list] = {w: [] for w in range(num_workers)}

    def report(self, worker: int, step_latency_s: float,
               now: Optional[float] = None):
        self.last_seen[worker] = time.monotonic() if now is None else now
        h = self.latency_hist[worker]
        h.append(step_latency_s)
        if len(h) > 64:
            del h[:-64]

    def dead_workers(self, now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        return [w for w, t in self.last_seen.items()
                if now - t > self.cfg.deadline_s]

    def stragglers(self):
        """Robust z-score across workers on their median recent latency."""
        meds = {w: float(np.median(h)) for w, h in self.latency_hist.items()
                if len(h) >= self.cfg.min_history}
        if len(meds) < 2:
            return []
        vals = np.asarray(list(meds.values()))
        med = np.median(vals)
        mad = np.median(np.abs(vals - med)) + 1e-9
        return [w for w, v in meds.items()
                if v > med + self.cfg.straggler_mad_k * mad]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    max_restarts: int = 10


class TrainLoop:
    """Restartable training driver (see examples/train_proxy.py for use)."""

    def __init__(self, step_fn: Callable, source, ckpt: CheckpointManager,
                 cfg: LoopConfig, monitor: Optional[HeartbeatMonitor] = None,
                 on_step: Optional[Callable] = None):
        self.step_fn = step_fn
        self.source = source
        self.ckpt = ckpt
        self.cfg = cfg
        self.monitor = monitor or HeartbeatMonitor(1)
        self.on_step = on_step
        self.restarts = 0

    def run(self, params, opt_state, start_step: int = 0):
        step = start_step
        while step < self.cfg.total_steps:
            try:
                params, opt_state, step = self._run_span(params, opt_state,
                                                         step)
            except RestartRequired:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                params, opt_state, step, _ = self.ckpt.restore()
                # deterministic source: no iterator state to rebuild
        return params, opt_state, step

    def _run_span(self, params, opt_state, step):
        for batch in self.source.iter_from(step):
            t0 = time.monotonic()
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            self.monitor.report(0, time.monotonic() - t0)
            step += 1
            if self.on_step:
                self.on_step(step, metrics)
            if step % self.cfg.ckpt_every == 0 or \
                    step >= self.cfg.total_steps:
                self.ckpt.save_async(step, params, opt_state)
            if self.monitor.dead_workers():
                raise RestartRequired("heartbeat deadline missed")
            if step >= self.cfg.total_steps:
                break
        return params, opt_state, step
