"""Distributed serving: batched proxy scoring (prefill) and decode steps.

The SUPG pipeline's proxy plane: `serve_prefill` maps a batch of records
(token streams) to proxy scores A(x) in [0,1]; `serve_decode` advances one
token against KV/state caches (the decode_32k / long_500k shapes). Both are
pure functions lowered by the dry-run and executed by
examples/selection_service.py on small configs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.launch import sharding as shardlib
from repro.models import model as modellib


def make_serve_prefill(cfg, target_token=1):
    def serve_prefill(params, batch):
        return modellib.proxy_scores(params, cfg, batch["tokens"],
                                     target_token)
    return serve_prefill


def make_serve_decode(cfg):
    def serve_decode(params, batch, caches):
        logits, new_caches = modellib.apply_decode(
            params, cfg, batch["tokens"], caches, batch["pos"])
        return logits, new_caches
    return serve_decode


def input_specs_prefill(cfg, shape):
    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, s, cfg.num_codebooks) if cfg.num_codebooks > 1 else (b, s)
    return {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}


def input_specs_decode(cfg, shape):
    b = shape.global_batch
    tok_shape = (b, 1, cfg.num_codebooks) if cfg.num_codebooks > 1 else (b, 1)
    return {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
            "pos": jax.ShapeDtypeStruct((b,), jnp.int32)}


def cache_specs_struct(cfg, shape, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree for decode caches (no allocation)."""
    caches = jax.eval_shape(
        lambda: modellib.init_caches(cfg, shape.global_batch, shape.seq_len,
                                     dtype))
    return caches


def shardings_for_serve(cfg, params, mesh, shape, kind, dtype=jnp.bfloat16,
                        fsdp=False):
    pspecs = shardlib.param_shardings(cfg, params, mesh, fsdp=fsdp)
    b = shape.global_batch
    extra = 2 if cfg.num_codebooks > 1 else 1
    bspec = NamedSharding(mesh, shardlib.batch_spec(mesh, extra, batch=b))
    if kind == "prefill":
        batch_shard = {"tokens": bspec}
        return (pspecs, batch_shard), None
    cache_struct = cache_specs_struct(cfg, shape, dtype)
    cspecs = shardlib.cache_specs(cfg, cache_struct, mesh,
                                  shape.global_batch)
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs)
    batch_shard = {"tokens": bspec,
                   "pos": NamedSharding(mesh,
                                        shardlib.batch_spec(mesh, 0, batch=b))}
    return (pspecs, batch_shard, c_shard), (None, c_shard)
