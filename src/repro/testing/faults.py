"""Deterministic fault injection for oracle channels.

`FaultInjector` wraps any ``indices -> labels`` callable and misbehaves
on a *schedule*: a plain mapping from underlying-call index to fault
kind. No wall clock, no global randomness — the schedule is data, so a
faulty run replays bit-for-bit and a test can assert exactly which
calls failed. `fault_schedule` builds one from a seed (its own
`numpy` Generator, never the global RNG).

Fault kinds (the failure shapes a real remote oracle exhibits):

``transient``  raise `OracleTransientError` (a 5xx / dropped connection)
``fatal``      raise `OracleFatalError` (a permanent rejection)
``latency``    answer correctly, but only after ``spike_s`` on the
               injectable sleep — trips a channel's per-call watchdog
``torn``       return one label too few (a truncated response body)
``dup``        return one label too many (a duplicated tail record)
``nan``        right length, but leading labels are NaN (corrupt data)

Every kind is either raised or *detectably* malformed: the channel's
validation (length + finiteness) must reject ``torn``/``dup``/``nan``
before caching, so no fault can silently corrupt a label. Faults spend
a schedule slot even when they raise — the retry is the *next* call
index, which the schedule may fault again.

>>> import numpy as np
>>> from repro.core.oracle import array_oracle
>>> inj = FaultInjector(array_oracle(np.arange(8.0)),
...                     {0: "transient", 2: "torn"})
>>> try:
...     inj([1, 2])
... except Exception as e:
...     print(type(e).__name__)
OracleTransientError
>>> [float(v) for v in inj([1, 2])]     # call 1: clean
[1.0, 2.0]
>>> len(inj([1, 2, 3]))                 # call 2: torn — one label short
2
>>> inj.calls, dict(inj.injected)
(3, {'transient': 1, 'torn': 1})
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Dict, Mapping, Sequence

import numpy as np

from repro.core.resilience import OracleFatalError, OracleTransientError

KINDS = ("transient", "fatal", "latency", "torn", "dup", "nan")


def fault_schedule(seed: int, n_calls: int, rate: float,
                   kinds: Sequence[str] = ("transient",)) -> Dict[int, str]:
    """Seeded Bernoulli schedule: each of the first `n_calls` underlying
    calls faults with probability `rate`, drawing its kind uniformly
    from `kinds`. Pure function of the arguments (own Generator, no
    global RNG), so tests and benches share reproducible chaos."""
    for k in kinds:
        if k not in KINDS:
            raise ValueError(f"unknown fault kind {k!r} (choose from {KINDS})")
    rng = np.random.default_rng(seed)
    out: Dict[int, str] = {}
    for i in range(int(n_calls)):
        if rng.random() < rate:
            out[i] = kinds[int(rng.integers(len(kinds)))]
    return out


class FaultInjector:
    """Schedule-driven unreliable wrapper around an ``indices -> labels``
    callable (see the module docstring for the fault kinds).

    Thread-safe: the call counter and injection log update under a lock,
    so a channel's drain thread and a watchdog's sacrificial threads
    observe a consistent schedule. `calls` counts every invocation
    (faulted or not); `injected` tallies faults by kind.
    """

    def __init__(self, fn: Callable[[np.ndarray], np.ndarray],
                 schedule: Mapping[int, str], *,
                 spike_s: float = 0.0,
                 sleep: Callable[[float], None] = time.sleep):
        for i, k in dict(schedule).items():
            if k not in KINDS:
                raise ValueError(
                    f"unknown fault kind {k!r} at call {i} "
                    f"(choose from {KINDS})")
        self._fn = fn
        self.schedule = dict(schedule)
        self.spike_s = float(spike_s)
        self._sleep = sleep
        self._lock = threading.Lock()
        self.calls = 0
        self.injected: collections.Counter = collections.Counter()

    def __call__(self, indices) -> np.ndarray:
        """Label `indices` — or misbehave, if this call is scheduled to."""
        with self._lock:
            i = self.calls
            self.calls += 1
            kind = self.schedule.get(i)
            if kind is not None:
                self.injected[kind] += 1
        if kind is None:
            return self._fn(indices)
        if kind == "transient":
            raise OracleTransientError(
                f"injected transient fault (call {i})")
        if kind == "fatal":
            raise OracleFatalError(f"injected fatal fault (call {i})")
        if kind == "latency":
            self._sleep(self.spike_s)
            return self._fn(indices)
        labels = np.asarray(self._fn(indices), np.float32).reshape(-1)
        if kind == "torn":
            return labels[:-1]
        if kind == "dup":
            return np.concatenate([labels, labels[-1:]])
        # kind == "nan": right length, corrupt leading values
        out = labels.copy()
        out[:max(1, out.size // 8)] = np.nan
        return out
