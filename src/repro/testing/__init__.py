"""Test/benchmark harnesses that are product surface, not test code.

`repro.testing.faults` carries the deterministic `FaultInjector` used by
the resilience tests, the chaos acceptance tests, and the faulty-load
benchmark rows — anything that needs a reproducibly unreliable oracle.

`repro.testing.crash` carries `CrashInjector`, its sibling for the
durability plane: deterministic process death at named crashpoints.
"""
from repro.testing.crash import CrashInjector, SimulatedCrash, crash_schedule
from repro.testing.faults import FaultInjector, fault_schedule

__all__ = [
    "CrashInjector",
    "FaultInjector",
    "SimulatedCrash",
    "crash_schedule",
    "fault_schedule",
]
