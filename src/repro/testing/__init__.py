"""Test/benchmark harnesses that are product surface, not test code.

`repro.testing.faults` carries the deterministic `FaultInjector` used by
the resilience tests, the chaos acceptance tests, and the faulty-load
benchmark rows — anything that needs a reproducibly unreliable oracle.
"""
from repro.testing.faults import FaultInjector, fault_schedule

__all__ = ["FaultInjector", "fault_schedule"]
