"""`CrashInjector` — deterministically kill the process at a named instant.

Sibling of `repro.testing.faults.FaultInjector` (which makes the oracle
*channel* unreliable): this harness makes the *process* unreliable. The
durable layer announces crash-interesting instants by calling
``repro.durable.atomic.crashpoint("name")`` between a write and its
commit; a `CrashInjector` installs a process-global hook that raises
`SimulatedCrash` at a scheduled hit of a scheduled point.

Two properties make the simulation honest:

  * `SimulatedCrash` subclasses `BaseException`, so routine
    ``except Exception`` blocks cannot absorb it — it unwinds like a
    kill signal, not like an error.
  * The injector **latches**: once it has fired, *every* subsequent
    crashpoint also raises. A dead process does not keep committing;
    without the latch, a caller that caught the first crash could run
    the rest of its commit protocol and the test would prove nothing.

What a simulated crash models: all fsync'd bytes survive (they were
acknowledged to stable storage), and bytes merely written survive too —
the page cache outlives a process kill, matching a real `SIGKILL`
(only power failure loses un-fsync'd pages; that stricter model is out
of scope here). What it loses is everything in process memory.

>>> import os, tempfile
>>> from repro.durable import atomic
>>> path = os.path.join(tempfile.mkdtemp(), "state.json")
>>> atomic.atomic_write_json(path, {"epoch": 1})
>>> inj = CrashInjector({"pre_rename": 0})
>>> with inj:
...     try:
...         atomic.atomic_write_json(path, {"epoch": 2})
...     except SimulatedCrash:
...         pass
>>> (inj.fired, inj.fired_at)
(True, 'pre_rename')
>>> atomic.read_json(path)["epoch"]   # old file intact, no torn mix
1
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence

import numpy as np

from repro.durable import atomic


class SimulatedCrash(BaseException):
    """The process died here. `BaseException` so ``except Exception``
    recovery paths cannot accidentally survive their own death."""


def crash_schedule(seed: int,
                   points: Optional[Sequence[str]] = None,
                   max_hit: int = 3) -> Dict[str, int]:
    """Seeded schedule: pick one crashpoint and the hit index to kill at.

    Returns ``{point: hit_index}`` with a single entry — one process,
    one death. `points` defaults to every registered crashpoint;
    `hit_index` is uniform in ``[0, max_hit)`` so sweeps over seeds also
    cover "the Nth append dies", not just the first.

    >>> crash_schedule(0) == crash_schedule(0)
    True
    >>> (point, hit), = crash_schedule(7).items()
    >>> point in atomic.CRASHPOINTS and 0 <= hit < 3
    True
    """
    pool = tuple(points) if points is not None else atomic.CRASHPOINTS
    rng = np.random.default_rng(seed)
    point = pool[int(rng.integers(len(pool)))]
    return {point: int(rng.integers(max_hit))}


class CrashInjector:
    """Raise `SimulatedCrash` at scheduled hits of named crashpoints.

    `schedule` maps crashpoint name -> 0-based hit index at which to
    die; names are validated against `repro.durable.atomic.CRASHPOINTS`
    so a renamed point cannot silently turn a crash test into a no-op.
    Use as a context manager — it installs itself as the process-global
    crash hook on enter and restores the previous hook on exit.
    """

    def __init__(self, schedule: Dict[str, int]):
        unknown = sorted(set(schedule) - set(atomic.CRASHPOINTS))
        if unknown:
            raise ValueError(
                f"unknown crashpoint(s) {unknown}; registered: "
                f"{list(atomic.CRASHPOINTS)}")
        self.schedule = {k: int(v) for k, v in schedule.items()}
        self.hits: Dict[str, int] = {}   # point -> times reached
        self.fired = False
        self.fired_at: Optional[str] = None
        self.fired_event = threading.Event()
        self._lock = threading.Lock()
        self._prev_hook = None

    def __enter__(self) -> "CrashInjector":
        self._prev_hook = atomic._hook
        atomic.set_crash_hook(self._observe)
        return self

    def __exit__(self, *exc) -> bool:
        atomic.set_crash_hook(self._prev_hook)
        return False

    def _observe(self, point: str) -> None:
        with self._lock:
            if self.fired:
                # Latch: the process is dead; nothing commits after.
                raise SimulatedCrash(
                    f"crashpoint {point!r} reached after death at "
                    f"{self.fired_at!r}")
            i = self.hits.get(point, 0)
            self.hits[point] = i + 1
            if self.schedule.get(point) == i:
                self.fired = True
                self.fired_at = point
                self.fired_event.set()
                raise SimulatedCrash(f"crash at {point}[{i}]")
