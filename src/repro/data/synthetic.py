"""Synthetic datasets — the paper's Beta benchmarks and LM token corpora.

Beta datasets (Table 2): proxy scores A(x) ~ Beta(alpha, beta), oracle
labels O(x) ~ Bernoulli(A(x)) — a perfectly calibrated proxy whose sharpness
and positive rate are controlled by (alpha, beta). The paper's pairs:
(0.01, 1) with TPR ~0.5-1% and (0.01, 2) with TPR ~1%; the imbalance sweep
(Fig 10) uses beta in {0.125, ..., 2.0}.

Noise / drift variants (Fig 9, Table 3): additive Gaussian proxy noise
clipped to [0,1], and shifted-parameter datasets for the drift experiments.

LM corpora: deterministic synthetic token streams with a planted "event"
structure so the selection service has a learnable predicate: sequences
containing a marker n-gram are positives; the oracle checks the marker
exactly and the proxy model is trained to detect it.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class BetaDataset:
    scores: np.ndarray       # A(x), float32 in [0,1]
    labels: np.ndarray       # O(x), float32 {0,1}
    alpha: float
    beta: float

    @property
    def tpr(self) -> float:
        return float(self.labels.mean())

    def truth_mask(self) -> np.ndarray:
        return self.labels > 0.5


def make_beta(n=1_000_000, alpha=0.01, beta=1.0, seed=0,
              noise_std=0.0) -> BetaDataset:
    rng = np.random.default_rng(seed)
    probs = rng.beta(alpha, beta, n).astype(np.float32)
    labels = (rng.random(n) < probs).astype(np.float32)
    scores = probs
    if noise_std > 0:
        scores = np.clip(probs + rng.normal(0, noise_std, n)
                         .astype(np.float32), 0.0, 1.0)
    return BetaDataset(scores=scores, labels=labels, alpha=alpha, beta=beta)


def make_drift_pair(n=1_000_000, seed=0):
    """(train, shifted) Beta datasets — Table 3's synthetic drift row."""
    return (make_beta(n, 0.01, 1.0, seed=seed),
            make_beta(n, 0.01, 2.0, seed=seed + 1))


def make_miscalibrated(n=1_000_000, alpha=0.01, beta=1.0, seed=0,
                       temperature=3.0):
    """Proxy that is *correlated but miscalibrated* (sharpened scores):
    used by robustness tests — guarantees must hold anyway."""
    rng = np.random.default_rng(seed)
    probs = rng.beta(alpha, beta, n).astype(np.float32)
    labels = (rng.random(n) < probs).astype(np.float32)
    scores = probs ** (1.0 / temperature)
    return BetaDataset(scores=scores, labels=labels, alpha=alpha, beta=beta)


def make_adversarial(n=100_000, tpr=0.01, seed=0):
    """Anti-correlated proxy: high scores on negatives. Defensive mixing
    must still deliver validity (quality will be poor — that's expected)."""
    rng = np.random.default_rng(seed)
    labels = (rng.random(n) < tpr).astype(np.float32)
    scores = np.where(labels > 0.5,
                      rng.beta(1, 20, n), rng.beta(20, 1, n)).astype(
                          np.float32)
    return BetaDataset(scores=scores, labels=labels, alpha=0, beta=0)


# ---------------------------------------------------------------------------
# Token corpora for the LM planes
# ---------------------------------------------------------------------------

MARKER = (7, 13, 42)   # planted n-gram; sequences containing it match


def make_token_corpus(num_records=4096, seq_len=128, vocab=128,
                      positive_rate=0.05, seed=0):
    """Deterministic synthetic corpus with planted positives.

    Returns (tokens (N, S) int32, labels (N,) float32). A record is positive
    iff the marker tri-gram occurs; the oracle is exact marker matching (the
    ground truth), the proxy is a trained model's confidence.
    """
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, vocab, (num_records, seq_len), dtype=np.int32)
    # stamp the marker into a random subset at random offsets
    n_pos = int(num_records * positive_rate)
    pos_idx = rng.choice(num_records, n_pos, replace=False)
    offs = rng.integers(0, seq_len - len(MARKER), n_pos)
    for i, off in zip(pos_idx, offs):
        tokens[i, off:off + len(MARKER)] = MARKER
    labels = contains_marker(tokens).astype(np.float32)
    return tokens, labels


def contains_marker(tokens) -> np.ndarray:
    """Exact oracle predicate: does the marker tri-gram occur?"""
    t = np.asarray(tokens)
    hits = np.zeros(t.shape[0], bool)
    for off in range(t.shape[1] - len(MARKER) + 1):
        window = t[:, off:off + len(MARKER)]
        hits |= (window == np.asarray(MARKER)).all(axis=1)
    return hits


def lm_batches(key_seed, num_steps, global_batch, seq_len, vocab,
               start_step=0):
    """Deterministic next-token-prediction batches (resumable by step)."""
    for step in range(start_step, num_steps):
        rng = np.random.default_rng((key_seed, step))
        toks = rng.integers(0, vocab, (global_batch, seq_len + 1),
                            dtype=np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
