"""Deterministic, sharded, resumable data pipeline.

Production posture:
  * every batch is a pure function of (seed, step) — restart at step k
    reproduces exactly the stream a failed run would have seen (the
    checkpoint only needs to store the step counter, no iterator state);
  * each data-parallel host reads only its shard of the global batch
    (shard_index / num_shards), so ingest bandwidth scales with the fleet;
  * a background prefetch thread keeps `depth` batches ready so host-side
    generation overlaps device compute (the standard single-host overlap);
  * record stores for the selection plane are memory-mapped score arrays
    (np.memmap) so a 1e9-score corpus never fully materializes in RAM.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np


class DeterministicSource:
    """Batch source: batch = f(seed, step), sharded across hosts."""

    def __init__(self, make_batch: Callable[[np.random.Generator, int], dict],
                 seed: int, shard_index: int = 0, num_shards: int = 1):
        self._make = make_batch
        self.seed = seed
        self.shard_index = shard_index
        self.num_shards = num_shards

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        full = self._make(rng, step)
        return {k: v[self.shard_index::self.num_shards]
                for k, v in full.items()}

    def iter_from(self, start_step: int) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of a batch iterator (depth-bounded)."""

    _SENTINEL = object()

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None

        def run():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:  # noqa: BLE001 — surfaced on get
                self._err = e
            finally:
                self._q.put(self._SENTINEL)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


class ScoreStore:
    """Memory-mapped proxy-score shard store for the selection plane.

    Layout: one float32 array per shard on disk. Writers are the serve
    plane's scoring jobs; readers are SUPG queries and the sketch kernel.
    """

    def __init__(self, path, num_records: int, mode="r+", create=False):
        self.path = str(path)
        if create:
            self._arr = np.memmap(self.path, np.float32, "w+",
                                  shape=(num_records,))
            self._arr[:] = -1.0   # unscored marker
        else:
            self._arr = np.memmap(self.path, np.float32, mode,
                                  shape=(num_records,))

    def write(self, start: int, scores: np.ndarray):
        self._arr[start:start + scores.shape[0]] = scores
        self._arr.flush()

    def read(self, start: int = 0, count: Optional[int] = None) -> np.ndarray:
        end = None if count is None else start + count
        return np.asarray(self._arr[start:end])

    @property
    def scores(self) -> np.ndarray:
        """Zero-copy memmap view — SelectionEngine consumes stores directly
        through this so out-of-core shards never materialize in RAM."""
        return self._arr

    def __len__(self) -> int:
        return self._arr.shape[0]

    @property
    def num_scored(self) -> int:
        return int((self._arr >= 0).sum())
