"""Deterministic, sharded, resumable data pipeline.

Production posture:
  * every batch is a pure function of (seed, step) — restart at step k
    reproduces exactly the stream a failed run would have seen (the
    checkpoint only needs to store the step counter, no iterator state);
  * each data-parallel host reads only its shard of the global batch
    (shard_index / num_shards), so ingest bandwidth scales with the fleet;
  * a background prefetch thread keeps `depth` batches ready so host-side
    generation overlaps device compute (the standard single-host overlap);
  * record stores for the selection plane are memory-mapped score arrays
    (np.memmap) so a 1e9-score corpus never fully materializes in RAM;
  * selection *output* is streamed, not materialized: the engine emits
    selected record indices shard-by-shard in fixed-size chunks into a
    `SelectionSink` (in-memory `IndexSink`, memmap-packed `BitmaskStore`,
    or `CallbackSink`/`SelectionStream` for service streaming), so a query
    over 1e8+ records never allocates a full-corpus boolean mask;
  * every chunked walk — sketch construction, selection emission, the PT
    stage-2 region draw, `ScoreStore.num_scored` — iterates one shared
    `ChunkPlan` (shard → chunk spans), and a persistent `WorkerPool`
    drives those spans through one long-lived thread pool: memmap reads
    and the numpy selection/reduction paths release the GIL, so the walks
    scale across cores without paying executor spin-up per call. Walks
    from concurrent queries compose: `ChunkPlan.fuse` merges same-geometry
    plans into one span list so k passes touch each data chunk once
    (`run_fused`). Sinks carry an explicit thread-safety contract (see
    `SelectionSink`).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import os
import queue
import threading
from typing import (Callable, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple, TypeVar)

import numpy as np

# Leaf module (no repro-internal imports of its own) — the two-phase
# commit primitives ScoreStore.append and BitmaskStore growth publish
# through. See docs/guarantees.md, "Durability & recovery".
from repro.durable import atomic as _atomic

# Default streaming granularity: 4M records (16 MB of float32 scores per
# chunk) — big enough to amortize per-chunk overheads, small enough that
# per-query peak host memory stays O(chunk), not O(corpus).
CHUNK_RECORDS = 1 << 22

_T = TypeVar("_T")
_R = TypeVar("_R")


# ---------------------------------------------------------------------------
# ChunkPlan — the shared shard → chunk iteration contract
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChunkSpan:
    """One unit of streaming work: a half-open [start, stop) record range
    inside one shard. `chunk_id` is the span's dense index within its shard,
    so per-chunk state (sampling masses, region counts) lines up with the
    span order without any extra bookkeeping."""
    shard_id: int
    chunk_id: int
    start: int
    stop: int

    @property
    def size(self) -> int:
        return self.stop - self.start


class ChunkPlan:
    """Shard → chunk decomposition shared by every streaming pass.

    One plan instance replaces the hand-rolled ``range(0, n, chunk)`` loops
    that used to live in sketch construction, selection emission, and the
    PT stage-2 region walk: all of them iterate the same spans, so per-chunk
    state computed by one pass (e.g. the sampling chunk masses accumulated
    during the sketch pass) is addressable by any other via
    ``(shard_id, chunk_id)``. Empty shards contribute no spans.

    >>> plan = ChunkPlan([5, 3], chunk_records=2)
    >>> [(s.shard_id, s.chunk_id, s.start, s.stop) for s in plan]
    [(0, 0, 0, 2), (0, 1, 2, 4), (0, 2, 4, 5), (1, 0, 0, 2), (1, 1, 2, 3)]
    >>> plan.total_chunks
    5

    A plan may be restricted to a subset of its shards (`shard_ids`) while
    keeping the full corpus addressing — the live plane's standing-query
    re-emissions walk only newly appended shards this way, and plans with
    equal restriction still fuse:

    >>> [(s.shard_id, s.start, s.stop)
    ...  for s in ChunkPlan([5, 3], 2, shard_ids=[1])]
    [(1, 0, 2), (1, 2, 3)]
    """

    def __init__(self, shard_sizes: Sequence[int], chunk_records: int,
                 shard_ids: Optional[Sequence[int]] = None):
        if chunk_records <= 0:
            raise ValueError("chunk_records must be positive")
        self.shard_sizes = [int(n) for n in shard_sizes]
        self.chunk_records = int(chunk_records)
        if shard_ids is None:
            self.shard_ids = tuple(range(len(self.shard_sizes)))
        else:
            ids = sorted({int(i) for i in shard_ids})
            if ids and (ids[0] < 0 or ids[-1] >= len(self.shard_sizes)):
                raise ValueError(
                    f"shard_ids {ids} out of range for "
                    f"{len(self.shard_sizes)} shards")
            self.shard_ids = tuple(ids)

    def num_chunks(self, shard_id: int) -> int:
        n = self.shard_sizes[shard_id]
        return -(-n // self.chunk_records)

    @property
    def total_chunks(self) -> int:
        return sum(self.num_chunks(sh) for sh in self.shard_ids)

    def shard_spans(self, shard_id: int) -> List[ChunkSpan]:
        n = self.shard_sizes[shard_id]
        c = self.chunk_records
        return [ChunkSpan(shard_id, ci, o, min(o + c, n))
                for ci, o in enumerate(range(0, n, c))]

    def __iter__(self) -> Iterator[ChunkSpan]:
        for shard_id in self.shard_ids:
            yield from self.shard_spans(shard_id)

    @property
    def geometry(self) -> Tuple[Tuple[int, ...], int, Tuple[int, ...]]:
        """Hashable span-structure identity: two plans with equal geometry
        produce identical span lists and can therefore fuse. Shard
        restriction is part of the identity — a restricted walk must not
        share spans with a full-corpus one."""
        return (tuple(self.shard_sizes), self.chunk_records, self.shard_ids)

    @staticmethod
    def fuse(plans: Sequence["ChunkPlan"]) \
            -> List[Tuple[ChunkSpan, List[int]]]:
        """Compose several plans' walks into one span list.

        Plans sharing geometry contribute their spans *once*, tagged with
        every plan index that covers them; distinct geometries keep their
        own spans. A scheduler walking the fused list runs k same-geometry
        passes while touching each data chunk once instead of k times —
        the per-round fusion a multi-query session relies on. Span order:
        geometry groups in first-appearance order, spans in plan order
        within a group, so a single-plan fuse degenerates to `list(plan)`.
        """
        groups: Dict[Tuple, List[int]] = {}
        first: List[Tuple[Tuple, "ChunkPlan"]] = []
        for i, plan in enumerate(plans):
            g = plan.geometry
            if g not in groups:
                groups[g] = []
                first.append((g, plan))
            groups[g].append(i)
        fused: List[Tuple[ChunkSpan, List[int]]] = []
        for g, plan in first:
            owners = groups[g]
            for span in plan:
                fused.append((span, owners))
        return fused


@dataclasses.dataclass
class ChunkWalk:
    """One chunk-streamed pass: run `fn` on every span of `plan`.

    The unit a query plan *yields* when it needs a full chunked walk
    (selection emission): the scheduler fuses all walks yielded in one
    round via `ChunkPlan.fuse` and drives the fused span list through the
    worker pool once (`run_fused`), then resumes each plan."""
    plan: ChunkPlan
    fn: Callable[[ChunkSpan], None]


class WorkerPool:
    """Persistent, lazily-built thread pool for the streaming plane.

    Replaces the per-call `ThreadPoolExecutor` spin-up that used to live in
    `parallel_map`: an engine owns one pool for its whole lifetime, so
    thread creation is paid once, not per chunk walk. Semantics:

      * `map` preserves item order, and work items carry their output
        slots, so thread count never changes any output bit;
      * inline fast path: with `workers <= 1`, a single-item work list, or
        a call *from one of the pool's own worker threads* (a plan step
        running on the pool may itself call `map` for its internal walks),
        the map runs as a plain in-order loop on the calling thread — the
        nested case would otherwise deadlock a fixed-size pool waiting on
        its own slots;
      * a task exception propagates to the caller and the pool stays
        usable (the executor survives poisoned tasks);
      * `close()` is idempotent and exception-safe; a closed pool still
        serves the inline fast paths (they own no threads) but refuses
        threaded work. Use as a context manager for scoped lifetimes.

    >>> with WorkerPool(4) as pool:
    ...     pool.map(lambda x: x * x, range(5))   # order preserved
    [0, 1, 4, 9, 16]
    """

    def __init__(self, workers: int = 1):
        self.workers = max(1, int(workers))
        self._ex: Optional[concurrent.futures.ThreadPoolExecutor] = None
        self._lock = threading.Lock()
        self._tl = threading.local()
        self._closed = False

    @property
    def closed(self) -> bool:
        return self._closed

    def _executor(self) -> concurrent.futures.ThreadPoolExecutor:
        with self._lock:
            if self._closed:
                raise RuntimeError("WorkerPool is closed")
            if self._ex is None:
                tl = self._tl

                def _mark_worker():
                    tl.inside_pool = True

                self._ex = concurrent.futures.ThreadPoolExecutor(
                    max_workers=self.workers,
                    thread_name_prefix="repro-pool",
                    initializer=_mark_worker)
            return self._ex

    def map(self, fn: Callable[[_T], _R], items: Iterable[_T]) -> List[_R]:
        """Map `fn` over `items` preserving order; threaded when the pool
        is sized > 1 and the call comes from outside the pool itself."""
        items = list(items)
        if (self.workers <= 1 or len(items) <= 1
                or getattr(self._tl, "inside_pool", False)):
            return [fn(it) for it in items]
        return list(self._executor().map(fn, items))

    def close(self) -> None:
        """Shut the executor down (joining its threads). Idempotent."""
        with self._lock:
            self._closed = True
            ex, self._ex = self._ex, None
        if ex is not None:
            ex.shutdown(wait=True)

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def run_fused(walks: Sequence[ChunkWalk],
              pool: Optional[WorkerPool] = None) \
        -> List[Optional[BaseException]]:
    """Run several chunk walks as one fused span pass over the pool.

    Same-geometry walks share spans (`ChunkPlan.fuse`), so k emission
    passes touch each data chunk once. Errors are isolated per walk: the
    first exception a walk's `fn` raises is captured, that walk skips its
    remaining spans (best effort — spans already in flight on other
    threads still run), and the other walks keep streaming. Returns one
    entry per walk: None on success, the captured exception otherwise —
    the caller throws it into the owning plan.
    """
    walks = list(walks)
    errors: List[Optional[BaseException]] = [None] * len(walks)
    fused = ChunkPlan.fuse([w.plan for w in walks])

    def run_item(item):
        span, owners = item
        for i in owners:
            if errors[i] is not None:
                continue
            try:
                walks[i].fn(span)
            except BaseException as err:  # noqa: BLE001 — isolated per walk
                errors[i] = err

    if pool is not None:
        pool.map(run_item, fused)
    else:
        for it in fused:
            run_item(it)
    return errors


def parallel_map(fn: Callable[[_T], _R], items: Iterable[_T],
                 workers: int = 1,
                 pool: Optional[WorkerPool] = None) -> List[_R]:
    """Map `fn` over `items`, preserving order; threaded when workers > 1.

    Back-compat wrapper over `WorkerPool`: with `pool` given, the work
    rides that persistent pool (the engine path); otherwise a scoped pool
    lives for this one call — the historical per-call behavior. With
    workers <= 1 this is a plain in-order loop — identical results, zero
    thread overhead — so callers get determinism-by-construction: work
    items carry their output slot and never depend on completion order.
    """
    if pool is not None:
        return pool.map(fn, items)
    items = list(items)
    if workers <= 1 or len(items) <= 1:
        return [fn(it) for it in items]
    with WorkerPool(workers) as scoped:
        return scoped.map(fn, items)


class DeterministicSource:
    """Batch source: batch = f(seed, step), sharded across hosts."""

    def __init__(self, make_batch: Callable[[np.random.Generator, int], dict],
                 seed: int, shard_index: int = 0, num_shards: int = 1):
        self._make = make_batch
        self.seed = seed
        self.shard_index = shard_index
        self.num_shards = num_shards

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        full = self._make(rng, step)
        return {k: v[self.shard_index::self.num_shards]
                for k, v in full.items()}

    def iter_from(self, start_step: int) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of a batch iterator (depth-bounded)."""

    _SENTINEL = object()

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None

        def run():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:  # noqa: BLE001 — surfaced on get
                self._err = e
            finally:
                self._q.put(self._SENTINEL)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


class ScoreStore:
    """Memory-mapped proxy-score shard store for the selection plane.

    Layout: one float32 array per shard on disk. Writers are the serve
    plane's scoring jobs; readers are SUPG queries and the sketch kernel.
    """

    _ITEM = np.dtype(np.float32).itemsize

    def __init__(self, path, num_records: int, mode="r+", create=False):
        self.path = str(path)
        if create:
            self._arr = np.memmap(self.path, np.float32, "w+",
                                  shape=(num_records,))
            self._arr[:] = -1.0   # unscored marker
            self._arr.flush()
            _atomic.commit_length(self.path, num_records * self._ITEM)
        else:
            # Crash recovery for the two-phase append: bytes past the
            # committed length are an un-acknowledged grow — truncate
            # them away and clamp the view, so a reopened store is
            # exactly its last committed state. Stores without a length
            # sidecar (pre-durability files, ad-hoc arrays) open as-is.
            committed = _atomic.committed_length(self.path)
            if committed is not None:
                _atomic.discard_uncommitted_tail(self.path)
                num_records = min(int(num_records),
                                  committed // self._ITEM)
            self._arr = np.memmap(self.path, np.float32, mode,
                                  shape=(num_records,))
        self._num_scored: Optional[int] = None
        # write()/append() bump _version under _lock; num_scored's chunked
        # scan runs lock-free and commits only if the version it started
        # from is still current — see num_scored for the race contract.
        self._lock = threading.Lock()
        self._version = 0

    def write(self, start: int, scores: np.ndarray):
        """Overwrite `scores.size` records at `start` (atomic w.r.t. the
        `num_scored` cache: a racing count can never commit a stale scan
        over this write)."""
        scores = np.asarray(scores)
        with self._lock:
            n = int(self._arr.shape[0])
            # Reject out-of-range writes outright — memmap slicing would
            # silently truncate them and scoring jobs would lose records.
            if start < 0 or start + scores.shape[0] > n:
                raise ValueError(
                    f"write [{start}, {start + scores.shape[0]}) out of "
                    f"range for store of {n} records")
            self._arr[start:start + scores.shape[0]] = scores
            self._arr.flush()
            self._version += 1
            self._num_scored = None   # invalidate the cached scan

    def append(self, scores: np.ndarray) -> int:
        """Grow the store by `scores.size` records at the tail; returns the
        new record count.

        The backing file is extended and remapped; existing `.scores`
        views (e.g. shards pinned by an in-flight engine snapshot) keep
        their old length and stay valid — the file only ever grows, and
        records below the old tail are untouched. The `num_scored` cache
        is delta-updated in place (appends know exactly how many scored
        records they add), so a warm cache never pays a rescan — the
        only cache an append invalidates is none at all.

        The grow is a two-phase commit: the tail bytes are written and
        fsync'd first, then the new length is published through the
        atomic sidecar (`repro.durable.atomic.commit_length`). A crash
        between the phases (`pre_length_commit`) leaves a file whose
        extra bytes are truncated away on the next open — the append was
        never acknowledged, so re-issuing it is exactly-once.
        """
        scores = np.asarray(scores, np.float32)
        k = int(scores.shape[0])
        with self._lock:
            old = self._arr
            n = int(old.shape[0])
            if k:
                old.flush()
                # Seed the sidecar for pre-durability files so recovery
                # has a committed length to truncate back to.
                if _atomic.committed_length(self.path) is None:
                    _atomic.commit_length(self.path, n * self._ITEM)
                with open(self.path, "r+b") as f:
                    f.truncate((n + k) * self._ITEM)
                grown = np.memmap(self.path, np.float32, "r+",
                                  shape=(n + k,))
                grown[n:] = scores
                grown.flush()
                _atomic.fsync_path(self.path)
                _atomic.crashpoint("pre_length_commit")
                _atomic.commit_length(self.path, (n + k) * self._ITEM)
                self._arr = grown
            self._version += 1
            if self._num_scored is not None:
                self._num_scored += int((scores >= 0).sum())
            return n + k

    def read(self, start: int = 0, count: Optional[int] = None) -> np.ndarray:
        end = None if count is None else start + count
        return np.asarray(self._arr[start:end])

    @property
    def scores(self) -> np.ndarray:
        """Zero-copy memmap view — SelectionEngine consumes stores directly
        through this so out-of-core shards never materialize in RAM."""
        return self._arr

    def __len__(self) -> int:
        return self._arr.shape[0]

    def _count_span(self, arr: np.ndarray, start: int, stop: int) -> int:
        """Scored-record count over one span of `arr` (the seam
        `tests/test_data.py`'s race regression overrides to land a write
        mid-scan)."""
        return int((arr[start:stop] >= 0).sum())

    @property
    def num_scored(self) -> int:
        """Count of scored (non-sentinel) records, cached between writes.

        The scan itself is chunked so even a 1e9-record store is counted
        with O(chunk) peak memory; repeat reads are O(1) until the next
        `write` invalidates the cache (appends delta-update it instead).

        Concurrency contract: the chunked scan runs *outside* the store
        lock (it may touch gigabytes), but it only commits to the cache —
        and only returns — if the store's version is unchanged from when
        the scan started. A `write()` or `append()` landing mid-scan bumps
        the version, so the stale count is discarded and the scan retries;
        the epoch-pinning logic layered on top (`repro.live`) can therefore
        never observe a count that mixes pre- and post-write state.
        """
        while True:
            with self._lock:
                if self._num_scored is not None:
                    return self._num_scored
                v0 = self._version
                arr = self._arr
            plan = ChunkPlan([int(arr.shape[0])], CHUNK_RECORDS)
            total = sum(self._count_span(arr, sp.start, sp.stop)
                        for sp in plan)
            with self._lock:
                if self._num_scored is not None:
                    return self._num_scored
                if self._version == v0:
                    self._num_scored = total
                    return total
                # a write/append landed mid-scan: the count may be stale
                # in either direction — rescan against the new version.


# ---------------------------------------------------------------------------
# Selection sinks — the streaming output plane
# ---------------------------------------------------------------------------

class SelectionSink:
    """Chunked consumer protocol for streamed selection emission.

    The engine calls, in order:

        open(shard_sizes)              once, before any emission
        fold(shard_id, local_idx)      labeled positives *below* tau
                                       (Algorithm 1's R1, sink-level merge)
        emit(shard_id, local_idx)      ascending in-chunk; disjoint from
                                       fold()
        close() -> per-shard counts    once, after the last chunk

    emit/fold receive *shard-local* indices; `offsets` maps them to global
    ids. Because the engine guarantees fold/emit disjointness, the base
    class's per-shard counts are exact without any dedup state.

    Thread-safety contract: with an engine worker pool (workers > 1) `emit`
    may be called concurrently from multiple threads, including for chunks
    of the *same* shard, and chunk arrival order is unspecified. The base
    class serializes each call (count update + `_consume`) under one lock,
    so subclasses only need per-shard buffers that tolerate interleaved
    appends and are merged into canonical order at `close()` — exactly what
    `IndexSink` does with its per-shard chunk lists. With workers == 1 the
    legacy ordering (chunks ascending per shard, shards in order) still
    holds. `open`, `fold` and `close` are always driver-thread only.

    One sink serves one query at a time: under a `QuerySession` (or any
    concurrent `run_many` batch) each query opens and closes its own sink,
    and `open` refuses a sink that is already open — two queries sharing a
    sink object would silently interleave their emissions. A sink may be
    *reused* sequentially (open after close), which resets its state.

    The `IndexSink` flow, driven by hand:

    >>> import numpy as np
    >>> sink = IndexSink()
    >>> sink.open([4, 4])                    # two shards of 4 records
    >>> sink.fold(1, np.asarray([0]))        # labeled positive below tau
    >>> sink.emit(0, np.asarray([1, 3]))     # a {A >= tau} chunk
    >>> sink.close().tolist()                # per-shard counts
    [2, 1]
    >>> sink.indices(0).tolist(), sink.mask(1).tolist()
    ([1, 3], [True, False, False, False])
    """

    def open(self, shard_sizes: Sequence[int]) -> None:
        if getattr(self, "_is_open", False):
            raise RuntimeError(
                f"{type(self).__name__} is already open: one sink object "
                "cannot serve two queries at once (their emissions would "
                "interleave) — give each query its own sink")
        self._is_open = True
        self.shard_sizes = [int(n) for n in shard_sizes]
        self.offsets = np.concatenate(
            [[0], np.cumsum(self.shard_sizes)]).astype(np.int64)
        self.counts = np.zeros(len(self.shard_sizes), np.int64)
        self._lock = threading.Lock()

    def emit(self, shard_id: int, local_idx: np.ndarray) -> None:
        local_idx = np.asarray(local_idx, np.int64)
        if local_idx.size == 0:
            return
        with self._lock:
            self.counts[shard_id] += local_idx.size
            self._consume(shard_id, local_idx, folded=False)

    def fold(self, shard_id: int, local_idx: np.ndarray) -> None:
        local_idx = np.asarray(local_idx, np.int64)
        if local_idx.size == 0:
            return
        with self._lock:
            self.counts[shard_id] += local_idx.size
            self._consume(shard_id, local_idx, folded=True)

    def close(self) -> np.ndarray:
        self._finalize()
        self._is_open = False
        return self.counts.copy()

    @property
    def total_selected(self) -> int:
        return int(self.counts.sum())

    # -- subclass hooks -------------------------------------------------

    def _consume(self, shard_id: int, local_idx: np.ndarray,
                 folded: bool) -> None:
        raise NotImplementedError

    def _finalize(self) -> None:
        pass

    # -- optional views (materializing sinks only) ----------------------

    def indices(self, shard_id: int) -> np.ndarray:
        """Sorted shard-local selected indices."""
        raise NotImplementedError(f"{type(self).__name__} holds no state")

    def mask(self, shard_id: int) -> np.ndarray:
        """Boolean selection mask for one shard (materializes that shard)."""
        m = np.zeros(self.shard_sizes[shard_id], bool)
        m[self.indices(shard_id)] = True
        return m


class IndexSink(SelectionSink):
    """In-memory per-shard index sink — the default materializer.

    Holds O(selected) int64 indices instead of O(corpus) booleans; `mask`
    rematerializes a single shard's boolean view on demand.
    """

    def open(self, shard_sizes):
        super().open(shard_sizes)
        self._chunks: List[List[np.ndarray]] = [[] for _ in self.shard_sizes]
        self._idx: Optional[List[np.ndarray]] = None

    def _consume(self, shard_id, local_idx, folded):
        self._chunks[shard_id].append(local_idx)

    def _finalize(self):
        # Emission is ascending per shard but fold() chunks interleave
        # arbitrarily; one sort per shard restores canonical order.
        self._idx = [
            np.sort(np.concatenate(c)) if c else np.empty(0, np.int64)
            for c in self._chunks]
        self._chunks = [[] for _ in self.shard_sizes]

    def indices(self, shard_id):
        if self._idx is None:
            raise RuntimeError("sink not closed yet")
        return self._idx[shard_id]


class BitmaskStore(SelectionSink):
    """Memmap-backed packed selection bitmask: 1 bit per record on disk.

    The out-of-core materializer — a 1e9-record selection costs 125 MB of
    disk and O(chunk) host memory while being written. Bits are byte-aligned
    per shard so shards stay independently addressable.

    Epoch-aware growth: a sidecar meta file (``<path>.meta.json``) records
    the shard layout the stored bits were written under. Reopening with a
    layout that *extends* the recorded one (same shard sizes, plus new
    shards at the tail — exactly what a live-corpus append produces) grows
    the backing file through the two-phase atomic-commit path and keeps
    every committed bit, so a store sized at certify time covers appended
    shards as standing-query catch-ups re-emit over them. Reopening with
    an incompatible layout starts fresh (wipe), the pre-durability
    behavior.
    """

    def __init__(self, path):
        self.path = str(path)
        self.meta_path = self.path + ".meta.json"
        self._arr: Optional[np.memmap] = None

    def open(self, shard_sizes):
        super().open(shard_sizes)
        self._byte_offsets = np.concatenate(
            [[0], np.cumsum([(n + 7) // 8 for n in self.shard_sizes])]
        ).astype(np.int64)
        total = max(int(self._byte_offsets[-1]), 1)
        meta = _atomic.read_json(self.meta_path)
        old_sizes = (None if meta is None
                     else [int(n) for n in meta.get("shard_sizes", [])])
        if (old_sizes is not None and os.path.exists(self.path)
                and len(self.shard_sizes) >= len(old_sizes)
                and self.shard_sizes[:len(old_sizes)] == old_sizes):
            # Extend-or-equal: grow in place, preserving committed bits.
            # Two phases — zero + fsync the grown tail, then commit the
            # new layout through the atomic meta replace. A crash between
            # them (`mid_bitmask_commit`) leaves the old layout
            # committed; the next open simply re-grows, and re-emission
            # over the new shards is an idempotent OR.
            old_total = max(int(sum((n + 7) // 8 for n in old_sizes)), 1)
            with open(self.path, "r+b") as f:
                f.truncate(total)
            self._arr = np.memmap(self.path, np.uint8, "r+", shape=(total,))
            if total > old_total:
                self._arr[old_total:] = 0
                self._arr.flush()
                _atomic.fsync_path(self.path)
                _atomic.crashpoint("mid_bitmask_commit")
        else:
            self._arr = np.memmap(self.path, np.uint8, "w+", shape=(total,))
        _atomic.atomic_write_json(self.meta_path,
                                  {"shard_sizes": self.shard_sizes})

    def _consume(self, shard_id, local_idx, folded):
        base = int(self._byte_offsets[shard_id])
        np.bitwise_or.at(self._arr, base + (local_idx >> 3),
                         (1 << (local_idx & 7)).astype(np.uint8))

    def _finalize(self):
        self._arr.flush()
        _atomic.fsync_path(self.path)

    def mask(self, shard_id):
        base = int(self._byte_offsets[shard_id])
        nbytes = int(self._byte_offsets[shard_id + 1]) - base
        bits = np.unpackbits(np.asarray(self._arr[base:base + nbytes]),
                             bitorder="little")
        return bits[:self.shard_sizes[shard_id]].astype(bool)

    def indices(self, shard_id, chunk_bytes: int = 1 << 20):
        """Sorted shard-local indices, decoded in bounded byte chunks."""
        base = int(self._byte_offsets[shard_id])
        nbytes = int(self._byte_offsets[shard_id + 1]) - base
        out = []
        for off in range(0, nbytes, chunk_bytes):
            span = np.asarray(self._arr[base + off:
                                        base + min(off + chunk_bytes,
                                                   nbytes)])
            bits = np.unpackbits(span, bitorder="little")
            hit = np.nonzero(bits)[0].astype(np.int64) + off * 8
            if hit.size:
                out.append(hit)
        if not out:
            return np.empty(0, np.int64)
        idx = np.concatenate(out)
        return idx[idx < self.shard_sizes[shard_id]]


class CallbackSink(SelectionSink):
    """Streams (shard_id, global_ids, folded) chunks to a callback as the
    engine emits them — the service-streaming sink. Holds no index state;
    only the per-shard counts survive close()."""

    def __init__(self, fn: Callable[[int, np.ndarray, bool], None]):
        self._fn = fn

    def _consume(self, shard_id, local_idx, folded):
        self._fn(shard_id, self.offsets[shard_id] + local_idx, folded)


class _StreamCancelled(Exception):
    """Raised inside the producer when the consumer closed the stream."""


class SelectionStream:
    """Iterator inversion of `CallbackSink`: consume a streamed selection
    as `(shard_id, global_ids, folded)` chunks while the engine produces
    them from a background thread.

        with SelectionStream(
                lambda sink: engine.run(key, oracle, q, sink=sink)) as st:
            for shard_id, gids, folded in st:
                ...                    # incremental consumption
        result = st.result             # ShardedSelection after exhaustion

    The queue is depth-bounded, so a slow consumer backpressures the
    emission loop instead of buffering the whole selection. A consumer
    that stops early must call `close()` (the context manager does) —
    it cancels the producer at its next chunk and reaps the thread;
    `result` stays None for a cancelled stream.
    """

    _SENTINEL = object()

    def __init__(self, run_fn: Callable[[SelectionSink], object],
                 depth: int = 8):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None
        self._closed = False
        self._done = False
        self.result = None

        def on_chunk(sh, gids, folded):
            if self._closed:
                raise _StreamCancelled
            self._q.put((sh, gids, folded))

        def produce():
            try:
                self.result = run_fn(CallbackSink(on_chunk))
            except _StreamCancelled:
                pass
            except BaseException as e:  # noqa: BLE001 — surfaced on get
                self._err = e
            finally:
                self._q.put(self._SENTINEL)

        self._thread = threading.Thread(target=produce, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        if self._done:
            raise StopIteration
        item = self._q.get()
        if item is self._SENTINEL:
            self._done = True
            self._thread.join()
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        """Abandon the stream: cancel the producer at its next chunk and
        drain the queue so a blocked put() can finish. Safe to call at any
        point, including after exhaustion."""
        if self._done:
            return
        self._closed = True
        while True:
            if self._q.get() is self._SENTINEL:
                break
        self._thread.join()
        self._done = True

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
