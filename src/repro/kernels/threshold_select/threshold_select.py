"""Fused threshold-selection kernel — the streaming emission hot loop.

Selection emission is the last O(n) pass of a SUPG query: once tau is
estimated from the tiny labeled sample, every shard must be scanned for
{x : A(x) >= tau}. Materializing a boolean mask per query costs one full
host-side allocation per corpus; at 1e9 records that is the memory wall the
streaming plane removes. This kernel fuses, per (1, block_n) score block:

    compare:  sel[i] = (A(x_i) >= tau) & (A(x_i) >= 0)   (-1 marks unscored
              records / padding — they are never emitted, regardless of tau)
    count:    cnt    = sum(sel)
    compact:  idx[j] = i of the j-th selected record, j < cnt (block-local)

so one streaming read of the chunk yields dense per-block index lists whose
total size is O(selected), not O(n). Compaction is resolved the same way
score_hist resolves bin membership: the slot assignment pos = cumsum(sel)-1
drives one-hot (block_n x 512) masks contracted against the block-local
iota on the MXU (float32 is exact for indices < 2^24 >> block_n). Entries
at slots >= cnt are matmul zeros; callers slice by cnt.

Layout: grid (n_blocks,); tau rides in SMEM; outputs are (nb, block_n)
compacted indices + (nb, 128) lane-broadcast counts. Compiled on TPU,
`interpret=True` emulation elsewhere; the pure-numpy reference in ref.py is
the non-tile-aligned / CPU-throughput fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_SLOT_TILE = 512


def _select_kernel(tau_ref, s_ref, idx_ref, cnt_ref, *, block_n):
    tau = tau_ref[0]
    s = s_ref[0].astype(jnp.float32)                  # (block_n,)
    valid = s >= 0.0                                  # sentinel/padding = -1
    sel = jnp.logical_and(valid, s >= tau)
    self32 = sel.astype(jnp.float32)
    pos = jnp.cumsum(self32) - 1.0                    # slot of each selected
    local = jax.lax.broadcasted_iota(jnp.float32, (1, block_n), 1)

    for t in range(block_n // _SLOT_TILE):
        lo = t * _SLOT_TILE
        slot_ids = lo + jax.lax.broadcasted_iota(
            jnp.float32, (block_n, _SLOT_TILE), 1)
        onehot = jnp.where(sel[:, None], (pos[:, None] == slot_ids)
                           .astype(jnp.float32), 0.0)
        compact = jax.lax.dot_general(
            local, onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # (1, _SLOT_TILE)
        idx_ref[0, lo:lo + _SLOT_TILE] = compact[0]
    cnt_ref[0, :] = jnp.full((128,), jnp.sum(self32), jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def threshold_select_blocks(scores, tau, block_n=1024, interpret=False):
    """scores: (N,) float; entries < 0 (unscored sentinel/padding) are never
    selected. Returns (idx, cnt): idx (nb, block_n) float32 block-local
    compacted indices (garbage beyond the count), cnt (nb, 128) float32
    per-block selected counts broadcast across lanes.
    """
    assert block_n % _SLOT_TILE == 0
    n = scores.shape[0]
    pad = (-n) % block_n
    if pad:
        scores = jnp.concatenate(
            [scores, jnp.full((pad,), -1.0, scores.dtype)])
    nb = scores.shape[0] // block_n
    blocks = scores.reshape(nb, block_n)
    tau_arr = jnp.full((1,), tau, jnp.float32)

    kernel = functools.partial(_select_kernel, block_n=block_n)
    idx, cnt = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda i: (i, 0)),
            pl.BlockSpec((1, 128), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block_n), jnp.float32),
            jax.ShapeDtypeStruct((nb, 128), jnp.float32),
        ],
        interpret=interpret,
    )(tau_arr, blocks)
    return idx, cnt
