"""Pure-numpy oracle for the fused threshold select (nonzero formulation).

Also the production CPU-throughput path: a chunk-local nonzero is exactly
what the fused kernel computes, and numpy's nonzero streams the chunk once
with no per-record Python work. Operates on host arrays (memmap chunks
included) without copying them to a device buffer.
"""
from __future__ import annotations

import numpy as np


def threshold_select_ref(scores, tau) -> np.ndarray:
    """Ascending local indices of {i : scores[i] >= tau and scores[i] >= 0}.

    Entries below 0 are the "unscored" sentinel (-1) and are never selected,
    matching the kernel's validity mask bit-for-bit. The two conditions
    fold into one comparison against max(tau, 0) — same set for every
    input, half the temporaries, one pass instead of three.
    """
    s = np.asarray(scores)
    return np.nonzero(s >= max(float(tau), 0.0))[0].astype(np.int64)
