"""Public wrapper for the fused threshold-selection kernel.

`backend="auto"` compiles the Pallas kernel on TPU and routes to the
pure-numpy nonzero reference elsewhere — the reference IS the CPU
production path (interpret-mode emulation of the one-hot compaction is for
kernel validation, not throughput, so unlike score_hist it is opt-in via
`backend="interpret"`). `backend="ref"` forces the numpy path, which is
also the automatic fallback whenever `block_n` is not tile-aligned. All
backends return identical ascending int64 indices, so the streaming plane
is backend-agnostic bit-for-bit.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.kernels.threshold_select import ref
from repro.kernels.threshold_select.threshold_select import _SLOT_TILE
from repro.kernels.threshold_select.threshold_select import (
    threshold_select_blocks as _kernel)


def kernel_supported(block_n: int) -> bool:
    """Whether the fused kernel's slot-tile layout covers this block size."""
    return block_n % _SLOT_TILE == 0


def default_backend() -> str:
    """The engine's platform default: compiled kernel on TPU, numpy
    reference elsewhere (interpret emulation is for kernel validation, not
    CPU throughput)."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def threshold_select(scores, tau, *, block_n: int = 1024,
                     backend: str = "auto") -> np.ndarray:
    """Ascending local indices of {i : scores[i] >= tau, scores[i] >= 0}.

    scores may be any host float array (np.memmap chunks included); entries
    below 0 are the "unscored" sentinel and are never selected. The kernel
    path stitches per-block compacted indices with per-block counts on the
    host — peak memory is O(len(scores)), so callers bound memory by
    chunking the corpus, never by masking it whole.
    """
    n = int(np.asarray(scores).shape[0])
    if n == 0:
        return np.empty(0, np.int64)
    if backend == "auto":
        backend = default_backend()
    if backend != "ref" and not kernel_supported(block_n):
        backend = "ref"
    if backend == "ref":
        return ref.threshold_select_ref(scores, tau)

    idx, cnt = _kernel(np.asarray(scores, np.float32), float(tau),
                       block_n=block_n, interpret=(backend == "interpret"))
    idx = np.asarray(idx)
    cnt = np.asarray(cnt)[:, 0].astype(np.int64)
    nb = idx.shape[0]
    lane = np.arange(block_n, dtype=np.int64)
    keep = lane[None, :] < cnt[:, None]
    base = (np.arange(nb, dtype=np.int64) * block_n)[:, None]
    out = (idx.astype(np.int64) + base)[keep]   # row-major => ascending
    return out
