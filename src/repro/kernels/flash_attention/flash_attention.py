"""Flash attention (causal, GQA) — Pallas TPU kernel.

Grid (B, H, nQ, nK): the two outer axes parallelize over batch and query
heads; the inner two walk query/key blocks. TPU grids execute sequentially
per core, so the (m, l, acc) online-softmax state lives in VMEM scratch and
persists across the nK axis; output is written once at the last visited K
block for each Q block.

VMEM working set per step (block_q = block_k = 512, dh = 128, fp32):
  q (512x128) + k (512x128) + v (512x128) + scores (512x512) + acc (512x128)
  ~ 2.3 MB  << 16 MB VMEM/core; block sizes are multiples of the 128-lane
MXU tile so every matmul maps onto full systolic passes.

Causality: K blocks strictly above the diagonal are skipped via pl.when
(no MXU work issued, unlike the masked-but-executed jnp fallback).
GQA: the K/V BlockSpec index_map folds q-head h onto kv-head h // group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale, block_q, block_k, causal):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Causal block skip: K block strictly after the Q block contributes
    # nothing — issue no compute at all.
    diag_ok = (qi * block_q >= ki * block_k) if causal else True

    @pl.when(diag_ok)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (block_q, dh)
        k = k_ref[0, 0].astype(jnp.float32)        # (block_k, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, block_q=512, block_k=512,
                    interpret=False):
    """q: (B,H,S,dh); k/v: (B,KV,S,dh) with H % KV == 0 -> (B,H,S,dh)."""
    b, h, s, dh = q.shape
    kv = k.shape[1]
    group = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    sm_scale = 1.0 / (dh ** 0.5)

    grid = (b, h, s // block_q, s // block_k)
    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, block_q=block_q, block_k=block_k,
        causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
