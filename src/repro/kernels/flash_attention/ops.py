"""Jit'd public wrapper for the flash attention kernel.

`flash_attention(q, k, v)` accepts (B, S, H, dh)-layout tensors (the model
stack's convention), transposes to the kernel's (B, H, S, dh) layout, and
dispatches to the Pallas kernel (interpret=True on CPU) or the jnp oracle.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import (
    flash_attention as _kernel)


@functools.partial(jax.jit, static_argnames=("causal", "backend", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, *, causal=True, backend="interpret",
                    block_q=512, block_k=512):
    """q: (B,S,H,dh); k/v: (B,S,KV,dh) -> (B,S,H,dh)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if backend == "ref":
        ot = ref.attention_ref(qt, kt, vt, causal=causal)
    else:
        ot = _kernel(qt, kt, vt, causal=causal, block_q=block_q,
                     block_k=block_k, interpret=(backend == "interpret"))
    return ot.transpose(0, 2, 1, 3)
