"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal=True):
    """q: (B,H,S,dh); k/v: (B,KV,S,dh). Naive softmax attention, fp32."""
    b, h, s, dh = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, s, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgqd,bkpd->bkgqp", qg, kf) / (dh ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqp,bkpd->bkgqd", p, vf)
    return o.reshape(b, h, s, dh).astype(q.dtype)
