"""Chunked diagonal-decay linear scan (GLA/SSD) — Pallas TPU kernel.

Serves both RWKV6 (per-channel data-dependent decay + bonus u, pre-update
read) and Mamba2 (scalar-per-head decay broadcast over the state dim,
post-update read). The recurrence

    S_t = diag(w_t) S_{t-1} + k_t^T v_t,    o_t = q_t . S_{t(-1)} (+ u-term)

is evaluated chunk-parallel: within a chunk of length c the strictly-causal
part is an MXU matmul against decay-normalized q~/k^ tensors; across chunks
the (dk x dv) state is carried in VMEM scratch (TPU grids run sequentially,
so scratch persists along the chunk axis).

Grid (B, H, nC). VMEM per step (c = 128, dk = dv = 128, fp32):
  4 input blocks (c x dk) + attn (c x c) + state (dk x dv)  ~ 0.4 MB.

Numerics envelope (standard GLA practice): decay ratios are factored as
(q . L_t)(k / L_s) *within one chunk only*, so the dynamic range is
exp(chunk x |log w|_max). Per-step log-decay is floored at -2.5 (w >= 0.082)
which bounds the range at exp(80) < fp32 max for chunk = 32. Signals passing
a true w < 0.082 step are attenuated > 12x per step, so the floor's output
error is < 1e-3 relative; production decays (Mamba2/RWKV6: w >= ~0.9) sit
far inside the envelope. The ref oracle (exact recurrence) has no envelope.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_LOG_FLOOR = -2.5  # per-step log-decay floor; see numerics envelope above


def _scan_kernel(q_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, state,
                 *, chunk, bonus):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    q = q_ref[0, 0].astype(jnp.float32)            # (c, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)            # (c, dv)
    w = jnp.clip(w_ref[0, 0].astype(jnp.float32), 1e-8, 1.0)

    logw = jnp.maximum(jnp.log(w), _LOG_FLOOR)
    clog = jnp.cumsum(logw, axis=0)
    l_cum = jnp.exp(clog)
    l_tot = jnp.exp(clog[-1:, :])                  # (1, dk)

    q_tilde = q * (jnp.exp(clog - logw) if bonus else l_cum)
    k_div = k * jnp.exp(-clog)
    k_hat = k * jnp.exp(clog[-1:, :] - clog)

    attn = jax.lax.dot_general(q_tilde, k_div, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    attn = jnp.where(row > col, attn, 0.0)         # strictly causal

    if bonus:
        u = u_ref[0].astype(jnp.float32)           # (1, dk) -> broadcast
        diag_val = jnp.sum(q * u * k, axis=1, keepdims=True)
    else:
        diag_val = jnp.sum(q * k, axis=1, keepdims=True)

    o_intra = jax.lax.dot_general(attn, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32) \
        + diag_val * v
    o_inter = jax.lax.dot_general(q_tilde, state[...],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    o_ref[0, 0] = (o_intra + o_inter).astype(o_ref.dtype)

    state[...] = state[...] * l_tot.T + jax.lax.dot_general(
        k_hat, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _emit_state():
        s_ref[0, 0] = state[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "bonus", "interpret"))
def linear_scan(q, k, v, w, u=None, *, chunk=32, bonus=False,
                interpret=False):
    """q,k,w: (B,H,S,dk); v: (B,H,S,dv); u: (H,dk) if bonus.

    Returns (o: (B,H,S,dv), final_state: (B,H,dk,dv) fp32). Initial state is
    zero (prefill-from-scratch); carries are handled by the jnp chunked path.
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    if u is None:
        u = jnp.zeros((h, dk), jnp.float32)

    kernel = functools.partial(_scan_kernel, chunk=chunk, bonus=bonus)
    grid = (b, h, nc)
    o, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dk), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, dk), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, dv), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, dk), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, dk), lambda bi, hi, ci: (hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, dv), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, dv), v.dtype),
            jax.ShapeDtypeStruct((b, h, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, w, u)
    return o, state
