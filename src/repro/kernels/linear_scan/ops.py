"""Jit'd public wrapper for the chunked linear scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.linear_scan import ref
from repro.kernels.linear_scan.linear_scan import linear_scan as _kernel


@functools.partial(jax.jit, static_argnames=("backend", "chunk"))
def linear_scan(q, k, v, w, u=None, *, backend="interpret", chunk=128):
    """Dispatch: 'interpret' (Pallas on CPU), 'tpu' (Pallas compiled), 'ref'."""
    if backend == "ref":
        return ref.linear_scan_ref(q, k, v, w, u)
    return _kernel(q, k, v, w, u, chunk=chunk, bonus=u is not None,
                   interpret=(backend == "interpret"))
