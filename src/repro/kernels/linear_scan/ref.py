"""Pure-jnp oracle for the linear scan kernel: exact step-by-step recurrence."""
from repro.models.scan_ops import linear_scan_recurrent


def linear_scan_ref(q, k, v, w, u=None):
    """Exact recurrence (jax.lax.scan over time). Returns (o, final_state)."""
    return linear_scan_recurrent(q, k, v, w, u)
