"""Pure-jnp oracle for the fused score sketch (scatter-add formulation)."""
from __future__ import annotations

import jax.numpy as jnp


def score_hist_ref(scores, num_bins=4096):
    scores = jnp.asarray(scores, jnp.float32)
    valid = scores >= 0.0
    a = jnp.clip(scores, 0.0, 1.0)
    ids = jnp.minimum((a * num_bins).astype(jnp.int32), num_bins - 1)
    vm = valid.astype(jnp.float32)
    counts = jnp.zeros(num_bins, jnp.float32).at[ids].add(vm)
    sum_w = jnp.zeros(num_bins, jnp.float32).at[ids].add(jnp.sqrt(a) * vm)
    sum_a = jnp.zeros(num_bins, jnp.float32).at[ids].add(a * vm)
    return counts, sum_w, sum_a
