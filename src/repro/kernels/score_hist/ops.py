"""Jit'd public wrapper for the fused score sketch.

`backend="auto"` (the default) runs the Pallas kernel compiled on TPU and
falls back to `interpret=True` emulation everywhere else, so callers can
treat the fused kernel as the default sketch path without platform checks.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.score_hist import ref
from repro.kernels.score_hist.score_hist import _BIN_TILE
from repro.kernels.score_hist.score_hist import score_hist as _kernel


def kernel_supported(num_bins: int) -> bool:
    """Whether the fused kernel's bin-tile layout covers this bin count."""
    return num_bins % _BIN_TILE == 0


@functools.partial(jax.jit,
                   static_argnames=("num_bins", "backend", "block_n"))
def score_hist(scores, num_bins=4096, *, backend="auto", block_n=2048):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    if backend == "ref":
        return ref.score_hist_ref(scores, num_bins)
    return _kernel(scores, num_bins=num_bins, block_n=block_n,
                   interpret=(backend == "interpret"))
