"""Jit'd public wrapper for the fused score sketch."""
from __future__ import annotations

import functools

import jax

from repro.kernels.score_hist import ref
from repro.kernels.score_hist.score_hist import score_hist as _kernel


@functools.partial(jax.jit,
                   static_argnames=("num_bins", "backend", "block_n"))
def score_hist(scores, num_bins=4096, *, backend="interpret", block_n=2048):
    if backend == "ref":
        return ref.score_hist_ref(scores, num_bins)
    return _kernel(scores, num_bins=num_bins, block_n=block_n,
                   interpret=(backend == "interpret"))
