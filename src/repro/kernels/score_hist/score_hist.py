"""Fused score-sketch histogram — the SUPG selection plane's HBM hot loop.

One pass over a proxy-score shard produces, per histogram bin b:
    counts[b] = |{x : A(x) in bin b}|
    sum_w[b]  = sum of sqrt(A(x))     (Theorem-1 weight normalizer)
    sum_a[b]  = sum of A(x)           ('prop' baseline normalizer)

The pure-jnp path needs one scatter-add pass per statistic; this kernel
fuses all three into a single streaming read — at ~1e9 scores the pass is
HBM-bandwidth-bound (4 GB read, ~5 ms/chip at 819 GB/s), so halving passes
halves selection-plane latency.

Layout: grid (n_blocks,); each step streams one (1, block_n) score block
into VMEM and accumulates a (4, num_bins) fp32 sketch that lives entirely
in VMEM (num_bins = 4096 -> 64 KiB) across the sequential grid; bin
membership is resolved as 8 one-hot (block_n x 512) masks driving MXU
matmuls (bins_tile = 512 keeps each mask at 2 MiB fp32). Row 3 of the
output is the in-range count used to cross-check padding handling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIN_TILE = 512


def _hist_kernel(s_ref, o_ref, *, num_bins, block_n):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s = s_ref[0].astype(jnp.float32)                   # (block_n,)
    valid = (s >= 0.0).astype(jnp.float32)             # padding marked -1
    a = jnp.clip(s, 0.0, 1.0)
    ids = jnp.minimum((a * num_bins).astype(jnp.int32), num_bins - 1)
    stats = jnp.stack([valid, jnp.sqrt(a) * valid, a * valid, valid],
                      axis=0)                          # (4, block_n)

    for t in range(num_bins // _BIN_TILE):
        lo = t * _BIN_TILE
        tile_ids = lo + jax.lax.broadcasted_iota(
            jnp.int32, (block_n, _BIN_TILE), 1)
        onehot = (ids[:, None] == tile_ids).astype(jnp.float32)
        contrib = jax.lax.dot_general(
            stats, onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (4, _BIN_TILE)
        o_ref[:, lo:lo + _BIN_TILE] += contrib


@functools.partial(jax.jit, static_argnames=("num_bins", "block_n",
                                             "interpret"))
def score_hist(scores, num_bins=4096, block_n=2048, interpret=False):
    """scores: (N,) float in [0,1] (entries < 0 are ignored padding).

    Returns (counts, sum_w, sum_a) each (num_bins,) float32.
    """
    assert num_bins % _BIN_TILE == 0
    n = scores.shape[0]
    pad = (-n) % block_n
    if pad:
        scores = jnp.concatenate(
            [scores, jnp.full((pad,), -1.0, scores.dtype)])
    nb = scores.shape[0] // block_n
    blocks = scores.reshape(nb, block_n)

    kernel = functools.partial(_hist_kernel, num_bins=num_bins,
                               block_n=block_n)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block_n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((4, num_bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((4, num_bins), jnp.float32),
        interpret=interpret,
    )(blocks)
    return out[0], out[1], out[2]
