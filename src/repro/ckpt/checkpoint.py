"""Sharded, atomic, elastic checkpointing.

Fault-tolerance contract:
  * ATOMIC: a checkpoint directory appears only fully written — staged under
    `<dir>/tmp.<step>` and os.replace()'d into place (crash-safe on POSIX);
  * SHARDED: each host writes only the shards it owns (`process_index`
    namespacing); single-process runs write everything;
  * RESUMABLE: restore() returns (params, opt_state, step); the data
    pipeline is deterministic in step, so restart is exact;
  * ELASTIC: save() records the logical PartitionSpec tree, not device
    placements — restore(mesh=...) re-shards onto whatever mesh the new job
    has (grow/shrink pods without converting checkpoints);
  * BOUNDED: keep the last k checkpoints, delete older ones only after the
    newest is durable;
  * ASYNC: save_async() snapshots to host RAM synchronously (cheap) and
    writes to disk on a background thread — training continues immediately.

Scope note: this is the legacy *training-state* checkpointer (model
params + optimizer state for the proxy-training loop). Durability of the
*selection plane* — corpus epochs, standing-query certifications, tenant
ledgers — lives in `repro.durable` (`DurabilityPlane`,
`SelectionServer.snapshot()/restore()`), which this module's atomic
publish now delegates to (`repro.durable.atomic.publish_dir`). New
crash-recovery surface belongs there, not here.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Optional

import jax
import numpy as np

from repro.durable.atomic import publish_dir


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, process_index: int = 0):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.process_index = process_index
        self._async_thread: Optional[threading.Thread] = None

    # -- public API ---------------------------------------------------------

    def save(self, step: int, params, opt_state=None, extra: dict = None):
        self._wait_async()
        self._save_sync(step, params, opt_state, extra or {})

    def save_async(self, step: int, params, opt_state=None,
                   extra: dict = None):
        self._wait_async()
        # snapshot to host memory NOW (device buffers may be donated later)
        host = jax.tree.map(np.asarray, (params, opt_state))
        extra = dict(extra or {})

        def run():
            self._save_sync(step, host[0], host[1], extra)

        self._async_thread = threading.Thread(target=run, daemon=True)
        self._async_thread.start()

    def restore(self, step: Optional[int] = None, mesh=None, specs=None):
        """Returns (params, opt_state, step, extra). With mesh+specs the
        leaves are device_put with NamedSharding(mesh, spec) — elastic
        re-sharding onto the current topology."""
        self._wait_async()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:010d}"
        with open(d / "manifest.json") as f:
            manifest = json.load(f)
        data = np.load(d / f"shard_{self.process_index:05d}.npz",
                       allow_pickle=False)
        leaves = [data[f"arr_{i}"] for i in range(manifest["num_leaves"])]
        treedef = jax.tree_util.tree_structure(
            _skeleton(manifest["treedef_repr"]))
        if treedef is None:
            raise ValueError("corrupt manifest")
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        params, opt_state = tree
        if mesh is not None and specs is not None:
            from jax.sharding import NamedSharding
            params = jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                params, specs)
        return params, opt_state, step, manifest.get("extra", {})

    def latest_step(self) -> Optional[int]:
        self._wait_async()
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if p.is_dir())
        return steps[-1] if steps else None

    def all_steps(self):
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if p.is_dir())

    # -- internals -----------------------------------------------------------

    def _save_sync(self, step, params, opt_state, extra):
        tree = (params, opt_state)
        leaves, treedef = _flatten(jax.tree.map(np.asarray, tree))
        tmp = self.dir / f"tmp.{step:010d}.{self.process_index}"
        final = self.dir / f"step_{step:010d}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / f"shard_{self.process_index:05d}.npz",
                 **{f"arr_{i}": leaf for i, leaf in enumerate(leaves)})
        manifest = {
            "step": step,
            "num_leaves": len(leaves),
            "treedef_repr": _skeleton_repr(tree),
            "extra": extra,
        }
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
        if final.exists():
            shutil.rmtree(final)
        publish_dir(tmp, final)         # atomic publish (rename + dir fsync)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)
        # clean stale tmp dirs from crashed writers
        for p in self.dir.glob("tmp.*"):
            shutil.rmtree(p, ignore_errors=True)

    def _wait_async(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None


# treedef round-trip: store a structural skeleton (nested dict/list/None)
# so restore() does not need pickle (portable + safe).

def _skeleton_repr(tree):
    def conv(x):
        if isinstance(x, dict):
            return {"__d__": {k: conv(v) for k, v in x.items()}}
        if isinstance(x, (list, tuple)):
            tag = "__t__" if isinstance(x, tuple) else "__l__"
            named = type(x).__name__ if hasattr(x, "_fields") else None
            return {tag: [conv(v) for v in x], "named": named}
        return "__leaf__" if x is not None else None

    return conv(tree)


def _skeleton(rep):
    from repro.optim.adamw import AdamWState

    def conv(x):
        if x is None:
            return None
        if x == "__leaf__":
            return 0
        if "__d__" in x:
            return {k: conv(v) for k, v in x["__d__"].items()}
        for tag, ctor in (("__t__", tuple), ("__l__", list)):
            if tag in x:
                vals = [conv(v) for v in x[tag]]
                if x.get("named") == "AdamWState":
                    return AdamWState(*vals)
                return ctor(vals)
        raise ValueError(f"bad skeleton node {x!r}")

    return conv(rep)
