"""repro — SUPG approximate selection framework (JAX, multi-pod)."""
__version__ = "1.0.0"
