"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.

38L d_model=2048, ssm_state=64, shared attn 32H (kv=32, hd=64) + MLP
d_ff=8192, reused every 6 Mamba2 layers [arXiv:2411.15242; hf].
Sub-quadratic backbone: long_500k RUNS (decode attention is O(S) per token,
Mamba state is O(1)).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    block="mamba", ssm_state_dim=64, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=6, remat="block",
    # dp REFUTED: per-invocation shared-block weight gathers + conv-state
    # layouts cost 19.1 s vs 1.8 s TP (EXPERIMENTS §Perf iteration 4)
)


def smoke():
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128,
        block="mamba", ssm_state_dim=16, ssm_head_dim=16, ssm_expand=2,
        shared_attn_every=2, dtype="float32",
    )
