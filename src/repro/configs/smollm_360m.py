"""smollm-360m [dense] — llama-arch small; the cheap-proxy tier of the SUPG
model zoo. 32L d=960 15H (kv=5) d_ff=2560 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
    d_ff=2560, vocab_size=49152, tie_embeddings=True, remat="block",
    train_parallelism="dp",
)


def smoke():
    return ModelConfig(
        name="smollm-smoke", family="dense",
        num_layers=2, d_model=60, num_heads=3, num_kv_heads=1,
        d_ff=128, vocab_size=128, tie_embeddings=True, dtype="float32",
    )
