"""Architecture registry: --arch <id> resolution for launchers and tests."""
from __future__ import annotations

import importlib

from repro.configs.base import (SHAPES, SHAPES_BY_NAME, ModelConfig,
                                ShapeConfig, shape_applicable)

_MODULES = {
    "musicgen-medium": "repro.configs.musicgen_medium",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "yi-6b": "repro.configs.yi_6b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "smollm-360m": "repro.configs.smollm_360m",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch_id]).smoke()


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "SHAPES_BY_NAME",
           "ARCH_IDS", "get_config", "get_smoke_config", "shape_applicable"]
