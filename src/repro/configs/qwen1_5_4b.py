"""qwen1.5-4b [dense] — QKV bias. 40L d=2560 20H (kv=20) d_ff=6912
vocab=151936 [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
    d_ff=6912, vocab_size=151936, qkv_bias=True, remat="block",
    # dp REFUTED for this arch: the 152k-vocab embedding/head gathers under
    # pure-DP cost 255 s of collectives vs 15.6 s TP (EXPERIMENTS §Perf it.4)
)


def smoke():
    return ModelConfig(
        name="qwen-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, qkv_bias=True, dtype="float32",
    )
