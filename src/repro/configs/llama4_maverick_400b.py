"""llama4-maverick-400b-a17b [moe] — interleaved MoE (every 2nd layer),
top-1 routing + shared expert, early fusion (patch embeds stubbed: token
stream precomputed). 48L d=5120 40H (GQA kv=8) d_ff=8192 vocab=202048,
128 routed experts [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].
Sigmoid router gate (llama4 uses per-expert sigmoid, not softmax).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    moe=True, num_experts=128, num_experts_per_tok=1,
    num_shared_experts=1, moe_d_ff=8192, dense_d_ff=8192, moe_layer_step=2,
    rope_theta=500_000.0, remat="block",
)


def smoke():
    return ModelConfig(
        name="llama4-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=128,
        moe=True, num_experts=4, num_experts_per_tok=1,
        num_shared_experts=1, moe_d_ff=128, dense_d_ff=128, moe_layer_step=2,
        dtype="float32",
    )
