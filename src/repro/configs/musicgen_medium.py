"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (GQA kv=24 => MHA) d_ff=6144 vocab=2048
[arXiv:2306.05284; hf]. The EnCodec frontend is a stub: inputs are
precomputed 4-codebook token streams (B, S, K=4); embeddings are summed and
K parallel LM heads predict each codebook (the delay-pattern scheduler is
outside the backbone).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, num_codebooks=4,
    act="gelu", tie_embeddings=False, remat="block",
    train_parallelism="dp",
)


def smoke():
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128, num_codebooks=4,
        act="gelu", dtype="float32",
    )
