"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay.

32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 [arXiv:2404.05892; hf].
head_size 64 => 64 wkv heads. Sub-quadratic: long_500k RUNS (O(1) state).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=14336, vocab_size=65536,
    block="rwkv", ssm_head_dim=64, rwkv_lora_dim=64,
    remat="block", train_parallelism="dp",
)


def smoke():
    return ModelConfig(
        name="rwkv6-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=128, vocab_size=128,
        block="rwkv", ssm_head_dim=16, rwkv_lora_dim=8, dtype="float32",
    )
