"""Model/arch configuration system + the assigned input-shape suite.

Every assigned architecture gets a frozen `ModelConfig` in its own module
(src/repro/configs/<id>.py) with the exact published hyperparameters, plus a
`smoke()` reduced config of the same family for CPU tests.

Input shapes (assigned suite — seq_len x global_batch):
    train_4k     4,096 x 256   -> train_step
    prefill_32k  32,768 x 32   -> serve_step (prefill scoring)
    decode_32k   32,768 x 128  -> serve_step (1 new token, KV cache = seq_len)
    long_500k    524,288 x 1   -> serve_step decode; sub-quadratic archs only
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                  # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # --- attention variants ---
    qkv_bias: bool = False          # qwen1.5
    qk_norm: bool = False           # chameleon
    rope_theta: float = 10_000.0

    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    moe: bool = False
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim
    dense_d_ff: int = 0             # hidden dim of dense (non-MoE) layers
    first_k_dense: int = 0          # deepseek-v2: leading dense layers
    moe_layer_step: int = 1         # llama4: MoE every k-th layer
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # --- SSM / linear attention ---
    block: str = "attn"             # attn | rwkv | mamba
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    shared_attn_every: int = 0      # zamba2: shared attn+MLP block period
    rwkv_lora_dim: int = 32

    # --- modality stubs ---
    num_codebooks: int = 1          # musicgen EnCodec codebooks

    # --- common ---
    act: str = "silu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "none"             # none | block (activation checkpointing)
    unroll_layers: bool = False     # python-loop layers (dry-run cost probes)
    shard_activations: bool = False  # with_sharding_constraint on logits/CE
    train_parallelism: str = "tp"   # tp | dp — dp = pure ZeRO-3 over all
    # axes for training (small/attention-free archs: activation TP costs
    # ~30 full-activation collectives/layer; weight gathers are cheaper)

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)

    @property
    def attention_free(self) -> bool:
        return self.block in ("rwkv", "mamba") and self.shared_attn_every == 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / linear attention)."""
        return self.block in ("rwkv", "mamba")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k only runs for sub-quadratic archs (see DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: pure full-attention arch; long_500k requires "
                       "sub-quadratic sequence mixing")
    return True, ""
