"""deepseek-v2-236b [moe] — MLA + fine-grained MoE; the oracle-grade scorer.

60L d_model=5120 128H (MLA kv_lora=512, rope=64, nope=128, v=128,
q_lora=1536) moe_d_ff=1536, 2 shared + 160 routed top-6, first layer dense
(dense d_ff=12288), vocab=102400 [arXiv:2405.04434; hf].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=1536, vocab_size=102400,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    moe=True, num_experts=160, num_experts_per_tok=6,
    num_shared_experts=2, moe_d_ff=1536, dense_d_ff=12288, first_k_dense=1,
    remat="block",
)


def smoke():
    return ModelConfig(
        name="dsv2-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=96, vocab_size=128,
        use_mla=True, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        moe=True, num_experts=8, num_experts_per_tok=2,
        num_shared_experts=1, moe_d_ff=96, dense_d_ff=128, first_k_dense=1,
        dtype="float32",
    )
