"""chameleon-34b [vlm] — early-fusion over VQ image + text tokens.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536
[arXiv:2405.09818; unverified]. Early fusion means image VQ codes live in
the shared vocab — the backbone consumes one token stream; the VQGAN
tokenizer is a stub (tokens precomputed). Chameleon's qk-norm is enabled
(its training-stability contribution).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=65536, qk_norm=True,
    remat="block", train_parallelism="dp",
)


def smoke():
    return ModelConfig(
        name="chameleon-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=128, qk_norm=True, dtype="float32",
    )
