"""Gradient compression for cross-pod all-reduce: int8 quantization with
error feedback.

At 512+ chips the inter-pod hop is the thin pipe (data-center links between
pods are ~10x slower than in-pod ICI). The standard mitigation is a
hierarchical all-reduce — full-precision reduce inside the pod, compressed
across pods — with error-feedback residuals so quantization noise does not
accumulate in the optimizer (it provably converges like SGD for smooth
objectives; Karimireddy et al. 2019).

`compressed_psum(mesh, grads, residuals)` implements exactly that pattern
with jax collectives:

    g_pod   = psum(g, ("data",))                  # fp32, in-pod ICI
    q, res  = quantize_int8(g_pod + residual)
    g_all   = psum(dequant(q), ("pod",))          # 4x fewer bytes inter-pod
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """Per-tensor symmetric int8 quantization. Returns (q, scale, residual)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    residual = xf - q.astype(jnp.float32) * scale
    return q, scale, residual


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residuals=None):
    """Quantize every leaf with error feedback. Returns (q_tree, new_res)."""
    if residuals is None:
        residuals = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                 grads)
    fed = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                       grads, residuals)
    qs = jax.tree.map(quantize_int8, fed,
                      is_leaf=lambda x: isinstance(x, jnp.ndarray))
    q_tree = jax.tree.map(lambda t: (t[0], t[1]), qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[2], qs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return q_tree, new_res


def hierarchical_psum(grads, *, in_pod_axes=("data",), cross_pod_axis="pod",
                      compress=True, residuals=None):
    """Inside shard_map: fp32 psum in-pod, int8 psum across pods.

    Returns (reduced_grads, new_residuals). With compress=False this is a
    plain two-hop psum (still useful: the in-pod reduction halves the
    cross-pod payload per chip by pre-combining).
    """
    g_pod = jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.float32), in_pod_axes), grads)
    if not compress:
        out = jax.tree.map(lambda g: jax.lax.psum(g, cross_pod_axis), g_pod)
        return out, residuals

    if residuals is None:
        residuals = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                 g_pod)

    def reduce_leaf(g, r):
        q, scale, new_r = quantize_int8(g + r)
        # int8 payload over the cross-pod links; scales are scalars.
        total = jax.lax.psum(q.astype(jnp.float32) * scale, cross_pod_axis)
        return total, new_r

    pairs = jax.tree.map(reduce_leaf, g_pod, residuals)
    out = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return out, new_res
