"""AdamW + cosine schedule + global-norm clipping — pure pytree functions.

Optimizer state is a pytree congruent with params; under pjit it inherits
the param shardings (ZeRO-1 sharding over the data axis is applied by
launch/sharding.zero1_specs, which extends each param's spec with the data
axis on its largest divisible dimension).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply(cfg: AdamWConfig, params, grads, state: AdamWState):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state.mu, grads)
    new_nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g,
                          state.nu, grads)

    def upd(p, m, n):
        mhat = m / bc1
        nhat = n / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_mu, new_nu)
    return new_params, AdamWState(step, new_mu, new_nu), \
        {"grad_norm": gnorm, "lr": lr}
