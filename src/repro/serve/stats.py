"""Serving-plane observability: counters, latency histograms, snapshots.

Everything here is cheap enough to update on every request and snapshot
on demand: counters are plain ints behind the server's lock, and
latencies go into a fixed-size log-spaced histogram (`LatencyHistogram`)
whose quantiles are read without storing per-request samples — the
standard serving-metrics shape (a query's p99 must not cost O(queries)
memory to know).

`ServerStats` is the exported snapshot: per-tenant counters (admitted /
rejected / timed out / completed / failed, oracle records charged),
channel totals (fn calls, records labeled, cache hits, throttle wait),
scheduler overlap accounting aggregated from the session pool, and
p50/p99 end-to-end latency. `SelectionServer.stats()` builds one;
`format()` renders the table the example prints.

>>> h = LatencyHistogram()
>>> for ms in (1, 2, 3, 100):
...     h.record(ms / 1e3)
>>> h.count, h.quantile(0.5) <= h.quantile(0.99)
(4, True)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional


class LatencyHistogram:
    """Log-spaced latency histogram with O(1) record and quantile reads.

    Buckets span 1 µs .. ~1000 s at 10 buckets/decade (91 bins), which
    resolves quantiles to within ~26% — ample for p50/p99 serving
    dashboards. `record` takes seconds; quantile reads return seconds
    (the bucket's upper edge, so reported latency never understates).
    """

    DECADES = 9           # 1e-6 .. 1e3 seconds
    PER_DECADE = 10

    def __init__(self):
        self._counts = [0] * (self.DECADES * self.PER_DECADE + 1)
        self.count = 0
        self.total_s = 0.0
        self.max_s = 0.0

    def _bucket(self, seconds: float) -> int:
        if seconds <= 1e-6:
            return 0
        pos = (math.log10(seconds) + 6.0) * self.PER_DECADE
        return min(len(self._counts) - 1, max(0, int(math.ceil(pos))))

    def record(self, seconds: float) -> None:
        """Add one observation (in seconds)."""
        self._counts[self._bucket(seconds)] += 1
        self.count += 1
        self.total_s += seconds
        self.max_s = max(self.max_s, seconds)

    def quantile(self, q: float) -> float:
        """Approximate `q`-quantile in seconds (upper bucket edge)."""
        if self.count == 0:
            return 0.0
        rank = min(self.count, max(1, int(math.ceil(q * self.count))))
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= rank:
                return 10.0 ** (i / self.PER_DECADE - 6.0)
        return self.max_s

    @property
    def mean_s(self) -> float:
        """Mean observed latency in seconds."""
        return self.total_s / self.count if self.count else 0.0


@dataclasses.dataclass
class TenantStats:
    """Per-tenant serving counters (one row of the `ServerStats` table)."""

    tenant: str
    quota: Optional[int] = None      # None = unmetered
    submitted: int = 0               # submit() calls accepted into the plane
    admitted: int = 0                # entered a session (left the queue)
    rejected: int = 0                # refused at submit (overflow queue full)
    shed: int = 0                    # refused at submit (circuit open)
    timed_out: int = 0               # expired waiting in the overflow queue
    completed: int = 0               # finished with a result
    failed: int = 0                  # finished with an error (budget/quota/..)
    oracle_charged: int = 0          # fn labels attributed to this tenant

    @property
    def in_flight(self) -> int:
        """Accepted queries not yet finished."""
        return self.submitted - self.rejected - self.shed - self.timed_out \
            - self.completed - self.failed


@dataclasses.dataclass
class ServerStats:
    """One consistent snapshot of a `SelectionServer`'s counters.

    `tenants` maps tenant name to its `TenantStats`; the scalar fields
    aggregate the channel (`oracle_calls`, `records_labeled`,
    `cache_hits`, `throttle_wait_s`), the channel's resilience layer
    (`retries`, `timeouts`, `batch_failures`, `batch_sheds`, plus the
    breaker's `circuit_state`/`circuit_opens`), the session pool's scheduler
    accounting (`rounds`, `drains`, `overlap_hidden_s`), and end-to-end
    query latency (`p50_s`/`p99_s`, measured submit -> result-ready,
    queue wait included).
    """

    tenants: Dict[str, TenantStats]
    queued: int = 0                  # waiting in the overflow queue now
    in_flight: int = 0               # admitted into sessions now
    oracle_calls: int = 0            # underlying fn invocations
    records_labeled: int = 0
    cache_hits: int = 0
    throttle_wait_s: float = 0.0     # time drains spent inside the bucket
    rounds: int = 0                  # session scheduler turns
    drains: int = 0                  # coalesced drains launched
    overlap_hidden_s: float = 0.0    # oracle latency hidden under compute
    completed: int = 0
    failed: int = 0
    p50_s: float = 0.0
    p99_s: float = 0.0
    mean_s: float = 0.0
    retries: int = 0                 # oracle calls re-attempted
    timeouts: int = 0                # oracle calls killed by the watchdog
    batch_failures: int = 0          # micro-batches that exhausted retries
                                     # (or failed fatally) — excludes sheds
    batch_sheds: int = 0             # micro-batches shed by the open circuit
    circuit_state: str = "closed"    # breaker state at snapshot time
    circuit_opens: int = 0           # closed -> open transitions so far
    epochs: int = 0                  # corpus appends installed (live plane)
    records_ingested: int = 0        # records those appends added
    standing_queries: int = 0        # registered standing queries
    standing_emissions: int = 0      # catch-up re-emission walks completed
    sentinel_checks: int = 0         # drift probes run
    sentinel_triggers: int = 0       # probes that flagged drift
    revalidations: int = 0           # re-validation queries auto-submitted
    durable: bool = False            # a durability plane is attached
    epochs_live: int = 1             # epochs still holding host memory
    epochs_freed: int = 0            # superseded epochs GC'd so far
    journal_records: int = 0         # valid epoch-journal records on disk
    journal_bytes: int = 0           # valid epoch-journal bytes on disk
    snapshots: int = 0               # snapshot() publishes this process
    recovered_epochs: int = 0        # epochs replayed at restore()
    recovered_queries: int = 0       # standing queries re-adopted at restore()

    @property
    def admitted(self) -> int:
        """Total queries admitted across tenants."""
        return sum(t.admitted for t in self.tenants.values())

    @property
    def rejected(self) -> int:
        """Total queries rejected at submit across tenants."""
        return sum(t.rejected for t in self.tenants.values())

    @property
    def circuit_shed(self) -> int:
        """Total admissions refused because the circuit was open."""
        return sum(t.shed for t in self.tenants.values())

    @property
    def timed_out(self) -> int:
        """Total queue-timeout expiries across tenants."""
        return sum(t.timed_out for t in self.tenants.values())

    def format(self) -> str:
        """Render the human-readable snapshot the example prints."""
        lines = [
            f"queries: {self.admitted} admitted, {self.completed} "
            f"completed, {self.failed} failed, {self.rejected} rejected, "
            f"{self.timed_out} timed out "
            f"({self.queued} queued, {self.in_flight} in flight)",
            f"latency: p50 {self.p50_s * 1e3:.1f} ms, "
            f"p99 {self.p99_s * 1e3:.1f} ms, "
            f"mean {self.mean_s * 1e3:.1f} ms",
            f"oracle:  {self.oracle_calls} calls, "
            f"{self.records_labeled} records labeled, "
            f"{self.cache_hits} cache hits, "
            f"throttled {self.throttle_wait_s * 1e3:.1f} ms",
            f"session: {self.rounds} rounds, {self.drains} drains, "
            f"{self.overlap_hidden_s * 1e3:.1f} ms oracle latency "
            f"hidden under compute",
            f"resilience: {self.retries} retries, {self.timeouts} "
            f"timeouts, {self.batch_failures} failed micro-batches, "
            f"{self.batch_sheds} shed micro-batches, "
            f"circuit {self.circuit_state} "
            f"({self.circuit_opens} opens, "
            f"{self.circuit_shed} admissions shed)",
            f"live:    {self.epochs} epochs, {self.records_ingested} "
            f"records ingested, {self.standing_queries} standing queries "
            f"({self.standing_emissions} re-emissions), sentinel "
            f"{self.sentinel_checks} checks / {self.sentinel_triggers} "
            f"triggers / {self.revalidations} re-validations",
            f"durable: {'on' if self.durable else 'off'}, "
            f"{self.journal_records} journal records "
            f"({self.journal_bytes} B), {self.snapshots} snapshots, "
            f"epochs {self.epochs_live} live / {self.epochs_freed} freed, "
            f"recovered {self.recovered_epochs} epochs / "
            f"{self.recovered_queries} queries",
        ]
        for name in sorted(self.tenants):
            t = self.tenants[name]
            quota = "unmetered" if t.quota is None else (
                f"{t.oracle_charged}/{t.quota} labels")
            lines.append(
                f"tenant {name!r}: {t.completed}/{t.submitted} completed "
                f"({t.failed} failed, {t.rejected} rejected, "
                f"{t.shed} shed, {t.timed_out} timed out), {quota}")
        return "\n".join(lines)
