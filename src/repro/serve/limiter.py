"""Token-bucket rate limiting for the oracle channel.

The paper's Section 4.1 operational model treats the oracle as a
*rate-limited, budgeted* external resource — a human labeling queue or an
expensive model endpoint that tolerates at most R records/second with
short bursts. `TokenBucket` makes that limit literal: the serving plane
hands one to `core.oracle.BatchingOracle` as its ``pacer`` hook, so every
underlying ``fn`` micro-batch first acquires as many tokens as it has
records. Because the hook runs on the channel's drain thread (under
`drain_async`), pacing throttles oracle I/O while query-plan compute
keeps overlapping it — the double-buffered scheduler never blocks on the
bucket directly.

Semantics are deterministic and test-friendly:

  * capacity (`burst`) bounds a single acquire — a request larger than
    the bucket can ever hold fails immediately with `RateLimitError`
    instead of deadlocking (the zero-capacity bucket is the degenerate
    case: every nonzero acquire is rejected);
  * the clock and sleep functions are injectable, so tests drive time
    by hand;
  * `wait_s` / `acquired` account total throttle wait and tokens
    granted — the serving plane's `ServerStats` reads them.

>>> t = [0.0]
>>> bucket = TokenBucket(rate=10.0, burst=5,
...                      clock=lambda: t[0],
...                      sleep=lambda s: t.__setitem__(0, t[0] + s))
>>> bucket.acquire(5)            # burst capacity: no wait
0.0
>>> round(bucket.acquire(3), 3)  # empty: 3 tokens at 10/s = 0.3 s
0.3
>>> bucket.acquired
8
"""
from __future__ import annotations

import threading
import time
from typing import Callable


class RateLimitError(RuntimeError):
    """A single acquire exceeds the bucket's capacity (can never succeed).

    `retryable` is False: `core.resilience.is_retryable` duck-types this
    attribute, so a channel's retry loop fails the micro-batch alone
    (poisoning only its owners) instead of re-running an acquire that
    can never be granted.
    """

    retryable = False


# Grant tolerance: refill arithmetic (`(now - last) * rate`) leaves float
# residue, and a deficit below the clock's ulp would otherwise spin the
# acquire loop forever (sleep too small to advance the clock).
_EPS = 1e-9


class TokenBucket:
    """Classic token bucket: `rate` tokens/second, capacity `burst`.

    `acquire(n)` blocks until `n` tokens are available, removes them, and
    returns the seconds it waited. Thread-safe — concurrent acquirers
    serialize on one lock and sleep outside their turn's refill math, so
    a stalled oracle drain never wedges other channel users.
    """

    def __init__(self, rate: float, burst: float, *,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        if not rate > 0:
            raise ValueError("rate must be positive (tokens per second)")
        if burst < 0:
            raise ValueError("burst (capacity) must be >= 0")
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._sleep = sleep
        self._tokens = float(burst)       # start full: allow initial burst
        self._last = clock()
        self._lock = threading.Lock()
        self.wait_s = 0.0                 # total time spent throttled
        self.acquired = 0                 # tokens granted so far

    def _refill_locked(self) -> None:
        now = self._clock()
        self._tokens = min(self.burst,
                           self._tokens + (now - self._last) * self.rate)
        self._last = now

    def try_acquire(self, n: int = 1) -> bool:
        """Take `n` tokens if immediately available; never blocks."""
        if n <= 0:
            return True
        with self._lock:
            if n > self.burst:
                return False
            self._refill_locked()
            if self._tokens + _EPS >= n:
                self._tokens = max(0.0, self._tokens - n)
                self.acquired += int(n)
                return True
            return False

    def acquire(self, n: int = 1) -> float:
        """Block until `n` tokens are available; returns seconds waited.

        Raises `RateLimitError` when `n` exceeds the bucket's capacity —
        including every nonzero acquire on a zero-capacity bucket — since
        no amount of waiting could ever satisfy the request.
        """
        if n <= 0:
            return 0.0
        waited = 0.0
        while True:
            with self._lock:
                if n > self.burst:
                    raise RateLimitError(
                        f"acquire({n}) exceeds bucket capacity "
                        f"{self.burst:g}: the request can never be "
                        f"satisfied — lower the batch size or raise burst")
                self._refill_locked()
                if self._tokens + _EPS >= n:
                    self._tokens = max(0.0, self._tokens - n)
                    self.acquired += int(n)
                    self.wait_s += waited
                    return waited
                deficit = (n - self._tokens) / self.rate
            # Sleep outside the lock so other acquirers (and stats reads)
            # are never blocked by our wait.
            self._sleep(deficit)
            waited += deficit

    def __call__(self, n: int = 1) -> float:
        """Alias for `acquire` — the `BatchingOracle` pacer-hook shape."""
        return self.acquire(n)
