"""`SelectionServer` — the long-lived serving plane around `QuerySession`.

The engine is a library; this module makes it a daemon. One server hosts:

  * one long-lived `SelectionEngine` (sketch + sampling state built once,
    amortized over every query the process ever serves),
  * one shared `BatchingOracle` channel — optionally paced by a
    `TokenBucket` (the paper's §4.1 rate-limited oracle, made literal) —
    so concurrent clients' oracle requests coalesce into micro-batches
    and share one label cache,
  * a pool of `QuerySession`s driven by a single scheduler thread
    (`step()` turns), so client threads never touch engine state,
  * admission control: at most `max_inflight` queries execute; the rest
    wait in a bounded FIFO overflow queue (`queue_depth`), rejected
    synchronously with `AdmissionError` when it is full and expired with
    `QueueTimeoutError` when they out-wait `queue_timeout_s`,
  * per-tenant metering: every query's budget ledger chains under its
    tenant's quota ledger, so a tenant exhausting its quota mid-drain
    fails *its own* ticket alone (`BudgetExceededError`, labelled with
    the tenant) while co-batched queries of other tenants proceed —
    exactly the per-query poisoning semantics of the session scheduler.

Results are bit-for-bit identical to `engine.run_many` over the same
(queries, keys) for any pure oracle: plans are pure given (key, labels),
and neither admission order, pacing, queue waits, nor tenant metering
changes which labels a query sees — only *when* the oracle is invoked
and who pays for it.

Client API::

    with SelectionServer(engine, oracle_fn, max_inflight=8,
                         rate=10_000, burst=2_000,
                         quotas={"alice": 5_000}) as server:
        h = server.submit(query, tenant="alice", key=key)
        sel = h.result()          # blocks this client only
        print(server.stats().format())
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Deque, Dict, List, Optional, Tuple, Union

import jax

from repro.core.engine import (QueryHandle, QuerySession, SelectionEngine,
                               ShardedSelection)
from repro.core.oracle import BatchingOracle, BudgetLedger, OracleClient
from repro.core.resilience import (CircuitBreaker, CircuitOpenError,
                                   RetryPolicy)
from repro.data import pipeline
from repro.durable import (DurabilityPlane, decode_key, decode_query,
                           encode_key, encode_query)
from repro.live import (DriftSentinel, DriftWatch, IngestPlane,
                        StandingQuery, StandingRegistry)
from repro.serve.limiter import TokenBucket
from repro.serve.stats import LatencyHistogram, ServerStats, TenantStats

_UNMETERED = 1 << 62      # tenant ledger budget when no quota configured


class ServerClosedError(RuntimeError):
    """The server is closing or closed; the query was not accepted."""


class AdmissionError(RuntimeError):
    """Admission control refused the query (overflow queue full)."""


class QueueTimeoutError(AdmissionError):
    """The query expired in the overflow queue before being admitted."""


class ServerHandle:
    """Client-facing future for one submitted query.

    `result()` blocks the calling client thread only — all scheduling
    happens on the server's own thread — and returns the query's
    `ShardedSelection` or raises its typed error (`QueueTimeoutError`,
    `BudgetExceededError` for a budget/quota overrun, `ServerClosedError`
    if the server shut down first).
    """

    def __init__(self, query, tenant: str, key, sink, chunk_records):
        self.query = query
        self.tenant = tenant
        self._key = key
        self._sink = sink
        self._chunk_records = chunk_records
        self._t_submit = time.monotonic()
        self._deadline: Optional[float] = None    # overflow-queue expiry
        self._event = threading.Event()
        self._result: Optional[ShardedSelection] = None
        self._error: Optional[BaseException] = None

    @property
    def done(self) -> bool:
        """True once the query finished (result or error)."""
        return self._event.is_set()

    def _finish(self, result=None, error=None) -> float:
        self._result, self._error = result, error
        latency = time.monotonic() - self._t_submit
        self._event.set()
        return latency

    def result(self, timeout: Optional[float] = None) -> ShardedSelection:
        """Block until the query finishes; return its selection.

        Raises the query's error if it failed, or `TimeoutError` if
        `timeout` seconds elapse first (the query keeps running — call
        `result()` again to keep waiting).
        """
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"query for tenant {self.tenant!r} still running "
                f"after {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result


class _Tenant:
    """Server-internal per-tenant state: quota ledger + counters."""

    def __init__(self, name: str, quota: Optional[int]):
        self.stats = TenantStats(tenant=name, quota=quota)
        # Unmetered tenants still get a ledger so oracle usage is
        # attributed per tenant in ServerStats; the budget is just never
        # reachable.
        self.ledger = BudgetLedger(
            _UNMETERED if quota is None else int(quota),
            label=f"tenant {name!r} quota")


class SelectionServer:
    """Rate-limited, quota-metered daemon serving SUPG queries.

    Parameters
    ----------
    engine: the hosted `SelectionEngine` (closed with the server when
        `own_engine`, the default — pass ``own_engine=False`` when the
        caller manages the engine's lifetime, e.g. inside an existing
        ``with engine:`` block).
    oracle_fn: plain ``indices -> labels`` callable wrapped in the
        server's shared `BatchingOracle`, or an existing `OracleClient`
        (then `rate`/`burst`/`max_batch` must be None — the channel's
        owner configured it).
    max_inflight: queries executing concurrently across the session pool.
    queue_depth: overflow-queue capacity; a full queue rejects at
        `submit` with `AdmissionError`.
    queue_timeout_s: max time a query may wait for admission before its
        handle fails with `QueueTimeoutError` (None = wait forever).
    rate, burst: `TokenBucket` pacing of the oracle channel, in records
        per second and records of burst capacity (None = unpaced).
    max_batch: records per underlying oracle call (see `BatchingOracle`).
    retry, call_timeout_s, breaker: the channel's fault-tolerance stack
        (`RetryPolicy`, per-call watchdog seconds, `CircuitBreaker` —
        see `core.resilience`). While the circuit is open, `submit`
        sheds new admissions with `CircuitOpenError` (carrying a
        retry-after hint) instead of queueing work that will die; the
        half-open probe is left to the drain path, so shedding never
        delays recovery.
    quotas: tenant name -> total oracle-label quota (a `BudgetLedger`
        each query of that tenant chains under). Unknown tenants get
        `default_quota` (None = unmetered).
    sessions: size of the `QuerySession` pool. All sessions share the
        one channel/cache; more sessions only add scheduling isolation.
    sentinel_probe_budget, sentinel_sigma: the drift sentinel's probe
        size (oracle labels per calibration probe) and trigger threshold
        (see `repro.live.DriftSentinel`) — used for subscriptions made
        with ``audit=True``.

    Live corpus surface: `append(shards)` grows the hosted corpus one
    epoch at a time (delta-update, never a rebuild — in-flight queries
    keep their pinned epoch), and `subscribe(query, ...)` registers a
    standing query that certifies once and re-emits over every appended
    shard; with ``audit=True`` the drift sentinel probes each new epoch
    and auto re-validates tau through the shared channel when the §6.2
    drift statistic trips.

    Durability surface: pass ``durable=<path>`` to journal every append
    (write-ahead, fsync'd) under that root; `snapshot()` persists the
    certifications, sentinel references, and tenant ledger balances that
    replay cannot recompute, and `SelectionServer.restore(<path>, ...)`
    brings a killed server back bit-for-bit without re-spending any
    oracle budget — see docs/guarantees.md, "Durability & recovery".
    """

    def __init__(self, engine: SelectionEngine, oracle_fn, *,
                 max_inflight: int = 8, queue_depth: int = 64,
                 queue_timeout_s: Optional[float] = None,
                 rate: Optional[float] = None,
                 burst: Optional[float] = None,
                 max_batch: Optional[int] = None,
                 retry: Optional[RetryPolicy] = None,
                 call_timeout_s: Optional[float] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 quotas: Optional[Dict[str, int]] = None,
                 default_quota: Optional[int] = None,
                 sessions: int = 1,
                 own_engine: bool = True,
                 sentinel_probe_budget: int = 2048,
                 sentinel_sigma: float = 4.0,
                 durable: Optional[Union[str, DurabilityPlane]] = None):
        self.engine = engine
        self._own_engine = bool(own_engine)
        # Durability plane (optional): journal-first appends + snapshots.
        # A path means a *new* journal for this server's lifetime — a
        # journal that already has records belongs to a crashed server
        # and must come back through `SelectionServer.restore` so its
        # epochs and certifications are actually re-applied.
        if isinstance(durable, (str, bytes)) or hasattr(durable,
                                                        "__fspath__"):
            durable = DurabilityPlane(durable)
            if durable.journal_records:
                raise ValueError(
                    f"durable root {durable.root!r} already holds "
                    f"{durable.journal_records} journal record(s) — "
                    f"recover it with SelectionServer.restore(...) "
                    f"instead of attaching a fresh server")
        self.durable: Optional[DurabilityPlane] = durable
        self._append_lock = threading.Lock()
        self.recovered_epochs = 0
        self.recovered_queries = 0
        self.snapshots = 0
        self.bucket: Optional[TokenBucket] = None
        if isinstance(oracle_fn, OracleClient):
            if rate is not None or burst is not None or max_batch is not None \
                    or retry is not None or call_timeout_s is not None \
                    or breaker is not None:
                raise ValueError(
                    "rate/burst/max_batch/retry/call_timeout_s/breaker "
                    "configure the server's own channel; an "
                    "externally-owned OracleClient carries its own "
                    "configuration")
            self.channel = oracle_fn
            self._own_channel = False
            # Admission shedding still works with an external channel
            # that carries its own breaker.
            self.breaker = getattr(oracle_fn, "breaker", None)
        else:
            if rate is not None:
                self.bucket = TokenBucket(rate,
                                          rate if burst is None else burst)
            elif burst is not None:
                raise ValueError("burst requires rate")
            self.channel = BatchingOracle(oracle_fn, max_batch=max_batch,
                                          pacer=self.bucket, retry=retry,
                                          call_timeout_s=call_timeout_s,
                                          breaker=breaker)
            self._own_channel = True
            self.breaker = breaker
        self.max_inflight = max(1, int(max_inflight))
        self.queue_depth = max(0, int(queue_depth))
        self.queue_timeout_s = queue_timeout_s
        self._quotas = dict(quotas or {})
        self._default_quota = default_quota
        self._sessions: List[QuerySession] = [
            engine.session(self.channel) for _ in range(max(1, sessions))]

        # Live corpus plane: ingestion, standing queries, drift sentinel.
        # The registry rides the first session so re-emission walks fuse
        # with ordinary query rounds; the sentinel shares the channel so
        # probe labels join the common cache and metering.
        self.plane = IngestPlane(engine)
        self._registry = StandingRegistry(self.plane, self._sessions[0])
        self._sentinel = DriftSentinel(engine, self.channel,
                                       probe_budget=sentinel_probe_budget,
                                       sigma=sentinel_sigma)
        # Handed from subscribe() (any thread) to the scheduler under
        # the condition variable; everything below it is scheduler-owned.
        self._subscriptions: List[Tuple[StandingQuery, _Tenant, bool]] = []
        self._awaiting_watch: List[Tuple[StandingQuery, object]] = []
        # [sq, DriftWatch, base_key, last_audited_epoch] per audited query
        self._watches: List[list] = []

        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queue: Deque[ServerHandle] = collections.deque()
        self._tenants: Dict[str, _Tenant] = {}
        self._latency = LatencyHistogram()
        self._completed = 0
        self._failed = 0
        self._inflight: List[Tuple[ServerHandle, QueryHandle,
                                   QuerySession]] = []   # scheduler-owned
        self._inflight_n = 0      # mirrored under the lock for stats()
        self._closing = False
        self._abandon = False
        self._closed = False
        self._fatal: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._loop,
                                        name="repro-serve", daemon=True)
        self._thread.start()

    # -- client surface ---------------------------------------------------

    def submit(self, query, *, tenant: str = "default", key=None,
               sink: Optional[pipeline.SelectionSink] = None,
               chunk_records: Optional[int] = None) -> ServerHandle:
        """Submit one RT/PT/JT query on behalf of `tenant`.

        Returns a `ServerHandle` immediately. Raises `AdmissionError`
        synchronously when the overflow queue is full (the client should
        back off and retry), `CircuitOpenError` while the oracle circuit
        is open (graceful degradation — the error carries a retry-after
        hint), and `ServerClosedError` after `close()`. Thread-safe —
        this is the concurrent-client entry point.
        """
        with self._cond:
            if self._closing or self._closed:
                raise ServerClosedError("SelectionServer is closed")
            if self._fatal is not None:
                raise ServerClosedError(
                    f"SelectionServer scheduler died: {self._fatal!r}")
            ten = self._tenant_locked(tenant)
            if self.breaker is not None:
                # Non-mutating probe: retry_after_s() never consumes the
                # half-open slot, so admission shedding cannot starve
                # the drain path's recovery probe.
                retry_after = self.breaker.retry_after_s()
                if retry_after > 0.0:
                    ten.stats.submitted += 1
                    ten.stats.shed += 1
                    raise CircuitOpenError(
                        f"oracle circuit open — retry in "
                        f"{retry_after:.1f}s", retry_after_s=retry_after)
            room = self.max_inflight - self._inflight_n
            if len(self._queue) >= self.queue_depth + max(0, room):
                # Even an empty execution plane admits through the queue,
                # so the bound is queue_depth beyond the free slots.
                ten.stats.submitted += 1
                ten.stats.rejected += 1
                raise AdmissionError(
                    f"admission queue full ({len(self._queue)} waiting, "
                    f"{self._inflight_n}/{self.max_inflight} in flight) — "
                    f"back off and resubmit")
            handle = ServerHandle(query, tenant, key, sink, chunk_records)
            if self.queue_timeout_s is not None:
                handle._deadline = handle._t_submit + self.queue_timeout_s
            ten.stats.submitted += 1
            self._queue.append(handle)
            self._cond.notify_all()
            return handle

    def append(self, shards, *, use_kernel: Optional[bool] = None) -> int:
        """Append score shard(s) to the hosted corpus; returns the new
        epoch number.

        Delta-updates the engine in place (only the appended records are
        sketched); queries already in flight keep the epoch they pinned
        at submit. Standing queries catch up on the scheduler's next
        turn, and audited subscriptions get a sentinel pass over the new
        epoch before their re-emission runs. Thread-safe.

        With a durability plane the append is journal-first: shard bytes
        spool to disk and the epoch record fsyncs *before* the in-memory
        install, so a crash at any instant loses at most an append the
        caller never saw acknowledged — and if the journal got the record
        first, restore replays it, matching the timeline the caller was
        about to see. A client whose `append` call died mid-crash should
        re-issue it after restore iff the restored epoch shows the append
        missing (the epoch number is the idempotency key).
        """
        with self._cond:
            if self._closing or self._closed:
                raise ServerClosedError("SelectionServer is closed")
            if self._fatal is not None:
                raise ServerClosedError(
                    f"SelectionServer scheduler died: {self._fatal!r}")
        # Outside the lock: sketching the new shards may fan out over the
        # engine's worker pool, and clients must not block on it. The
        # append lock keeps journal order identical to install order.
        with self._append_lock:
            if self.durable is not None:
                shards = self.durable.record_append(
                    shards, epoch=self.plane.epoch + 1)
            epoch = self.plane.append(shards, use_kernel=use_kernel)
        with self._cond:
            self._cond.notify_all()
        return epoch

    def subscribe(self, query, *, tenant: str = "default", key=None,
                  sink: Optional[pipeline.SelectionSink] = None,
                  audit: bool = False) -> StandingQuery:
        """Register a standing query; returns its `StandingQuery`.

        The query certifies once on the current epoch (await it with
        ``sq.wait_certified()``), then every `append` triggers a catch-up
        re-emission of ``{A >= tau}`` over exactly the appended shards
        into `sink`. With ``audit=True`` the drift sentinel probes each
        new epoch first and auto re-validates tau (fresh budget, same
        query) when the drift statistic trips — see
        `repro.live.DriftSentinel`. Oracle labels (certification, probes,
        re-validations) are metered against `tenant`'s quota.
        """
        with self._cond:
            if self._closing or self._closed:
                raise ServerClosedError("SelectionServer is closed")
            if self._fatal is not None:
                raise ServerClosedError(
                    f"SelectionServer scheduler died: {self._fatal!r}")
            ten = self._tenant_locked(tenant)
            sq = StandingQuery(query, key, sink)
            sq.tenant_name = tenant        # snapshot()'s attribution
            sq.audited = bool(audit)
            self._subscriptions.append((sq, ten, bool(audit)))
            self._cond.notify_all()
            return sq

    def stats(self) -> ServerStats:
        """One consistent `ServerStats` snapshot (cheap; lock-guarded)."""
        with self._lock:
            tenants = {name: TenantStats(**vars(t.stats))
                       for name, t in self._tenants.items()}
            for name, t in self._tenants.items():
                tenants[name].oracle_charged = t.ledger.charged
            snap = ServerStats(
                tenants=tenants,
                queued=len(self._queue),
                in_flight=self._inflight_n,
                completed=self._completed,
                failed=self._failed,
                p50_s=self._latency.quantile(0.5),
                p99_s=self._latency.quantile(0.99),
                mean_s=self._latency.mean_s,
            )
        snap.oracle_calls = getattr(self.channel, "fn_calls", 0)
        snap.records_labeled = getattr(self.channel, "records_labeled", 0)
        snap.cache_hits = getattr(self.channel, "cache_hits", 0)
        snap.retries = getattr(self.channel, "retries", 0)
        snap.timeouts = getattr(self.channel, "timeouts", 0)
        snap.batch_failures = getattr(self.channel, "batch_failures", 0)
        snap.batch_sheds = getattr(self.channel, "batch_sheds", 0)
        if self.breaker is not None:
            snap.circuit_state = self.breaker.state
            snap.circuit_opens = self.breaker.opens
        if self.bucket is not None:
            snap.throttle_wait_s = self.bucket.wait_s
        for sess in self._sessions:
            snap.rounds += sess.stats.rounds
            snap.drains += sess.stats.drains
            snap.overlap_hidden_s += sess.stats.overlap_hidden_s
        snap.epochs = self.plane.appends
        snap.records_ingested = self.plane.records_ingested
        snap.standing_queries = len(self._registry.standing)
        snap.standing_emissions = self._registry.emissions
        snap.sentinel_checks = self._sentinel.checks
        snap.sentinel_triggers = self._sentinel.triggers
        snap.revalidations = self._sentinel.revalidations
        snap.epochs_live = self.engine.epochs_live
        snap.epochs_freed = self.engine.epochs_freed
        snap.recovered_epochs = self.recovered_epochs
        snap.recovered_queries = self.recovered_queries
        snap.snapshots = self.snapshots
        if self.durable is not None:
            snap.durable = True
            snap.journal_records = self.durable.journal_records
            snap.journal_bytes = self.durable.journal_bytes
        return snap

    # -- durability surface ----------------------------------------------

    @staticmethod
    def _encode_sink(sink) -> Optional[dict]:
        """Serialize a standing query's sink for the snapshot. Disk-backed
        sinks restore with their committed contents; in-memory sinks
        restore empty (their pre-crash state died with the process)."""
        if sink is None:
            return None
        if isinstance(sink, pipeline.BitmaskStore):
            return {"kind": "bitmask", "path": sink.path}
        if isinstance(sink, pipeline.IndexSink):
            return {"kind": "index"}
        return None

    @staticmethod
    def _decode_sink(obj: Optional[dict]):
        if obj is None:
            return None
        if obj["kind"] == "bitmask":
            return pipeline.BitmaskStore(obj["path"])
        return pipeline.IndexSink()

    def snapshot(self) -> dict:
        """Persist the serving-plane state no replay can recompute.

        Captures every *certified* standing query (tau, epoch, counters,
        sink identity), every sentinel watch (reference probe, last
        audited epoch), and every tenant ledger balance; writes it
        through the durability plane's atomic snapshot publish, then
        garbage-collects superseded corpus epochs (`engine.gc_epochs` —
        snapshotting is the natural checkpoint boundary). Returns the
        snapshot dict. Call at quiescent points (no certification in
        flight); `serve()`'s users typically snapshot after
        `wait_certified` or between appends.
        """
        standing = self._registry.standing
        entries = []
        kept = []
        for sq in standing:
            if not sq.certified or sq.tau is None:
                continue      # uncertified: nothing durable to keep yet
            kept.append(sq)
            entries.append({
                "tenant": getattr(sq, "tenant_name", "default"),
                "query": encode_query(sq.query),
                "key": encode_key(sq.key),
                "tau": float(sq.tau),
                "epoch": int(sq.epoch),
                "emissions": int(sq.emissions),
                "records_reemitted": int(sq.records_reemitted),
                "sink": self._encode_sink(sq.sink),
                "audit": bool(getattr(sq, "audited", False)),
            })
        watches = []
        for sq, watch, _base, last in list(self._watches):
            if sq not in kept:
                continue
            watches.append({
                "standing_index": kept.index(sq),
                "watch": {"scheme": watch.scheme,
                          "kappa": float(watch.kappa),
                          "tau": float(watch.tau),
                          "epoch": int(watch.epoch),
                          "ref_rate": float(watch.ref_rate),
                          "ref_var": float(watch.ref_var),
                          "probe_s": int(watch.probe_s)},
                "last_audited": int(last),
            })
        with self._lock:
            tenants = {name: {"charged": int(t.ledger.charged),
                              "quota": t.stats.quota}
                       for name, t in self._tenants.items()}
        state = {"epoch": int(self.plane.epoch), "standing": entries,
                 "watches": watches, "tenants": tenants}
        if self.durable is not None:
            self.durable.write_snapshot(state)
            self.snapshots += 1
        self.engine.gc_epochs()
        return state

    @classmethod
    def restore(cls, durable_root, oracle_fn, *, base_shards,
                engine_kw: Optional[dict] = None,
                use_kernel: Optional[bool] = None,
                **server_kw) -> "SelectionServer":
        """Resurrect a crashed server from its durability root.

        `base_shards` are the shards the dead server's engine was
        *constructed* with (the pre-journal corpus — score files
        themselves are the data plane's to persist; `ScoreStore`s
        qualify). The sequence: rebuild the engine over the base corpus,
        replay every journaled epoch (deterministic delta-sketching — the
        corpus comes back bit-for-bit), re-charge tenant ledgers to their
        snapshot balances, and re-adopt certified standing queries and
        sentinel watches *without running anything* — no oracle budget is
        re-spent, which is exactly why the recovered taus keep their
        certifications. Standing queries behind the replayed corpus catch
        up through ordinary re-emission (tau-threshold walks, zero
        labels) on the scheduler's first turn.
        """
        dur = DurabilityPlane(durable_root)
        snap = dur.read_snapshot() or {"epoch": 0, "standing": [],
                                       "watches": [], "tenants": {}}
        engine = SelectionEngine(base_shards, **(engine_kw or {}))
        server = cls(engine, oracle_fn, durable=dur, **server_kw)
        try:
            server._restore_from(snap, use_kernel=use_kernel)
        except BaseException:
            server.close(abandon=True)
            raise
        return server

    def _restore_from(self, snap: dict,
                      use_kernel: Optional[bool] = None) -> None:
        """Apply a snapshot + journal suffix to this freshly-built server
        (scheduler idle: nothing is registered yet)."""
        self.recovered_epochs = self.durable.replay_into(
            self.plane, use_kernel=use_kernel)
        with self._lock:
            for name, info in snap.get("tenants", {}).items():
                if name not in self._quotas and info.get("quota") is not None:
                    self._quotas[name] = int(info["quota"])
                ten = self._tenant_locked(name)
                if info.get("charged"):
                    ten.ledger.charge(int(info["charged"]))
        restored: List[StandingQuery] = []
        for entry in snap.get("standing", []):
            sq = StandingQuery(decode_query(entry["query"]),
                               decode_key(entry["key"]),
                               self._decode_sink(entry["sink"]))
            sq.tau = float(entry["tau"])
            sq.epoch = int(entry["epoch"])
            sq.emissions = int(entry["emissions"])
            sq.records_reemitted = int(entry["records_reemitted"])
            sq.tenant_name = entry["tenant"]
            sq.audited = bool(entry["audit"])
            sq._certified.set()
            self._registry.adopt(sq)
            restored.append(sq)
            self.recovered_queries += 1
        for w in snap.get("watches", []):
            sq = restored[w["standing_index"]]
            base = jax.random.fold_in(
                sq.key if sq.key is not None else jax.random.PRNGKey(0),
                0x5E47)
            watch = DriftWatch(query=sq.query, **w["watch"])
            self._watches.append([sq, watch, base,
                                  int(w["last_audited"])])
        with self._cond:
            self._cond.notify_all()    # pump catch-up re-emissions

    # -- scheduler thread -------------------------------------------------

    def _tenant_locked(self, name: str) -> _Tenant:
        ten = self._tenants.get(name)
        if ten is None:
            quota = self._quotas.get(name, self._default_quota)
            ten = self._tenants[name] = _Tenant(name, quota)
        return ten

    def _expire_locked(self, now: float) -> List[ServerHandle]:
        """Pop queued handles whose admission deadline passed."""
        expired = []
        while self._queue and self._queue[0]._deadline is not None \
                and self._queue[0]._deadline <= now:
            h = self._queue.popleft()
            self._tenants[h.tenant].stats.timed_out += 1
            expired.append(h)
        return expired

    def _admit_locked(self) -> List[Tuple[ServerHandle, _Tenant]]:
        admitted = []
        while self._queue and self._inflight_n < self.max_inflight:
            h = self._queue.popleft()
            ten = self._tenants[h.tenant]
            ten.stats.admitted += 1
            self._inflight_n += 1
            admitted.append((h, ten))
        return admitted

    def _next_wait_locked(self) -> Optional[float]:
        """Idle wait bound: the earliest queued admission deadline."""
        if not self._queue or self._queue[0]._deadline is None:
            return None
        return max(0.0, self._queue[0]._deadline - time.monotonic())

    def _live_work(self) -> bool:
        """True while the live plane has work the scheduler must drive:
        in-flight certifications/re-emissions, certified standing queries
        behind the current epoch, watches owed a sentinel pass, or a
        certification whose watch is ready to baseline."""
        if self._registry.has_pending():
            return True
        epoch = self.plane.epoch
        if any(sq.certified and not sq._busy and sq.epoch < epoch
               for sq in self._registry.standing):
            return True
        if any(entry[3] < epoch for entry in self._watches):
            return True
        return any(sq._certified.is_set()
                   for sq, _ in self._awaiting_watch)

    def _loop(self) -> None:
        try:
            self._run_scheduler()
        except BaseException as err:  # noqa: BLE001 — daemon must not die mute
            with self._cond:
                self._fatal = err
                self._cond.notify_all()
            self._fail_all(err)

    def _run_scheduler(self) -> None:
        while True:
            with self._cond:
                for h in self._expire_locked(time.monotonic()):
                    self._finish_locked(h, error=QueueTimeoutError(
                        f"query for tenant {h.tenant!r} waited "
                        f"{self.queue_timeout_s}s for admission"),
                        count=False)
                if self._abandon:
                    return
                admitted = self._admit_locked()
                subs, self._subscriptions = self._subscriptions, []
                if not admitted and not subs and not self._inflight \
                        and not self._live_work():
                    if self._closing and not self._queue:
                        return
                    self._cond.wait(self._next_wait_locked())
                    continue
            # Session work runs outside the server lock: plans touch only
            # engine/channel state, and clients must be able to submit
            # (and read stats) while rounds are in flight.
            for sq, ten, audit in subs:
                self._registry.activate(sq, ledger_parent=ten.ledger)
                if audit:
                    base = (sq.key if sq.key is not None
                            else jax.random.PRNGKey(0))
                    self._awaiting_watch.append(
                        (sq, jax.random.fold_in(base, 0x5E47)))
            if self._awaiting_watch:
                # Promote certified subscriptions to sentinel watches;
                # the reference probe adopts the certified tau (no extra
                # query budget spent).
                keep = []
                for sq, base in self._awaiting_watch:
                    if not sq._certified.is_set():
                        keep.append((sq, base))
                        continue
                    if sq._error is None:
                        watch = self._sentinel.watch(sq.query, key=base,
                                                     tau=sq.tau)
                        self._watches.append([sq, watch, base, watch.epoch])
                self._awaiting_watch = keep
            # Sentinel audits run *before* the registry pumps, so a
            # drifted epoch is re-emitted with the re-validated tau.
            epoch = self.plane.epoch
            for entry in self._watches:
                sq, watch, base, last = entry
                if epoch <= last:
                    continue
                try:
                    report = self._sentinel.audit(
                        watch, key=jax.random.fold_in(base, epoch))
                except BaseException as err:  # noqa: BLE001 — audit must
                    # not kill the scheduler: a failed probe (oracle
                    # fault, quota overrun) is recorded on the standing
                    # query and the epoch is skipped, not retried hot.
                    sq.last_error = err
                else:
                    if report.revalidated:
                        sq.update_tau(watch.tau)
                entry[3] = epoch
            self._registry.pump()
            for h, ten in admitted:
                sess = min(self._sessions, key=lambda s: s.in_flight)
                qh = sess.submit(h.query, key=h._key, sink=h._sink,
                                 chunk_records=h._chunk_records,
                                 ledger_parent=ten.ledger)
                self._inflight.append((h, qh, sess))
            for sess in self._sessions:
                sess.step()
            self._registry.poll()
            done = [(h, qh) for h, qh, _ in self._inflight if qh.done]
            if done:
                self._inflight = [t for t in self._inflight
                                  if not t[1].done]
                with self._cond:
                    for h, qh in done:
                        self._inflight_n -= 1
                        try:
                            self._finish_locked(h, result=qh.result())
                        except BaseException as err:  # noqa: BLE001
                            self._finish_locked(h, error=err)
                    self._cond.notify_all()

    def _finish_locked(self, h: ServerHandle, result=None, error=None,
                       count: bool = True) -> None:
        latency = h._finish(result, error)
        self._latency.record(latency)
        if not count:
            return
        ten = self._tenants[h.tenant].stats
        if error is None:
            self._completed += 1
            ten.completed += 1
        else:
            self._failed += 1
            ten.failed += 1

    def _fail_all(self, err: BaseException) -> None:
        """Scheduler died: every accepted-but-unfinished handle must
        still settle loudly (clients are blocked in result())."""
        with self._cond:
            leftovers = list(self._queue) + [h for h, _, _ in self._inflight]
            self._queue.clear()
            self._inflight = []
            self._inflight_n = 0
            for h in leftovers:
                if not h.done:
                    h._finish(error=ServerClosedError(
                        f"SelectionServer scheduler died: {err!r}"))

    # -- lifecycle --------------------------------------------------------

    def close(self, abandon: bool = False) -> None:
        """Shut the server down.

        Default: stop admissions, serve everything already accepted
        (queued + in flight) to completion, then release the session
        pool, the channel's drain thread, and (when owned) the engine.
        `abandon=True` drops unfinished work instead — their handles
        fail with `ServerClosedError`. Idempotent.
        """
        with self._cond:
            if self._closed:
                return
            self._closing = True
            self._abandon = self._abandon or bool(abandon)
            self._cond.notify_all()
        self._thread.join()
        with self._cond:
            self._closed = True
            leftovers = list(self._queue) + [h for h, _, _ in self._inflight]
            self._queue.clear()
            self._inflight = []
            self._inflight_n = 0
        for sess in self._sessions:
            sess.close(abandon=True)   # anything left is being dropped
        for h in leftovers:
            if not h.done:
                h._finish(error=ServerClosedError(
                    "SelectionServer closed before this query ran"))
        if self._own_channel:
            close_channel = getattr(self.channel, "close", None)
            if close_channel is not None:
                close_channel()
        if self._own_engine:
            self.engine.close()
        if self.durable is not None:
            self.durable.close()

    def __enter__(self) -> "SelectionServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close(abandon=exc_type is not None)
        return False
