"""Serving plane: a rate-limited, quota-metered daemon around the engine.

`SelectionServer` hosts one long-lived `SelectionEngine` plus a
`QuerySession` pool behind a thread-safe `submit(query, tenant=...)`
API with admission control, per-tenant quotas (`BudgetLedger` chains),
and `TokenBucket` pacing of the shared oracle channel. See
`docs/architecture.md` for where this sits in the stack.
"""
from repro.core.oracle import BudgetExceededError
from repro.core.resilience import (CircuitBreaker, CircuitOpenError,
                                   RetryPolicy)
from repro.serve.limiter import RateLimitError, TokenBucket
from repro.serve.server import (AdmissionError, QueueTimeoutError,
                                SelectionServer, ServerClosedError,
                                ServerHandle)
from repro.serve.stats import LatencyHistogram, ServerStats, TenantStats

__all__ = [
    "SelectionServer",
    "ServerHandle",
    "ServerStats",
    "TenantStats",
    "LatencyHistogram",
    "TokenBucket",
    "RateLimitError",
    "AdmissionError",
    "QueueTimeoutError",
    "ServerClosedError",
    "BudgetExceededError",
    "CircuitBreaker",
    "CircuitOpenError",
    "RetryPolicy",
]
