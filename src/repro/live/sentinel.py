"""Drift sentinel — §6.2 calibration-drift detection with auto re-validation.

The paper's guarantees hold for the score/label joint distribution the
certifying sample was drawn from; §6.2 shows that proxy calibration drift
silently voids them. The sentinel makes that failure loud and recoverable:

**The statistic.** For a sample drawn from the defensive importance
distribution p(x) with reweighting factors m(x) = u(x)/p(x), the
importance-weighted match estimate

    mu_hat = mean(m_i * o_i)   with   E_p[m * o] = (1/n) * sum_x o(x)

is an unbiased estimate of the corpus *match fraction* under any sampling
scheme the engine uses (for uniform draws m = 1 and it degenerates to the
plain mean). `watch()` records a certified reference probe (mu_ref,
var_ref); `check()` draws a fresh probe over the *current* epoch and
computes the two-sample z statistic

    z = |mu_hat - mu_ref| / sqrt(var_ref + var_cur)

(variances are of-the-mean, ddof=1). `z > sigma` flags drift: the match
mass has moved relative to what tau was certified against.

**The response.** `audit()` = check, and on trigger `revalidate()`:
re-run the watched query with a fresh budget through the shared oracle
channel, install the new tau on the watch (and, at the serve layer, on
the standing query), and re-baseline the reference probe. The re-validated
tau carries a fresh 1-delta guarantee over the corpus as of that epoch —
see "What re-validation re-guarantees" in `docs/guarantees.md`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import numpy as np

from repro.core.engine import CorpusState, SelectionEngine, ShardedSelection
from repro.core.oracle import BudgetLedger, as_oracle_client
from repro.core.queries import SUPGQuery


@dataclasses.dataclass
class DriftReport:
    """Outcome of one sentinel audit (`DriftSentinel.audit`)."""

    epoch: int                    # corpus epoch the fresh probe covered
    ref_rate: float               # certified reference match-rate estimate
    rate: float                   # fresh probe match-rate estimate
    z: float                      # two-sample drift statistic
    sigma: float                  # trigger threshold the check used
    drifted: bool                 # z > sigma
    revalidated: bool = False     # a re-validation query ran
    tau_before: float = math.nan
    tau_after: float = math.nan
    probe_spent: int = 0          # oracle labels the fresh probe charged
    revalidation_spent: int = 0   # oracle labels re-validation charged

    def format(self) -> str:
        """Human-readable multi-line report."""
        lines = [
            f"drift audit @ epoch {self.epoch}:",
            f"  match rate: ref {self.ref_rate:.6f} -> cur "
            f"{self.rate:.6f}  (z = {self.z:.2f}, sigma = "
            f"{self.sigma:.1f})",
            f"  verdict:    "
            f"{'DRIFTED' if self.drifted else 'calibrated'}",
        ]
        if self.revalidated:
            lines.append(
                f"  re-validated: tau {self.tau_before:.6f} -> "
                f"{self.tau_after:.6f}  ({self.revalidation_spent} "
                f"oracle labels)")
        elif self.drifted:
            lines.append(f"  tau unchanged at {self.tau_before:.6f} "
                         f"(re-validation not requested)")
        lines.append(f"  probe cost: {self.probe_spent} oracle labels")
        return "\n".join(lines)


@dataclasses.dataclass
class DriftWatch:
    """Per-query sentinel state: the certified reference the drift
    statistic compares against, updated in place by re-validation."""

    query: SUPGQuery
    scheme: str                   # probe sampling scheme ('uniform' ok)
    kappa: float
    tau: float                    # currently-installed threshold
    epoch: int                    # epoch tau was last (re-)certified at
    ref_rate: float               # reference probe mean(m * o)
    ref_var: float                # reference probe var-of-the-mean
    probe_s: int                  # probe budget both probes used


class DriftSentinel:
    """Watches certified queries for calibration drift; re-validates on
    trigger. All oracle traffic (probes and re-validation queries) rides
    the one shared channel passed at construction, so probe labels join
    the common cache and are metered like any other labels.

    >>> import jax, numpy as np
    >>> from repro.core.engine import SelectionEngine
    >>> from repro.core.queries import SUPGQuery
    >>> from repro.live.ingest import IngestPlane
    >>> scores = np.linspace(0.0, 1.0, 2048, dtype=np.float32)
    >>> labels = {}      # grown alongside the corpus
    >>> oracle = lambda idx: np.asarray(
    ...     [labels.get(int(i), 0.0) for i in np.asarray(idx)], np.float32)
    >>> labels.update({i: float(s > 0.7) for i, s in enumerate(scores)})
    >>> eng = SelectionEngine([scores], num_bins=64, use_kernel=False)
    >>> sent = DriftSentinel(eng, oracle, probe_budget=256, sigma=3.0)
    >>> q = SUPGQuery(target="recall", gamma=0.9, budget=256, method="is")
    >>> w = sent.watch(q, key=jax.random.PRNGKey(1))
    >>> # Drift: append high-score records that are all oracle-negative.
    >>> labels.update({i + 2048: 0.0 for i in range(2048)})
    >>> _ = IngestPlane(eng).append(np.full(2048, 0.9, np.float32))
    >>> rep = sent.audit(w, key=jax.random.PRNGKey(2))
    >>> (rep.drifted, rep.revalidated, rep.epoch)
    (True, True, 1)
    >>> eng.close()
    """

    def __init__(self, engine: SelectionEngine, oracle, *,
                 probe_budget: int = 2048, sigma: float = 4.0):
        self.engine = engine
        self.client = as_oracle_client(oracle)
        self.probe_budget = int(probe_budget)
        self.sigma = float(sigma)
        self.checks = 0
        self.triggers = 0
        self.revalidations = 0

    # -- probes ---------------------------------------------------------

    def _probe(self, key, scheme: str, kappa: float,
               state: CorpusState) -> Tuple[float, float, int]:
        """One importance-weighted match-rate probe over `state`.

        Returns (mean(m*o), var-of-the-mean, labels charged). Synchronous
        on the calling thread — safe from a serve-plane scheduler because
        between session rounds the channel holds no pending tickets.
        """
        s = self.probe_budget
        idx, m = self.engine.draw_sample(key, s, self.scheme_of(scheme),
                                         kappa=kappa, state=state)
        ledger = BudgetLedger(s)
        o = np.asarray(self.client.submit(idx, ledger=ledger).result(),
                       np.float64)
        x = np.asarray(m, np.float64) * o
        var = float(x.var(ddof=1)) / x.size if x.size > 1 else 0.0
        return float(x.mean()), var, int(ledger.charged)

    @staticmethod
    def scheme_of(scheme_or_query) -> str:
        """Probe sampling scheme for a query (or pass a scheme through)."""
        if isinstance(scheme_or_query, SUPGQuery):
            q = scheme_or_query
            return ("uniform" if q.method in ("uniform", "noci")
                    else q.weight_scheme)
        return str(scheme_or_query)

    # -- lifecycle ------------------------------------------------------

    def watch(self, query: SUPGQuery, *, key,
              tau: Optional[float] = None) -> DriftWatch:
        """Certify (or adopt) a query and baseline its reference probe.

        With `tau=None` the query is run through the shared channel to
        certify a threshold; pass an already-certified tau (e.g. a
        `StandingQuery`'s) to adopt it without spending query budget.
        Either way a reference probe of `probe_budget` labels is drawn
        over the current epoch.
        """
        state = self.engine.pin()
        try:
            scheme = self.scheme_of(query)
            k_cert, k_probe = jax.random.split(key)
            if tau is None:
                sel = self.engine.run(k_cert, self.client, query)
                tau = float(sel.tau)
            ref_rate, ref_var, _ = self._probe(k_probe, scheme,
                                               self.engine.kappa, state)
            return DriftWatch(query=query, scheme=scheme,
                              kappa=self.engine.kappa, tau=float(tau),
                              epoch=state.epoch, ref_rate=ref_rate,
                              ref_var=ref_var, probe_s=self.probe_budget)
        finally:
            self.engine.unpin(state)

    def check(self, watch: DriftWatch, *, key) -> DriftReport:
        """Fresh probe over the current epoch; flags drift, changes
        nothing."""
        state = self.engine.pin()
        try:
            rate, var, spent = self._probe(key, watch.scheme, watch.kappa,
                                           state)
        finally:
            self.engine.unpin(state)
        z = (abs(rate - watch.ref_rate)
             / math.sqrt(max(watch.ref_var + var, 1e-300)))
        self.checks += 1
        drifted = z > self.sigma
        if drifted:
            self.triggers += 1
        return DriftReport(epoch=state.epoch, ref_rate=watch.ref_rate,
                           rate=rate, z=z, sigma=self.sigma,
                           drifted=drifted, tau_before=watch.tau,
                           tau_after=watch.tau, probe_spent=spent)

    def revalidate(self, watch: DriftWatch, *, key,
                   budget: Optional[int] = None) -> ShardedSelection:
        """Re-run the watched query with a fresh budget over the current
        epoch; installs the new tau and re-baselines the reference probe.
        """
        q = (watch.query if budget is None
             else dataclasses.replace(watch.query, budget=int(budget)))
        state = self.engine.pin()
        try:
            k_run, k_probe = jax.random.split(key)
            sel = self.engine.run(k_run, self.client, q)
            watch.tau = float(sel.tau)
            watch.epoch = state.epoch
            watch.ref_rate, watch.ref_var, _ = self._probe(
                k_probe, watch.scheme, watch.kappa, state)
        finally:
            self.engine.unpin(state)
        self.revalidations += 1
        return sel

    def audit(self, watch: DriftWatch, *, key,
              budget: Optional[int] = None) -> DriftReport:
        """`check`, and on trigger `revalidate` — the serve plane's
        per-epoch sentinel pass. Returns the full report."""
        k_check, k_reval = jax.random.split(key)
        report = self.check(watch, key=k_check)
        if report.drifted:
            sel = self.revalidate(watch, key=k_reval, budget=budget)
            report.revalidated = True
            report.tau_after = watch.tau
            report.revalidation_spent = int(sel.oracle_calls)
        return report
