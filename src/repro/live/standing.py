"""Standing queries — certified once, re-emitting over every new epoch.

A `StandingQuery` is registered against a `QuerySession` + `IngestPlane`
pair through a `StandingRegistry`: the query certifies its tau on the
epoch current at registration (an ordinary RT/PT plan through the
session), and from then on each `pump()` catches every certified query up
to the latest epoch by submitting a *re-emission plan* — a threshold walk
restricted to exactly the shards appended since the query's last epoch
(`ChunkPlan(shard_ids=...)`), streaming `{A >= tau}` into the query's own
sink. Re-emission plans enter the session through
`QuerySession.submit_plan`, so they join the same cohorts, per-round walk
fusion, and double-buffered drains as ordinary queries: eight standing
queries catching up on one append touch each new chunk once, not eight
times.

What re-emission means statistically: the original tau's §5 guarantee is
about the distribution it was certified against. Re-emitting that tau
over appended data is the right operational default *only while the score
distribution has not drifted* — pair the registry with a
`repro.live.sentinel.DriftSentinel` (as `SelectionServer.subscribe(...,
audit=True)` does) to re-validate tau when it has. See "What
re-validation re-guarantees" in `docs/guarantees.md`.
"""
from __future__ import annotations

import threading
from typing import Generator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import (CorpusState, QueryHandle, QuerySession,
                               ShardedSelection, _close_quietly)
from repro.core.oracle import BudgetLedger
from repro.data import pipeline
from repro.live.ingest import IngestPlane


def _reemission_plan(engine, tau: float,
                     sink: Optional[pipeline.SelectionSink],
                     shard_ids: Sequence[int],
                     state: CorpusState) \
        -> Generator[object, Optional[np.ndarray], ShardedSelection]:
    """Resumable plan: one {A >= tau} walk over `shard_ids` of `state`.

    Speaks the same yield protocol as `_run_plan` (a single `ChunkWalk`
    yield, no oracle requests), so a `QuerySession` schedules and fuses it
    like any query plan.
    """
    walk, out_sink, finish = engine._emission_walk(
        tau, np.empty(0, np.int64), sink, None, state=state,
        shard_ids=shard_ids)
    try:
        yield walk
    except BaseException:
        _close_quietly(out_sink)
        raise
    return finish(0)


class StandingQuery:
    """One registered query: its certification result plus re-emission
    bookkeeping. Created via `StandingRegistry.register` (or
    `SelectionServer.subscribe`); consumers hold it to await
    certification and watch re-emission progress.
    """

    def __init__(self, query, key=None,
                 sink: Optional[pipeline.SelectionSink] = None):
        self.query = query
        self.key = key
        self.sink = sink
        self.tau: Optional[float] = None
        self.selection: Optional[ShardedSelection] = None
        self.epoch = -1                 # last epoch the sink is current for
        self.emissions = 0              # re-emission walks completed
        self.records_reemitted = 0      # records those walks selected
        self.reemit_failures = 0
        self.last_error: Optional[BaseException] = None
        self._certified = threading.Event()
        self._error: Optional[BaseException] = None
        self._busy = False              # a re-emission plan is in flight

    @property
    def certified(self) -> bool:
        """True once the initial certification query completed cleanly."""
        return self._certified.is_set() and self._error is None

    def wait_certified(self, timeout: Optional[float] = None) -> float:
        """Block until certification completes; returns tau.

        Raises `TimeoutError` on timeout, or the certification error if
        the underlying query failed. Safe from any thread — the scheduler
        (whoever pumps the registry) sets the event.
        """
        if not self._certified.wait(timeout):
            raise TimeoutError(
                "standing query not certified within timeout")
        if self._error is not None:
            raise self._error
        return float(self.tau)

    def update_tau(self, tau: float) -> None:
        """Install a re-validated tau; later re-emissions use it."""
        self.tau = float(tau)


class StandingRegistry:
    """Owns the standing queries of one (`IngestPlane`, `QuerySession`).

    Drive it from whatever thread pumps the session (the serve plane's
    scheduler): `activate` starts certifications, `pump` submits catch-up
    re-emission plans for certified queries behind the current epoch, and
    `poll` folds finished handles back into their `StandingQuery`s.

    >>> import numpy as np
    >>> from repro.core.engine import SelectionEngine
    >>> from repro.core.queries import SUPGQuery
    >>> from repro.live.ingest import IngestPlane
    >>> scores = np.linspace(0.0, 1.0, 512, dtype=np.float32)
    >>> labels = lambda idx: (np.asarray(idx) >= 384).astype(np.float32)
    >>> eng = SelectionEngine([scores], num_bins=32, use_kernel=False)
    >>> sess = eng.session(labels)
    >>> reg = StandingRegistry(IngestPlane(eng), sess)
    >>> sq = reg.register(SUPGQuery(target="recall", gamma=0.9,
    ...                             budget=128, method="is"))
    >>> reg.settle()    # pump the certification to completion
    >>> tau = sq.wait_certified(timeout=0)
    >>> _ = reg.plane.append(np.full(256, 0.99, np.float32))
    >>> reg.pump()      # one catch-up walk over the appended shard
    1
    >>> reg.settle(); (sq.emissions, sq.records_reemitted, sq.epoch)
    (1, 256, 1)
    >>> sess.close(); eng.close()
    """

    def __init__(self, plane: IngestPlane, session: QuerySession):
        self.plane = plane
        self.session = session
        self._lock = threading.Lock()
        self._standing: List[StandingQuery] = []
        # (sq, handle, kind, state) — kind is "certify" or "reemit"; the
        # pinned CorpusState is unpinned when the handle folds, so epoch
        # GC can free superseded epochs once no plan reads them.
        self._pending: List[Tuple[StandingQuery, QueryHandle, str,
                                  CorpusState]] = []
        self.emissions = 0
        self.records_reemitted = 0

    @property
    def standing(self) -> List[StandingQuery]:
        """Snapshot of the registered standing queries."""
        with self._lock:
            return list(self._standing)

    def register(self, query, *, key=None,
                 sink: Optional[pipeline.SelectionSink] = None,
                 ledger_parent: Optional[BudgetLedger] = None) \
            -> StandingQuery:
        """Create a `StandingQuery` and start its certification."""
        return self.activate(StandingQuery(query, key, sink),
                             ledger_parent=ledger_parent)

    def activate(self, sq: StandingQuery, *,
                 ledger_parent: Optional[BudgetLedger] = None) \
            -> StandingQuery:
        """Submit `sq`'s certification plan; call on the pumping thread.

        The plan pins the epoch current right now, so the certification
        and the query's re-emission baseline name the same corpus even if
        an append lands while the plan runs.
        """
        state = self.plane.engine.pin()
        sq.epoch = state.epoch
        handle = self.session.submit(sq.query, key=sq.key, sink=sq.sink,
                                     ledger_parent=ledger_parent,
                                     state=state)
        with self._lock:
            self._standing.append(sq)
            self._pending.append((sq, handle, "certify", state))
        return sq

    def adopt(self, sq: StandingQuery) -> StandingQuery:
        """Reinstate an already-certified `StandingQuery` without running
        anything — the restore path (`SelectionServer.restore`). The
        query keeps its snapshotted tau, epoch, and counters; no plan is
        submitted and no oracle budget is spent. The next `pump` catches
        its sink up to the current epoch through ordinary re-emission.
        """
        with self._lock:
            self._standing.append(sq)
        return sq

    def poll(self) -> None:
        """Fold every finished pending handle into its `StandingQuery`."""
        with self._lock:
            pending, self._pending = self._pending, []
        keep = []
        for sq, handle, kind, state in pending:
            if not handle.done:
                keep.append((sq, handle, kind, state))
                continue
            self.plane.engine.unpin(state)
            try:
                sel = handle.result()
            except BaseException as err:  # noqa: BLE001 — folded into sq
                if kind == "certify":
                    sq._error = err
                    sq._certified.set()
                else:
                    sq.reemit_failures += 1
                    sq.last_error = err
                    sq._busy = False
                continue
            if kind == "certify":
                sq.tau = float(sel.tau)
                sq.selection = sel
                sq._certified.set()
            else:
                sq.emissions += 1
                sq.records_reemitted += sel.total_selected
                sq._busy = False
                with self._lock:
                    self.emissions += 1
                    self.records_reemitted += sel.total_selected
        with self._lock:
            self._pending = keep + self._pending

    def has_pending(self) -> bool:
        """True while any certification or re-emission is in flight."""
        with self._lock:
            return bool(self._pending)

    def pump(self) -> int:
        """Submit catch-up re-emission plans; returns how many started.

        For every certified, idle standing query behind the current
        epoch: pin the epoch, restrict a threshold walk to the shards
        appended since the query's last epoch, and submit it through
        `QuerySession.submit_plan` (so concurrent catch-ups fuse). The
        query's epoch advances to the pinned one immediately — the walk
        covers exactly the gap.
        """
        self.poll()
        started = 0
        for sq in self.standing:
            if not sq.certified or sq._busy:
                continue
            state = self.plane.engine.pin()
            if sq.epoch >= state.epoch:
                self.plane.engine.unpin(state)
                continue
            # An append may install between the pin and this call, so
            # shards_since (which reads the *current* shard list) can name
            # shards the pinned epoch does not have — clamp to the pinned
            # state; sq.epoch only advances to state.epoch, so the excess
            # is walked next turn.
            shard_ids = [s for s in self.plane.shards_since(sq.epoch)
                         if s < len(state.shards)]
            if not shard_ids:
                sq.epoch = state.epoch
                self.plane.engine.unpin(state)
                continue
            plan = _reemission_plan(self.plane.engine, sq.tau, sq.sink,
                                    shard_ids, state)
            handle = self.session.submit_plan(plan, query=sq.query,
                                              sink=sq.sink)
            sq._busy = True
            sq.epoch = state.epoch
            with self._lock:
                self._pending.append((sq, handle, "reemit", state))
            started += 1
        return started

    def settle(self) -> None:
        """Run every pending handle to completion (pumps the session)."""
        while True:
            with self._lock:
                pending = list(self._pending)
            if not pending:
                return
            for _, handle, _, _ in pending:
                if not handle.done:
                    try:
                        handle.result()
                    except BaseException:  # noqa: BLE001 — poll folds it
                        pass
            self.poll()
