"""Live corpus plane — incremental ingestion, standing queries, drift watch.

The paper's guarantees (§5) are certified against a frozen, fully
proxy-scored corpus. This package keeps them meaningful when the corpus
is *not* frozen:

  IngestPlane       append score shards and delta-update engine state
                    (sketches merge additively, CDFs extend in place)
                    under a versioned epoch — never a cold rebuild
  StandingQuery /   registered queries whose sinks re-emit over newly
  StandingRegistry  appended shards each epoch, scheduled through the
                    same `QuerySession` pump as ordinary queries
  DriftSentinel /   §6.2 calibration-drift monitor: importance-weighted
  DriftWatch /      match-rate probes against a certified reference, and
  DriftReport       auto re-validation through the shared oracle channel

`repro.serve.SelectionServer` wires all three behind `append()` /
`subscribe()`; this package is the engine-level API underneath.
"""
from repro.live.ingest import IngestPlane
from repro.live.sentinel import DriftReport, DriftSentinel, DriftWatch
from repro.live.standing import StandingQuery, StandingRegistry

__all__ = [
    "IngestPlane",
    "StandingQuery", "StandingRegistry",
    "DriftSentinel", "DriftWatch", "DriftReport",
]
