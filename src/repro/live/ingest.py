"""Incremental ingestion — grow a live corpus without cold rebuilds.

`IngestPlane` is the public face of `SelectionEngine._append_shards`: it
accepts appended score shards (arrays or `ScoreStore`s), delta-updates the
engine's cached state — per-shard sketches for *only* the new data merge
additively into the global sketch, normalizers refresh from the merged
sketch, and every cached per-(scheme, kappa) chunk-mass CDF rebuilds from
cached chunk masses in O(n_chunks) without re-reading any old record —
and installs the result as a new corpus *epoch*.

Epoch semantics carry the correctness story:

  * installs are atomic (one attribute assignment); an in-flight plan that
    pinned its epoch keeps computing against a frozen, consistent corpus,
  * results over any epoch are bit-for-bit what a cold engine build over
    exactly that corpus would produce (`tests/test_live.py` asserts this
    for RT/PT/JT at workers 1/4/8),
  * `shards_since(epoch)` names the shards an epoch transition added —
    the unit the standing-query plane re-emits over.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.engine import CorpusState, SelectionEngine


class IngestPlane:
    """Appends score shards to a `SelectionEngine`, one epoch per append.

    >>> import numpy as np
    >>> from repro.core.engine import SelectionEngine
    >>> eng = SelectionEngine([np.linspace(0, 1, 512, dtype=np.float32)],
    ...                       num_bins=32, use_kernel=False)
    >>> plane = IngestPlane(eng)
    >>> epoch = plane.append(np.linspace(0, 1, 256, dtype=np.float32))
    >>> (epoch, eng.epoch, eng.n_total, plane.shards_since(0))
    (1, 1, 768, [1])
    >>> eng.close()
    """

    def __init__(self, engine: SelectionEngine):
        self.engine = engine
        self._lock = threading.Lock()
        # epoch -> shard count at that epoch, for shards_since(); seeded
        # with the engine's current epoch so a plane attached late still
        # resolves deltas from its attach point.
        self._shard_count_at: Dict[int, int] = {
            engine.epoch: len(engine.shards)}
        self.appends = 0             # epochs installed through this plane
        self.records_ingested = 0    # records those epochs added

    @property
    def epoch(self) -> int:
        """The engine's current corpus epoch."""
        return self.engine.epoch

    def append(self, shards: Union[Sequence, np.ndarray, object],
               use_kernel: Optional[bool] = None) -> int:
        """Append one shard (array / ScoreStore) or a sequence of shards;
        returns the new epoch number.

        Only the appended data is sketched (`use_kernel` overrides the
        engine's construction-time kernel choice for that pass); all other
        state updates are O(n_chunks) rebuilds from cached masses. Safe to
        call concurrently with query execution — in-flight plans keep
        their pinned epoch.
        """
        if isinstance(shards, (list, tuple)):
            batch = list(shards)
        else:
            batch = [shards]
        with self._lock:
            before = self.engine.n_total
            state = self.engine._append_shards(batch, use_kernel=use_kernel)
            self._shard_count_at[state.epoch] = len(state.shards)
            self.appends += 1
            self.records_ingested += state.n_total - before
            return state.epoch

    def shards_since(self, epoch: int) -> List[int]:
        """Shard ids appended strictly after `epoch` (through this plane).

        The re-emission unit: a standing query certified at `epoch` only
        needs a threshold walk over these shards to catch up to the
        current corpus.
        """
        with self._lock:
            if epoch not in self._shard_count_at:
                raise ValueError(
                    f"epoch {epoch} was not recorded by this IngestPlane "
                    f"(known: {sorted(self._shard_count_at)})")
            return list(range(self._shard_count_at[epoch],
                              len(self.engine.shards)))

    def pin(self) -> CorpusState:
        """Snapshot the current epoch (delegates to `engine.pin()`).
        Counts as a live reference — pair with `unpin` so epoch GC can
        free superseded epochs."""
        return self.engine.pin()

    def unpin(self, state: CorpusState) -> None:
        """Release a `pin` reference (delegates to `engine.unpin()`)."""
        self.engine.unpin(state)
