"""Atomic filesystem commit primitives + the named-crashpoint hook.

This is the leaf of the durability plane: every on-disk mutation the
repo wants to survive a crash goes through one of three shapes —

  * **atomic replace** (`atomic_write_bytes` / `atomic_write_json`):
    write to a same-directory temp file, fsync it, `os.replace` it over
    the destination, fsync the directory. A crash at any instant leaves
    either the old file or the new file, never a mixture.
  * **length commit** (`commit_length` / `committed_length`): for files
    that only ever *grow* (a `ScoreStore`'s backing array), the data is
    written and fsync'd past the committed length first, then the new
    length is published through an atomically-replaced sidecar. Bytes
    past the committed length are recovery garbage by definition and
    are truncated away on the next open.
  * **fsync barriers** (`fsync_path` / `fsync_dir`): make already-written
    bytes (and directory entries) durable before a dependent commit.

**Crashpoints.** Durable code announces the instants a crash is
interesting by calling ``crashpoint("name")`` between its write and its
commit. In production the hook is unset and the call is a dict lookup;
under test, `repro.testing.CrashInjector` installs a hook that raises
`SimulatedCrash` at a scheduled hit — deterministic kill-at-this-
instant, no signals or subprocesses. The registry of names is
`CRASHPOINTS`; injectors validate against it so a renamed point cannot
silently turn a crash test into a no-op.

No repro-internal imports: `repro.data.pipeline` (and anything else)
can depend on this module without cycles.

>>> import tempfile, os, pathlib
>>> d = tempfile.mkdtemp()
>>> p = os.path.join(d, "state.json")
>>> atomic_write_json(p, {"epoch": 1})
>>> read_json(p)["epoch"]
1
>>> atomic_write_json(p, {"epoch": 2})     # replace, never a torn mix
>>> read_json(p)["epoch"]
2
>>> commit_length(p, 10)
>>> committed_length(p)
10
"""
from __future__ import annotations

import json
import os
from typing import Callable, Optional

# Every named instant a `CrashInjector` may kill at. Grouped by the
# commit path that announces them; see each call site for the exact
# write-vs-commit window the point sits in.
CRASHPOINTS = (
    "pre_fsync",                  # atomic replace: temp written, not yet durable
    "pre_rename",                 # atomic replace: durable temp, not yet visible
    "journal_pre_append",         # journal: record not yet written at all
    "journal_pre_fsync",          # journal: frame written, not yet durable
    "post_journal_pre_install",   # ingest: journaled, epoch not yet installed
    "pre_length_commit",          # store append: data durable, length not committed
    "mid_bitmask_commit",         # bitmask grow: file grown, meta not committed
    "pre_snapshot_publish",       # snapshot: state built, not yet replacing
)

_hook: Optional[Callable[[str], None]] = None


def set_crash_hook(fn: Optional[Callable[[str], None]]) -> None:
    """Install (or clear, with None) the process-wide crashpoint hook.

    Test-only surface: `repro.testing.CrashInjector` is the supported
    installer. The hook is called with the crashpoint name and may raise
    to simulate the process dying at that instant.
    """
    global _hook
    _hook = fn


def crashpoint(name: str) -> None:
    """Announce a named crash-interesting instant (no-op in production)."""
    if _hook is not None:
        _hook(name)


def fsync_path(path) -> None:
    """fsync an existing file's contents to stable storage."""
    fd = os.open(str(path), os.O_RDWR)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_dir(path) -> None:
    """fsync a directory so renames/creates inside it are durable."""
    fd = os.open(str(path), os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_bytes(path, data: bytes) -> None:
    """Atomically replace `path` with `data` (write temp, fsync, rename).

    A crash at any instant leaves either the previous file or the new
    one — `crashpoint("pre_fsync")` and `crashpoint("pre_rename")` mark
    the two windows a `CrashInjector` can kill in to prove it.
    """
    path = str(path)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        crashpoint("pre_fsync")
        os.fsync(f.fileno())
    crashpoint("pre_rename")
    os.replace(tmp, path)
    fsync_dir(os.path.dirname(path) or ".")


def atomic_write_json(path, obj) -> None:
    """Atomically replace `path` with `obj` serialized as JSON."""
    atomic_write_bytes(path, (json.dumps(obj, sort_keys=True) + "\n")
                       .encode("utf-8"))


def read_json(path, default=None):
    """Read a JSON file; `default` when it does not exist (or is torn —
    an interrupted non-atomic writer; atomic writers never leave one)."""
    try:
        with open(str(path), "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError):
        return default


def _length_sidecar(path) -> str:
    return f"{path}.commit"


def commit_length(path, length: int) -> None:
    """Publish `length` as `path`'s committed length (atomic sidecar).

    The second phase of a grow-only file's two-phase append: call only
    after the bytes below `length` are written *and fsync'd*.
    """
    atomic_write_json(_length_sidecar(path), {"length": int(length)})


def committed_length(path, default: Optional[int] = None) -> Optional[int]:
    """Read `path`'s committed length; `default` when never committed."""
    meta = read_json(_length_sidecar(path))
    if meta is None:
        return default
    return int(meta["length"])


def discard_uncommitted_tail(path) -> Optional[int]:
    """Truncate `path` down to its committed length (crash recovery for
    grow-only files). Returns the committed length, or None when the
    file has no length sidecar (nothing to recover against)."""
    n = committed_length(path)
    if n is None:
        return None
    if os.path.getsize(str(path)) > n:
        with open(str(path), "r+b") as f:
            f.truncate(n)
            f.flush()
            os.fsync(f.fileno())
    return n


def publish_dir(tmp, final) -> None:
    """Atomically publish a staged directory: `os.replace` the temp dir
    over `final` and fsync the parent so the rename is durable. The
    checkpointing primitive `repro.ckpt` stages under."""
    os.replace(str(tmp), str(final))
    fsync_dir(os.path.dirname(str(final)) or ".")
