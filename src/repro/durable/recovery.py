"""`DurabilityPlane` — journal + shard spool + snapshot for one corpus.

Composes the layer's pieces into the recovery unit a `SelectionServer`
(or a bare `IngestPlane`) owns:

  * `record_append` makes an append durable *before* it is installed:
    each shard's bytes are spooled to ``<root>/shards/`` through an
    atomic replace (with a content CRC recorded alongside), then one
    journal record names the new epoch and its shard manifest, then
    ``crashpoint("post_journal_pre_install")`` marks the window where
    the intent is durable but the in-memory epoch is not.
  * `replay_into` rebuilds a corpus: every journaled epoch past the
    target plane's current one is loaded from the spool (CRC-checked)
    and re-applied through `IngestPlane.append`. Re-sketching is
    deterministic — the delta path is bit-for-bit a cold build (PR 9's
    guarantee) — so replay reproduces the crashed corpus exactly, and
    replaying an already-applied record is a no-op (the epoch guard
    skips it).
  * `write_snapshot` / `read_snapshot` persist the serving-plane state
    that must *not* be recomputed (certified taus, ledger balances,
    sentinel reference probes) through one atomic JSON replace.

What is deliberately *not* journaled: oracle labels and query results.
Certifications are snapshotted, never re-run — recovery re-derives only
what is free and deterministic (sketches, CDFs, threshold walks) and
restores what cost oracle budget.

>>> import numpy as np, tempfile
>>> from repro.core.engine import SelectionEngine
>>> from repro.live.ingest import IngestPlane
>>> root = tempfile.mkdtemp()
>>> base = np.linspace(0, 1, 256, dtype=np.float32)
>>> dur = DurabilityPlane(root)
>>> with SelectionEngine([base], num_bins=32, use_kernel=False) as eng:
...     plane = IngestPlane(eng)
...     arrs = dur.record_append(np.full(128, 0.5, np.float32),
...                              epoch=plane.epoch + 1)
...     epoch = plane.append(arrs)
...     n_crashed = eng.n_total
>>> with SelectionEngine([base], num_bins=32, use_kernel=False) as eng2:
...     replayed = dur.replay_into(IngestPlane(eng2))
...     (replayed, eng2.n_total == n_crashed, eng2.epoch)
(1, True, 1)
"""
from __future__ import annotations

import dataclasses
import io
import os
import zlib
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.durable import atomic
from repro.durable.journal import EpochJournal

SNAPSHOT_NAME = "snapshot.json"


def encode_key(key) -> Optional[dict]:
    """Serialize a PRNG key array (or None) to a JSON-safe dict."""
    if key is None:
        return None
    arr = np.asarray(key)
    return {"dtype": str(arr.dtype), "data": arr.tolist()}


def decode_key(obj: Optional[dict]):
    """Inverse of `encode_key`."""
    if obj is None:
        return None
    return np.asarray(obj["data"], dtype=np.dtype(obj["dtype"]))


def encode_query(q) -> dict:
    """Serialize a `SUPGQuery` / `JointSUPGQuery` to a JSON-safe dict."""
    kind = type(q).__name__
    if kind not in ("SUPGQuery", "JointSUPGQuery"):
        raise TypeError(f"cannot serialize query of type {kind}")
    return {"kind": kind, "fields": dataclasses.asdict(q)}


def decode_query(obj: dict):
    """Inverse of `encode_query`."""
    from repro.core.queries import JointSUPGQuery, SUPGQuery
    cls = {"SUPGQuery": SUPGQuery,
           "JointSUPGQuery": JointSUPGQuery}[obj["kind"]]
    return cls(**obj["fields"])


def _normalize_batch(shards: Union[Sequence, np.ndarray, object]) \
        -> List[np.ndarray]:
    """One shard or a sequence -> list of arrays, exactly as
    `IngestPlane.append` normalizes (ScoreStores pass their memmap)."""
    batch = (list(shards) if isinstance(shards, (list, tuple))
             else [shards])
    return [np.asarray(getattr(s, "scores", s)) for s in batch]


class DurabilityPlane:
    """Owns one corpus's journal, shard spool, and snapshot file.

    Layout under `root`::

        journal.log       append-only epoch journal (CRC-framed)
        shards/           spooled shard payloads, one .npy per shard
        snapshot.json     latest serving-state snapshot (atomic replace)
    """

    def __init__(self, root):
        self.root = str(root)
        self.shard_dir = os.path.join(self.root, "shards")
        os.makedirs(self.shard_dir, exist_ok=True)
        self.journal = EpochJournal(os.path.join(self.root, "journal.log"))
        self.journaled_appends = 0    # appends recorded this process
        self.replayed_epochs = 0      # epochs re-applied by replay_into
        self.snapshots = 0            # snapshots written this process

    # -- observability ---------------------------------------------------

    @property
    def journal_records(self) -> int:
        """Valid records currently in the journal (including recovered)."""
        return len(self.journal)

    @property
    def journal_bytes(self) -> int:
        """Valid journal bytes on disk."""
        return self.journal.valid_bytes

    # -- write-ahead append ----------------------------------------------

    def record_append(self, shards, *, epoch: int) -> List[np.ndarray]:
        """Durably record an append destined to install as `epoch`.

        Spools each shard's bytes (atomic replace + content CRC), then
        journals the epoch manifest, then announces
        `post_journal_pre_install`. Returns the normalized shard list so
        the caller installs exactly what was journaled. A crash before
        the journal fsync means the append was never acknowledged — the
        client retries; the epoch guard in `replay_into` (and the
        caller's resume path) makes the retry exactly-once.
        """
        arrs = _normalize_batch(shards)
        manifest = []
        for i, arr in enumerate(arrs):
            name = f"epoch_{epoch:08d}_{i:04d}.npy"
            buf = io.BytesIO()
            np.save(buf, np.ascontiguousarray(arr))
            data = buf.getvalue()
            atomic.atomic_write_bytes(os.path.join(self.shard_dir, name),
                                      data)
            manifest.append({"file": name, "records": int(arr.shape[0]),
                             "crc": zlib.crc32(data) & 0xFFFFFFFF})
        self.journal.append({"type": "append", "epoch": int(epoch),
                             "shards": manifest})
        self.journaled_appends += 1
        atomic.crashpoint("post_journal_pre_install")
        return arrs

    def _load_shard(self, entry: dict) -> np.ndarray:
        path = os.path.join(self.shard_dir, entry["file"])
        with open(path, "rb") as f:
            data = f.read()
        if zlib.crc32(data) & 0xFFFFFFFF != entry["crc"]:
            raise ValueError(
                f"spooled shard {entry['file']} fails its content CRC — "
                f"the journal acknowledged bytes that are no longer on "
                f"disk")
        arr = np.load(io.BytesIO(data), allow_pickle=False)
        if int(arr.shape[0]) != entry["records"]:
            raise ValueError(
                f"spooled shard {entry['file']} has {arr.shape[0]} "
                f"records, journal says {entry['records']}")
        return arr

    def replay_into(self, plane, *, use_kernel: Optional[bool] = None) \
            -> int:
        """Re-apply journaled appends past `plane`'s current epoch.

        `plane` is an `IngestPlane` (anything with ``epoch`` and
        ``append``). Records at or below the current epoch are skipped —
        replaying an already-applied record is a no-op — so the call is
        idempotent and safe to run on a half-recovered corpus. Returns
        the number of epochs applied.
        """
        applied = 0
        for rec in self.journal.replay():
            if rec.get("type") != "append":
                continue
            if int(rec["epoch"]) <= plane.epoch:
                continue
            arrs = [self._load_shard(e) for e in rec["shards"]]
            got = plane.append(arrs, use_kernel=use_kernel)
            if got != int(rec["epoch"]):
                raise RuntimeError(
                    f"journal replay installed epoch {got}, expected "
                    f"{rec['epoch']} — the journal and corpus disagree")
            applied += 1
        self.replayed_epochs += applied
        return applied

    # -- snapshots --------------------------------------------------------

    @property
    def snapshot_path(self) -> str:
        """Path of the snapshot file (may not exist yet)."""
        return os.path.join(self.root, SNAPSHOT_NAME)

    def write_snapshot(self, state: dict) -> str:
        """Atomically publish a serving-state snapshot; returns its path.

        `pre_snapshot_publish` marks the window before the replace: a
        crash there leaves the previous snapshot fully intact.
        """
        atomic.crashpoint("pre_snapshot_publish")
        atomic.atomic_write_json(self.snapshot_path, state)
        self.snapshots += 1
        return self.snapshot_path

    def read_snapshot(self) -> Optional[dict]:
        """The latest snapshot, or None when none was ever published."""
        return atomic.read_json(self.snapshot_path)

    def close(self) -> None:
        """Close the journal's file handle. Idempotent."""
        self.journal.close()
