"""Durability & crash-recovery plane.

Three layers, leaf first:

  * `repro.durable.atomic` — atomic replace, two-phase length commit,
    fsync barriers, and the named-crashpoint hook (`CRASHPOINTS`).
  * `repro.durable.journal` — `EpochJournal`, the CRC-framed, fsync'd,
    torn-tail-tolerant write-ahead log of corpus appends.
  * `repro.durable.recovery` — `DurabilityPlane`, composing journal +
    shard spool + snapshot into the unit `SelectionServer` owns; plus
    the query/key codecs snapshots serialize with.

See `docs/guarantees.md` ("Durability & recovery") for the contract:
what survives a crash, and why a recovered tau is still certified.
"""
from repro.durable.atomic import (
    CRASHPOINTS,
    atomic_write_bytes,
    atomic_write_json,
    commit_length,
    committed_length,
    crashpoint,
    discard_uncommitted_tail,
    read_json,
    set_crash_hook,
)
from repro.durable.journal import EpochJournal, scan
from repro.durable.recovery import (
    DurabilityPlane,
    decode_key,
    decode_query,
    encode_key,
    encode_query,
)

__all__ = [
    "CRASHPOINTS",
    "DurabilityPlane",
    "EpochJournal",
    "atomic_write_bytes",
    "atomic_write_json",
    "commit_length",
    "committed_length",
    "crashpoint",
    "decode_key",
    "decode_query",
    "discard_uncommitted_tail",
    "encode_key",
    "encode_query",
    "read_json",
    "scan",
    "set_crash_hook",
]
