"""`EpochJournal` — a CRC-framed, fsync'd, torn-tail-tolerant record log.

The write-ahead journal of the durability plane: every corpus append is
recorded here *before* it is installed in memory, so a crashed process
can rebuild exactly the epochs it acknowledged (plus at most one it
journaled but never got to install — which replay applies, matching the
uncrashed timeline; see `docs/guarantees.md`, "Durability & recovery").

Framing: each record is ``MAGIC(4) | payload_len(u32 LE) | crc32(u32
LE) | payload`` with a JSON payload. Appends write the frame then fsync
before acknowledging; `scan` walks frames from the start and stops at
the first bad magic, short frame, or CRC mismatch — a torn tail (the
one frame a mid-write crash can leave) is silently dropped, and a
journal opened for append truncates that tail away so the next record
lands on a clean boundary. Replay therefore never raises on a crashed
file and never invents a record.

>>> import tempfile, os
>>> path = os.path.join(tempfile.mkdtemp(), "journal.log")
>>> with EpochJournal(path) as j:
...     _ = j.append({"type": "append", "epoch": 1})
...     _ = j.append({"type": "append", "epoch": 2})
>>> [r["epoch"] for r in EpochJournal(path).replay()]
[1, 2]
>>> with open(path, "ab") as f:       # torn tail: half a record
...     _ = f.write(b"EPJ1\\x99")
>>> [r["epoch"] for r in EpochJournal(path).replay()]
[1, 2]
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import List, Optional, Tuple

from repro.durable.atomic import crashpoint, fsync_dir

MAGIC = b"EPJ1"
_HEADER = struct.Struct("<4sII")      # magic, payload length, crc32


def _frame(record: dict) -> bytes:
    payload = json.dumps(record, sort_keys=True).encode("utf-8")
    return _HEADER.pack(MAGIC, len(payload),
                        zlib.crc32(payload) & 0xFFFFFFFF) + payload


def scan(path) -> Tuple[List[dict], int]:
    """Parse every valid record of a journal file.

    Returns ``(records, valid_bytes)`` where `valid_bytes` is the byte
    offset of the first invalid frame (== file size for a clean file).
    Tolerant by construction: a missing file is an empty journal, and
    the scan stops — without raising — at the first torn, truncated, or
    corrupt frame, so a crash mid-append can only ever cost the record
    being written, never a parsed-garbage epoch.
    """
    try:
        with open(str(path), "rb") as f:
            data = f.read()
    except OSError:
        return [], 0
    records: List[dict] = []
    off = 0
    while off + _HEADER.size <= len(data):
        magic, length, crc = _HEADER.unpack_from(data, off)
        if magic != MAGIC:
            break
        start = off + _HEADER.size
        payload = data[start:start + length]
        if len(payload) < length:
            break                      # torn tail: frame cut short
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            break                      # corrupt frame: stop, don't guess
        try:
            records.append(json.loads(payload.decode("utf-8")))
        except ValueError:
            break
        off = start + length
    return records, off


class EpochJournal:
    """Append-only record log with CRC framing and fsync'd appends.

    Opening scans the existing file and truncates any torn tail (the
    incomplete frame a mid-write crash leaves) so appends resume on a
    record boundary. `append` is durable on return: the frame is
    written and fsync'd before the call acknowledges.
    """

    def __init__(self, path):
        self.path = str(path)
        parent = os.path.dirname(self.path) or "."
        os.makedirs(parent, exist_ok=True)
        records, valid = scan(self.path)
        self._records = records
        created = not os.path.exists(self.path)
        self._f = open(self.path, "ab" if created else "r+b")
        if created:
            fsync_dir(parent)          # make the journal's name durable
        else:
            self._f.truncate(valid)    # drop the torn tail, if any
        self._f.seek(valid)
        self.valid_bytes = valid

    def __enter__(self) -> "EpochJournal":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def __len__(self) -> int:
        return len(self._records)

    @property
    def records(self) -> List[dict]:
        """The journal's valid records (snapshot copy)."""
        return list(self._records)

    def append(self, record: dict) -> int:
        """Durably append one record; returns its index.

        Two crashpoints bracket the write: `journal_pre_append` (crash
        → nothing written, the caller never acknowledged) and
        `journal_pre_fsync` (crash → the frame may survive in the page
        cache; replay applies it — same outcome the caller was about to
        acknowledge).
        """
        frame = _frame(record)
        crashpoint("journal_pre_append")
        self._f.write(frame)
        self._f.flush()
        crashpoint("journal_pre_fsync")
        os.fsync(self._f.fileno())
        self._records.append(record)
        self.valid_bytes += len(frame)
        return len(self._records) - 1

    def replay(self) -> List[dict]:
        """Re-scan the file from disk and return every valid record."""
        return scan(self.path)[0]

    def close(self) -> None:
        """Close the underlying file handle. Idempotent."""
        if not self._f.closed:
            self._f.close()
