"""SUPG query execution — Algorithm 1 plus RT/PT/JT semantics (Section 3).

A query is:

    SELECT * FROM D WHERE oracle(x) ORACLE LIMIT s
    USING proxy_scores [RECALL | PRECISION] TARGET gamma WITH PROBABILITY 1-delta

`run_query` drives Algorithm 1:

    S   <- SampleOracle(D)            (core.sampling — uniform / sqrt-IS)
    tau <- EstimateTau(S)             (core.thresholds — Algs. 2-5)
    R   <- {x in S : O(x)=1}  ∪  {x in D : A(x) >= tau}

The sampled positives R1 are always included — for RT queries they can only
help recall; for PT queries they are exact positives so they can only help
precision. Joint-target (JT) queries (Appendix A) run the RT estimator with
an optimistic budget then exhaustively filter false positives.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import sampling, thresholds
from repro.core.oracle import BudgetLedger, as_oracle_client


@dataclasses.dataclass(frozen=True)
class SUPGQuery:
    target: str                 # 'recall' | 'precision'
    gamma: float                # target value in (0, 1)
    delta: float = 0.05         # failure probability
    budget: int = 10_000        # ORACLE LIMIT
    method: str = "is"          # 'is' (SUPG), 'uniform' (U-CI), 'nocI' (U-NoCI)
    weight_scheme: str = "sqrt"  # 'sqrt' (Theorem 1) | 'prop' (baseline)
    two_stage: bool = True      # PT only: Algorithm 5 vs one-stage
    defensive: bool = True      # Owen-Zhou defensive mixing
    min_step: int = thresholds.MIN_STEP

    def __post_init__(self):
        if self.target not in ("recall", "precision"):
            raise ValueError(f"bad target {self.target}")
        if not 0.0 < self.gamma < 1.0:
            raise ValueError("gamma must lie in (0,1)")
        if not 0.0 < self.delta < 1.0:
            raise ValueError("delta must lie in (0,1)")


@dataclasses.dataclass
class QueryResult:
    selected: np.ndarray        # sorted record indices of R = R1 ∪ R2
    tau: float                  # proxy threshold used for R2
    oracle_calls: int           # budget actually consumed
    corrected_target: float     # gamma' diagnostics (RT)
    n_sampled_positives: int    # |R1|

    def mask(self, n: int) -> np.ndarray:
        m = np.zeros(n, bool)
        m[self.selected] = True
        return m


def _labels_for(sample, oracle):
    return oracle(np.asarray(sample.indices))


def run_query(key, scores, oracle_fn, query: SUPGQuery) -> QueryResult:
    """Execute a SUPG query against proxy scores and an oracle callback.

    scores:    (n,) float array of proxy scores A(x) for every record.
    oracle_fn: callback indices -> {0,1} labels, or an
               `oracle.OracleClient` (e.g. a shared `BatchingOracle`) —
               either way requests ride the batched labeling channel via
               `as_oracle_client`, with budget enforced through this
               query's own `BudgetLedger` view.
    """
    scores = np.asarray(jax.device_get(scores), np.float32)
    n = scores.shape[0]
    # Normalize the key once so RT and PT accept key=None identically.
    key = jax.random.PRNGKey(0) if key is None else key
    client = as_oracle_client(oracle_fn)
    ledger = BudgetLedger(query.budget)

    def oracle(indices):
        return client.submit(indices, ledger=ledger).result()

    s = query.budget
    if query.target == "recall":
        res = _run_rt(key, scores, oracle, s, query)
    else:
        res = _run_pt(key, scores, oracle, s, query)
    tau, corrected = res

    r1 = ledger.labeled_positives()
    r2 = np.nonzero(scores >= tau)[0]
    selected = np.union1d(r1, r2)
    return QueryResult(selected=selected, tau=float(tau),
                       oracle_calls=ledger.charged,
                       corrected_target=float(corrected),
                       n_sampled_positives=int(r1.shape[0]))


def _run_rt(key, scores, oracle, s, q):
    scheme = {"is": q.weight_scheme, "uniform": "uniform",
              "noci": "uniform"}[q.method]
    sample = sampling.draw_oracle_sample(key, scores, s, scheme=scheme,
                                         defensive=q.defensive)
    o_s = _labels_for(sample, oracle)
    a_s = scores[np.asarray(sample.indices)]
    if q.method == "noci":
        res = thresholds.tau_unoci_r(a_s, o_s, q.gamma)
    else:
        res = thresholds.tau_ci_r(a_s, o_s, sample.m, q.gamma, q.delta)
    return float(res.tau), float(res.corrected_target)


def _run_pt(key, scores, oracle, s, q):
    k0, k1 = jax.random.split(key)
    if q.method == "noci":
        sample = sampling.draw_oracle_sample(k0, scores, s, scheme="uniform")
        o_s = _labels_for(sample, oracle)
        a_s = scores[np.asarray(sample.indices)]
        res = thresholds.tau_unoci_p(a_s, o_s, q.gamma)
        return float(res.tau), q.gamma

    if q.method == "uniform" or not q.two_stage:
        scheme = "uniform" if q.method == "uniform" else q.weight_scheme
        sample = sampling.draw_oracle_sample(k0, scores, s, scheme=scheme)
        o_s = _labels_for(sample, oracle)
        a_s = scores[np.asarray(sample.indices)]
        m_s = None if scheme == "uniform" else sample.m
        res = thresholds.tau_ci_p(a_s, o_s, q.gamma, q.delta, m_s=m_s,
                                  min_step=q.min_step)
        return float(res.tau), q.gamma

    # ---- Algorithm 5: two-stage importance sampling -----------------------
    # Stage 1 (budget s/2): UB the number of matches; restrict to D'.
    s0 = s // 2
    sample0 = sampling.draw_oracle_sample(k0, scores, s0,
                                          scheme=q.weight_scheme,
                                          defensive=q.defensive)
    o_s0 = _labels_for(sample0, oracle)
    n_match, rank = thresholds.pt_stage1_nmatch(
        o_s0, sample0.m, scores.shape[0], q.gamma, q.delta)
    tau_dprime = thresholds.dprime_cutoff_score(scores, rank)

    # Stage 2 (budget s/2): sample *uniformly within D'* — the restriction
    # itself is the importance step; uniform-in-D' keeps the printed
    # Algorithm-5 precision estimator (plain O-values) unbiased.
    mask = (scores >= float(tau_dprime)).astype(np.float32)
    sample1 = sampling.sample_weighted_masked(
        k1, np.ones_like(scores), mask, s - s0)
    o_s1 = _labels_for(sample1, oracle)
    a_s1 = scores[np.asarray(sample1.indices)]
    res = thresholds.tau_ci_p(a_s1, o_s1, q.gamma, q.delta / 2.0,
                              min_step=q.min_step)
    return float(res.tau), q.gamma


# ---------------------------------------------------------------------------
# Joint-target queries (Appendix A)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class JointSUPGQuery:
    """Declarative JT query spec for the engine's batched `run_many` plane.

    Semantics match `run_joint_query`: an RT stage at gamma_recall under
    stage_budget, then exhaustive oracle filtering of the candidate set
    (which makes the achieved precision exactly 1.0 >= gamma_precision;
    total oracle usage is unbounded by design, Appendix A).
    """
    gamma_recall: float
    gamma_precision: float = 1.0
    delta: float = 0.05
    stage_budget: int = 10_000
    method: str = "is"

    def __post_init__(self):
        if not 0.0 < self.gamma_recall < 1.0:
            raise ValueError("gamma_recall must lie in (0,1)")
        if not 0.0 < self.gamma_precision <= 1.0:
            raise ValueError("gamma_precision must lie in (0,1]")


@dataclasses.dataclass
class JointResult:
    selected: np.ndarray
    oracle_calls: int
    stage2_tau: float


def run_joint_query(key, scores, oracle_fn, gamma_recall, gamma_precision,
                    delta=0.05, stage_budget=10_000, method="is"):
    """JT query: RT subroutine + exhaustive false-positive filtering.

    1. optimistically allocate budget B for the RT stage;
    2. run IS-CI-R (or U-CI-R) at gamma_recall — with prob 1-delta the
       candidate set has sufficient recall;
    3. exhaustively oracle-label the candidate set, keep true positives.
       Total oracle usage is unbounded by design (Appendix A semantics).
    """
    scores_np = np.asarray(jax.device_get(scores), np.float32)
    q = SUPGQuery(target="recall", gamma=gamma_recall, delta=delta,
                  budget=stage_budget, method=method)
    # One labeling channel for both stages (also lets callers hand in an
    # OracleClient directly). RT keeps its own budget accounting.
    client = as_oracle_client(oracle_fn)
    rt_res = run_query(key, scores_np, client, q)
    # Stage 3: exhaustive filtering of the candidate set. No budget cap
    # here (the ledger is capped at n for attribution only); candidates
    # the RT stage already labeled are answered from the channel's cache.
    ledger = BudgetLedger(scores_np.shape[0])
    labels = client.submit(rt_res.selected, ledger=ledger).result()
    keep = rt_res.selected[labels > 0.5]
    total_calls = rt_res.oracle_calls + ledger.charged
    return JointResult(selected=keep, oracle_calls=total_calls,
                       stage2_tau=rt_res.tau)


# ---------------------------------------------------------------------------
# Result metrics (Section 3.2)
# ---------------------------------------------------------------------------

def precision_of(selected, truth_mask) -> float:
    sel = np.zeros_like(truth_mask, dtype=bool)
    sel[np.asarray(selected, np.int64)] = True
    denom = max(int(sel.sum()), 1)
    return float((sel & truth_mask).sum() / denom)


def recall_of(selected, truth_mask) -> float:
    sel = np.zeros_like(truth_mask, dtype=bool)
    sel[np.asarray(selected, np.int64)] = True
    denom = max(int(truth_mask.sum()), 1)
    return float((sel & truth_mask).sum() / denom)
