"""Proxy-threshold estimation — Algorithms 2-5 of the paper, vectorized in JAX.

Estimators (names follow Section 5):

  U-NoCI-R / U-NoCI-P : empirical threshold on a uniform sample, *no* CI
                        (the NoScope / probabilistic-predicates baseline —
                        provides NO guarantee; kept for Figures 1/5/6).
  U-CI-R   (Alg. 2)   : uniform sample + Lemma-1 corrected recall target.
  U-CI-P   (Alg. 3)   : uniform sample + per-candidate precision LBs with a
                        delta/M union bound over M = ceil(s/m) candidates.
  IS-CI-R  (Alg. 4)   : sqrt-proxy importance sample + reweighted Alg. 2.
  IS-CI-P  (Alg. 5)   : two-stage — stage 1 upper-bounds n_match with a
                        weighted sample; stage 2 samples from the top
                        n_match/gamma scores and runs the Alg. 3 scan.

Every estimator is a pure function of (sample arrays, targets); sampling and
oracle calls live in queries.py. All are jit-compatible: selection over
thresholds is expressed as prefix scans over score-sorted samples.

Tie/convention notes: thresholds returned are *inclusive* (the query returns
{x : A(x) >= tau}); selecting "the largest tau with Recall >= gamma" maps to
"the shortest descending-sorted prefix whose recall passes gamma".
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bounds

MIN_STEP = 100  # paper's minimum candidate step size m


class ThresholdResult(NamedTuple):
    tau: jnp.ndarray            # scalar float32 — inclusive score threshold
    corrected_target: jnp.ndarray  # gamma' (RT) or gamma (PT); diagnostics
    n_candidates: jnp.ndarray   # M for PT scans, 1 for RT
    valid: jnp.ndarray          # bool — False if no candidate met the target


def _sort_desc(a_s, *arrays):
    order = jnp.argsort(-a_s)
    return (a_s[order],) + tuple(arr[order] for arr in arrays)


# ---------------------------------------------------------------------------
# Recall-target estimators
# ---------------------------------------------------------------------------

def _recall_prefix_curve(a_desc, om_desc):
    """Recall_{S_w}(tau_j) for every prefix j (tau_j = a_desc[j])."""
    csum = jnp.cumsum(om_desc)
    total = jnp.maximum(csum[-1], 1e-30)
    return csum / total


def _max_tau_for_recall(a_desc, recall_curve, gamma):
    """max{tau : Recall(tau) >= gamma} == score at the shortest passing prefix.

    If even the full sample misses gamma (only possible with gamma > 1 after
    correction), fall back to tau = -inf (return everything — always valid
    for recall).
    """
    ok = recall_curve >= gamma
    any_ok = jnp.any(ok)
    # argmax finds first True; guard the all-False case.
    j = jnp.argmax(ok)
    tau = jnp.where(any_ok, a_desc[j], -jnp.inf)
    return tau, any_ok


@jax.jit
def tau_unoci_r(a_s, o_s, gamma):
    """U-NoCI-R: empirical threshold, no confidence correction (Eq. 6)."""
    a_desc, o_desc = _sort_desc(jnp.asarray(a_s, jnp.float32),
                                jnp.asarray(o_s, jnp.float32))
    curve = _recall_prefix_curve(a_desc, o_desc)
    tau, _ = _max_tau_for_recall(a_desc, curve, gamma)
    return ThresholdResult(tau, jnp.float32(gamma), jnp.int32(1),
                           jnp.bool_(True))


@jax.jit
def tau_ci_r(a_s, o_s, m_s, gamma, delta):
    """Algorithms 2 & 4 (unified): CI-corrected recall-target threshold.

    For uniform samples pass m_s = 1; for importance samples pass the
    reweighting factors m(x) = u(x)/w(x). Implements:

        tau_o  <- max{tau : Recall_{S_w}(tau) >= gamma}
        Z1/Z2  <- reweighted positives above/below tau_o
        gamma' <- UB(Z1)/(UB(Z1) + LB(Z2))        (each at delta/2)
        tau'   <- max{tau : Recall_{S_w}(tau) >= gamma'}
    """
    a_s = jnp.asarray(a_s, jnp.float32)
    o_s = jnp.asarray(o_s, jnp.float32)
    m_s = jnp.broadcast_to(jnp.asarray(m_s, jnp.float32), a_s.shape)
    s = a_s.shape[0]

    a_desc, om_desc = _sort_desc(a_s, o_s * m_s)
    curve = _recall_prefix_curve(a_desc, om_desc)
    tau_o, _ = _max_tau_for_recall(a_desc, curve, gamma)

    above = (a_desc >= tau_o).astype(jnp.float32)
    z1 = om_desc * above          # 1[A >= tau_o] O m, all s entries
    z2 = om_desc * (1.0 - above)  # 1[A <  tau_o] O m
    mu1, sg1 = bounds.sample_mean_std(z1)
    mu2, sg2 = bounds.sample_mean_std(z2)
    ub1 = bounds.ub(mu1, sg1, s, delta / 2.0)
    lb2 = jnp.maximum(bounds.lb(mu2, sg2, s, delta / 2.0), 0.0)
    gamma_p = jnp.clip(ub1 / jnp.maximum(ub1 + lb2, 1e-30), gamma, 1.0)

    tau_p, ok = _max_tau_for_recall(a_desc, curve, gamma_p)
    # gamma' > max achievable recall on S => take the most conservative
    # threshold observed (include the whole sampled range).
    tau_p = jnp.where(ok, tau_p, a_desc[-1])
    return ThresholdResult(tau_p, gamma_p, jnp.int32(1), jnp.bool_(True))


# ---------------------------------------------------------------------------
# Precision-target estimators
# ---------------------------------------------------------------------------

def _precision_candidate_scan(a_desc, o_desc, w_desc, gamma, delta,
                              min_step=MIN_STEP):
    """Shared Algorithm-3 scan: per-candidate precision LBs, delta/M each.

    Candidates are the descending-sorted sample prefixes of length
    j in {m, 2m, ..., s}; candidate threshold tau_j = a_desc[j-1]. For each,
    Z(tau_j) = weighted O-values of the prefix; LB uses Lemma 1 at delta/M.
    Returns the smallest passing threshold (largest passing prefix).
    """
    s = a_desc.shape[0]
    m_step = min(min_step, s)
    num_cand = max(s // m_step, 1)

    mu, sg, n = bounds.weighted_prefix_mean_std(o_desc, w_desc)
    p_l = bounds.lb(mu, sg, n, delta / num_cand)

    idx = jnp.arange(1, s + 1)
    is_cand = (idx % m_step == 0) & (idx <= num_cand * m_step)
    passing = is_cand & (p_l > gamma)

    any_pass = jnp.any(passing)
    # Smallest tau == largest passing prefix == last passing index.
    j = jnp.where(any_pass,
                  (s - 1) - jnp.argmax(passing[::-1]),
                  0)
    tau = jnp.where(any_pass, a_desc[j], jnp.inf)  # inf => empty set (valid)
    return tau, jnp.int32(num_cand), any_pass


@jax.jit
def tau_unoci_p(a_s, o_s, gamma):
    """U-NoCI-P: min{tau : empirical Precision_S(tau) >= gamma} (Eq. 5)."""
    a_desc, o_desc = _sort_desc(jnp.asarray(a_s, jnp.float32),
                                jnp.asarray(o_s, jnp.float32))
    n = jnp.arange(1, a_desc.shape[0] + 1, dtype=jnp.float32)
    prec = jnp.cumsum(o_desc) / n
    passing = prec >= gamma
    any_pass = jnp.any(passing)
    j = jnp.where(any_pass,
                  (a_desc.shape[0] - 1) - jnp.argmax(passing[::-1]), 0)
    tau = jnp.where(any_pass, a_desc[j], jnp.inf)
    return ThresholdResult(tau, jnp.float32(gamma), jnp.int32(a_desc.shape[0]),
                           any_pass)


@functools.partial(jax.jit, static_argnames=("min_step",))
def tau_ci_p(a_s, o_s, gamma, delta, m_s=None, min_step=MIN_STEP):
    """Algorithm 3 (and stage 2 of Algorithm 5): CI precision threshold.

    With m_s=None the sample is treated as uniform over its population (the
    paper's printed Algorithm 3/5 form, plain O-values). With explicit
    reweighting factors m_s, the scan uses the importance-weighted ratio
    estimator (Eq. 12) with conservative numerator/denominator bounds.
    """
    a_s = jnp.asarray(a_s, jnp.float32)
    o_s = jnp.asarray(o_s, jnp.float32)
    if m_s is None:
        a_desc, o_desc = _sort_desc(a_s, o_s)
        w_desc = jnp.ones_like(a_desc)
    else:
        a_desc, o_desc, w_desc = _sort_desc(a_s, o_s,
                                            jnp.asarray(m_s, jnp.float32))
    tau, num_cand, ok = _precision_candidate_scan(
        a_desc, o_desc, w_desc, gamma, delta, min_step)
    return ThresholdResult(tau, jnp.float32(gamma), num_cand, ok)


@jax.jit
def pt_stage1_nmatch(o_s0, m_s0, n_total, gamma, delta):
    """Stage 1 of Algorithm 5: UB on n_match and the D' cutoff rank.

    Z = {O(x) m(x)}; n_match = |D| * UB(mu_Z, sigma_Z, s/2, delta/2). Records
    below the n_match/gamma-th highest proxy score cannot reach precision
    gamma and are excluded from stage-2 sampling.
    """
    z = jnp.asarray(o_s0, jnp.float32) * jnp.asarray(m_s0, jnp.float32)
    mu, sg = bounds.sample_mean_std(z)
    n_match = n_total * bounds.ub(mu, sg, z.shape[0], delta / 2.0)
    n_match = jnp.clip(n_match, 1.0, n_total)
    rank = jnp.clip(jnp.ceil(n_match / gamma), 1.0, n_total).astype(jnp.int32)
    return n_match, rank


def dprime_cutoff_score(scores, rank):
    """tau such that |{A >= tau}| ~= rank, via a global top-k rank lookup.

    Exact single-host path (jnp.sort). The distributed path approximates the
    same rank from the binned sketch (see binned.py).
    """
    desc = jnp.sort(jnp.asarray(scores, jnp.float32))[::-1]
    idx = jnp.clip(rank - 1, 0, desc.shape[0] - 1)
    return desc[idx]
