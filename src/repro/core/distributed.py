"""Distributed SUPG selection plane: shard_map reductions + two-level sampling.

Scores are sharded over the mesh's data axes ("pod", "data"); the model axis
holds replicas. Three collective patterns cover everything SUPG needs:

  1. global sketch        : per-shard histogram + one psum of (B, 3) floats —
                            B=4096 bins => 48 KiB on the wire, independent of n.
  2. two-level sampling   : multinomial over shards (from psum'd shard weight
                            totals) then within-shard categorical; preserves
                            the paper's with-replacement semantics exactly.
  3. threshold selection  : embarrassingly parallel local filter A(x) >= tau.

Everything here is also runnable on a 1-device mesh (tests/CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import binned


def _data_axes(mesh: Mesh):
    return tuple(ax for ax in mesh.axis_names if ax in ("pod", "data"))


def global_sketch(mesh: Mesh, scores, num_bins=binned.DEFAULT_BINS):
    """Build the global ScoreSketch of a sharded score vector with one psum."""
    axes = _data_axes(mesh)
    spec = P(axes)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(spec,),
        out_specs=P(), check_rep=False)
    def _sketch(local_scores):
        # Collective path stays on the jnp formulation: the fused kernel is
        # the engine's host-local per-shard pass; inside shard_map the
        # scatter-add lowers cleanly on every backend.
        sk = binned.build_sketch(local_scores, num_bins, use_kernel=False)
        return binned.ScoreSketch(
            *[jax.lax.psum(x, axes) for x in sk])

    return _sketch(scores)


def shard_weight_totals(mesh: Mesh, scores, scheme="sqrt", kappa=0.1):
    """Per-shard unnormalized weight mass, all-gathered to every shard.

    Output: (num_data_shards,) vector W with W[i] = sum over shard i of the
    raw weights (sqrt(A) or A) plus the defensive uniform mass — this is the
    first level of the two-level sampler.
    """
    axes = _data_axes(mesh)
    spec = P(axes)

    @functools.partial(shard_map, mesh=mesh, in_specs=(spec,), out_specs=P(),
                       check_rep=False)
    def _totals(local_scores):
        a = jnp.clip(local_scores.astype(jnp.float32), 0.0, 1.0)
        raw = jnp.sqrt(a) if scheme == "sqrt" else a
        local = jnp.sum(raw)
        n_local = jnp.float32(local_scores.shape[0])
        # Gather every shard's (weight, count) pair.
        per_shard = jax.lax.all_gather(
            jnp.stack([local, n_local]), axes, tiled=False)
        return per_shard.reshape(-1, 2)

    return _totals(scores)


def two_level_sample(key, shard_totals, s, kappa=0.1):
    """Allocate s with-replacement draws across shards, then within shards.

    shard_totals: (num_shards, 2) of (raw weight mass, record count).
    Returns (shard_ids, per_draw_keys) for the host-side driver to dispatch
    within-shard categorical draws. The resulting joint distribution equals
    the global defensive-mixed categorical distribution exactly:
        p(x) = (1-kappa) raw(x)/Z + kappa/n_total.
    """
    raw, counts = shard_totals[:, 0], shard_totals[:, 1]
    z = jnp.maximum(jnp.sum(raw), 1e-30)
    n_total = jnp.maximum(jnp.sum(counts), 1.0)
    shard_mass = (1.0 - kappa) * raw / z + kappa * counts / n_total
    shard_mass = shard_mass / jnp.sum(shard_mass)
    k_alloc, k_draws = jax.random.split(key)
    shard_ids = jax.random.categorical(
        k_alloc, jnp.log(jnp.maximum(shard_mass, 1e-38)), shape=(s,))
    return shard_ids, jax.random.split(k_draws, s)


def within_shard_probs(local_scores, raw_total, n_total, scheme="sqrt",
                       kappa=0.1):
    """Per-record conditional draw probabilities inside one shard.

    Conditional on the draw landing in this shard, a record's probability is
    proportional to its global defensive-mixed weight; the m(x) reweighting
    factor is (1/n_total)/p_global(x), computed locally from the psum'd
    normalizers — no global score materialization.
    """
    a = jnp.clip(local_scores.astype(jnp.float32), 0.0, 1.0)
    raw = jnp.sqrt(a) if scheme == "sqrt" else a
    p_global = (1.0 - kappa) * raw / jnp.maximum(raw_total, 1e-30) \
        + kappa / jnp.maximum(n_total, 1.0)
    m = (1.0 / jnp.maximum(n_total, 1.0)) / jnp.maximum(p_global, 1e-38)
    return p_global, m


def local_selection(mesh: Mesh, scores, tau):
    """Local filter mask {A(x) >= tau} — stays sharded, zero communication."""
    axes = _data_axes(mesh)
    spec = P(axes)

    @functools.partial(shard_map, mesh=mesh, in_specs=(spec, P()),
                       out_specs=spec, check_rep=False)
    def _filter(local_scores, t):
        return (local_scores >= t)

    return _filter(scores, jnp.asarray(tau, jnp.float32))


def global_selection_count(mesh: Mesh, scores, tau):
    axes = _data_axes(mesh)
    spec = P(axes)

    @functools.partial(shard_map, mesh=mesh, in_specs=(spec, P()),
                       out_specs=P(), check_rep=False)
    def _count(local_scores, t):
        return jax.lax.psum(
            jnp.sum((local_scores >= t).astype(jnp.float32)), axes)

    return _count(scores, jnp.asarray(tau, jnp.float32))
