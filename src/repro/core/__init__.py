"""SUPG core — the paper's contribution: approximate selection with guarantees.

Public API:
  SUPGQuery / run_query / run_joint_query   query semantics (Section 3)
  sampling.*                                uniform & optimal importance samplers
  thresholds.*                              Algorithms 2-5 + U-NoCI baselines
  bounds.*                                  Lemma-1 confidence bounds
  binned.*                                  sketch-based distributed estimators
"""
from repro.core import bounds, sampling, thresholds
from repro.core.oracle import BudgetedOracle, BudgetExceededError, array_oracle
from repro.core.queries import (JointResult, JointSUPGQuery, QueryResult,
                                SUPGQuery, precision_of, recall_of,
                                run_joint_query, run_query)

__all__ = [
    "bounds", "sampling", "thresholds",
    "BudgetedOracle", "BudgetExceededError", "array_oracle",
    "SUPGQuery", "QueryResult", "JointResult", "JointSUPGQuery",
    "run_query", "run_joint_query", "precision_of", "recall_of",
]
