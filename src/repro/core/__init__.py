"""SUPG core — the paper's contribution: approximate selection with guarantees.

Public API:
  SUPGQuery / run_query / run_joint_query   query semantics (Section 3)
  OracleClient / BatchingOracle             batched labeling channel +
  BudgetLedger / as_oracle_client           per-query budget views (§4.1)
  resilience.*                              retry / timeout / breaker layer
  sampling.*                                uniform & optimal importance samplers
  thresholds.*                              Algorithms 2-5 + U-NoCI baselines
  bounds.*                                  Lemma-1 confidence bounds
  binned.*                                  sketch-based distributed estimators

The engine plane (SelectionEngine, QuerySession) lives in
`repro.core.engine` — imported explicitly so `import repro.core` stays
light (no kernel modules pulled in).
"""
from repro.core import bounds, sampling, thresholds
from repro.core.oracle import (BatchingOracle, BudgetedOracle,
                               BudgetExceededError, BudgetLedger,
                               DrainHandle, OracleClient, OracleRequest,
                               Ticket, array_oracle, as_oracle_client)
from repro.core.queries import (JointResult, JointSUPGQuery, QueryResult,
                                SUPGQuery, precision_of, recall_of,
                                run_joint_query, run_query)
from repro.core.resilience import (CircuitBreaker, CircuitOpenError,
                                   OracleError, OracleFatalError,
                                   OracleMalformedError, OracleTimeoutError,
                                   OracleTransientError, RetryPolicy,
                                   is_retryable)

__all__ = [
    "bounds", "sampling", "thresholds",
    "BudgetedOracle", "BudgetExceededError", "array_oracle",
    "BatchingOracle", "BudgetLedger", "DrainHandle", "OracleClient",
    "OracleRequest", "Ticket", "as_oracle_client",
    "CircuitBreaker", "CircuitOpenError", "OracleError", "OracleFatalError",
    "OracleMalformedError", "OracleTimeoutError", "OracleTransientError",
    "RetryPolicy", "is_retryable",
    "SUPGQuery", "QueryResult", "JointResult", "JointSUPGQuery",
    "run_query", "run_joint_query", "precision_of", "recall_of",
]
