"""Oracle-sample selection: uniform and optimal importance sampling.

Implements the sampling half of the SUPG algorithms:

* uniform i.i.d. sampling (the NoScope / probabilistic-predicates baseline),
* importance sampling with the paper's *optimal* weights  w(x) ∝ sqrt(A(x))·u(x)
  (Theorem 1), with the suboptimal proportional weights w ∝ A(x) kept as a
  baseline for the Figure-8 comparison,
* defensive mixing  w ← 0.9·w/||w||₁ + 0.1·𝟙/|D|  (Owen & Zhou),
* the reweighting factors m(x) = u(x)/w(x) used by Eqs. (11)-(12).

All samplers draw WITH replacement (as the paper's estimators assume i.i.d.
draws from w) via Gumbel-max / categorical sampling, so they run on-device and
shard cleanly over a data axis.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DEFENSIVE_KAPPA = 0.1  # mass of the uniform mixture component (paper: 0.1)


class WeightedSample(NamedTuple):
    """Result of a sampling round.

    indices:  (s,) int32 record indices into the dataset (with replacement)
    m:        (s,) float32 reweighting factors m(x) = u(x)/w(x)
    w:        (s,) float32 the sampling probabilities of the drawn records
    """

    indices: jnp.ndarray
    m: jnp.ndarray
    w: jnp.ndarray


def uniform_probs(n):
    return jnp.full((n,), 1.0 / n, jnp.float32)


def sqrt_proxy_weights(scores, defensive=True, kappa=DEFENSIVE_KAPPA):
    """Theorem-1 optimal weights: w ∝ sqrt(A(x)) with defensive mixing."""
    w = jnp.sqrt(jnp.clip(jnp.asarray(scores, jnp.float32), 0.0, 1.0))
    return _normalize_and_mix(w, defensive, kappa)


def proportional_proxy_weights(scores, defensive=True, kappa=DEFENSIVE_KAPPA):
    """Baseline weights w ∝ A(x) — provably no better than uniform (Sec 10.2)."""
    w = jnp.clip(jnp.asarray(scores, jnp.float32), 0.0, 1.0)
    return _normalize_and_mix(w, defensive, kappa)


def _normalize_and_mix(w, defensive, kappa):
    n = w.shape[0]
    tot = jnp.sum(w)
    # Degenerate all-zero proxy: fall back to uniform.
    w = jnp.where(tot > 0, w / jnp.maximum(tot, 1e-30), 1.0 / n)
    if defensive:
        w = (1.0 - kappa) * w + kappa / n
    return w


def sample_uniform(key, n, s):
    """Uniform with-replacement sample of s records out of n."""
    idx = jax.random.randint(key, (s,), 0, n)
    m = jnp.ones((s,), jnp.float32)  # u/w = 1 for uniform
    return WeightedSample(idx, m, jnp.full((s,), 1.0 / n, jnp.float32))


def _inverse_cdf_draw(key, probs, s):
    """s with-replacement categorical draws in O(n + s log n) memory.

    jax.random.categorical materializes an (s, n) Gumbel field — fatal at
    n ~ 1e6+. Inverse-CDF transform sampling (cumsum + searchsorted) is the
    standard streaming-scale substitute and is exactly equivalent in
    distribution (up to fp32 cdf rounding; the cdf is renormalized by its
    final value so total mass is exactly 1).
    """
    cdf = jnp.cumsum(probs)
    cdf = cdf / cdf[-1]
    u = jax.random.uniform(key, (s,), jnp.float32)
    idx = jnp.searchsorted(cdf, u, side="left")
    return jnp.clip(idx, 0, probs.shape[0] - 1).astype(jnp.int32)


def sample_weighted(key, probs, s):
    """With-replacement sample from an explicit probability vector."""
    probs = jnp.asarray(probs, jnp.float32)
    n = probs.shape[0]
    idx = _inverse_cdf_draw(key, probs, s)
    w_drawn = probs[idx]
    m = (1.0 / n) / jnp.maximum(w_drawn, 1e-38)
    return WeightedSample(idx, m, w_drawn)


def sample_weighted_masked(key, probs, mask, s):
    """Weighted sampling restricted to records where mask=1 (stage 2 of PT).

    Probabilities are renormalized over the masked subset; m(x) is computed
    w.r.t. the *uniform distribution on the masked subset*, matching the
    paper's stage-2 estimator which treats D' as the population.
    """
    probs = jnp.asarray(probs, jnp.float32) * jnp.asarray(mask, jnp.float32)
    tot = jnp.sum(probs)
    n_sub = jnp.maximum(jnp.sum(mask), 1.0)
    probs = jnp.where(tot > 0, probs / jnp.maximum(tot, 1e-30),
                      jnp.asarray(mask, jnp.float32) / n_sub)
    idx = _inverse_cdf_draw(key, probs, s)
    w_drawn = probs[idx]
    m = (1.0 / n_sub) / jnp.maximum(w_drawn, 1e-38)
    return WeightedSample(idx, m, w_drawn)


# ---------------------------------------------------------------------------
# Host-side CDF primitives for the engine's cached sampling state
# ---------------------------------------------------------------------------
# The SelectionEngine's cached state is *hierarchical*: per (shard, scheme)
# it persists only the per-chunk raw masses accumulated during the sketch
# pass — O(n / chunk_records) floats — and resolves record-level draws at
# query time by streaming just the allocated chunks (categorical over chunk
# masses, then an exact inverse-CDF draw over freshly computed within-chunk
# weights). Because a chunk's defensive-mixture mass is exactly the sum of
# its records' p(x), chunk mass × within-chunk p reproduces the global p(x),
# so m(x) = (1/n)/p(x) stays exact with no O(n) state. float64 keeps the
# prefix sums faithful at 1e8+ records.

def normalized_cdf(weights) -> np.ndarray:
    """Inclusive float64 prefix CDF, renormalized to end exactly at 1."""
    w = np.asarray(weights, np.float64)
    cdf = np.cumsum(w)
    total = cdf[-1] if cdf.size else 0.0
    if not total > 0:
        raise ValueError("normalized_cdf needs positive total mass")
    return cdf / total


def draw_from_cdf(cdf: np.ndarray, u) -> np.ndarray:
    """Vectorized inverse-CDF draws: indices such that cdf[i-1] <= u < cdf[i]."""
    idx = np.searchsorted(cdf, np.asarray(u, np.float64), side="left")
    return np.minimum(idx, cdf.shape[0] - 1).astype(np.int64)


class ChunkMasses(NamedTuple):
    """Per-chunk raw sampling masses for one shard (the persistent half of
    the hierarchical sampler — O(n_chunks), never O(n_records)).

    Accumulated during the chunked sketch pass at engine construction: the
    chunk is already in cache there, so the two extra float64 reductions are
    effectively free. `sizes` counts *all* records in the chunk (unscored
    sentinels included) because the defensive uniform component kappa/n
    gives every record mass, exactly like the dense p(x) formula.
    """

    sum_sqrt: np.ndarray   # (n_chunks,) float64 Σ sqrt(clip(A)) per chunk
    sum_a: np.ndarray      # (n_chunks,) float64 Σ clip(A) per chunk
    sizes: np.ndarray      # (n_chunks,) int64 record count per chunk

    def raw(self, scheme: str) -> np.ndarray:
        return self.sum_sqrt if scheme == "sqrt" else self.sum_a

    @classmethod
    def empty(cls) -> "ChunkMasses":
        return cls(np.empty(0, np.float64), np.empty(0, np.float64),
                   np.empty(0, np.int64))


def chunk_raw_masses(scores_chunk) -> Tuple[float, float]:
    """Float64 Σ sqrt(A) and Σ A over one chunk (sentinels contribute 0)."""
    a = np.clip(np.asarray(scores_chunk, np.float32), 0.0, 1.0)
    return (float(np.sum(np.sqrt(a), dtype=np.float64)),
            float(np.sum(a, dtype=np.float64)))


def defensive_chunk_mass(raw: np.ndarray, sizes: np.ndarray, z: float,
                         kappa: float, n_total: int) -> np.ndarray:
    """Total defensive-mixture draw probability of each chunk.

    Summing p(x) = (1-kappa)·raw(x)/Z + kappa/n over a chunk gives
    (1-kappa)·Σraw/Z + kappa·|chunk|/n — computable from the cached chunk
    masses alone, so the chunk-level categorical needs no record access.
    """
    z = max(float(z), 1e-30)
    return ((1.0 - kappa) * np.asarray(raw, np.float64) / z
            + kappa * np.asarray(sizes, np.float64) / n_total)


def append_cdf(cum: np.ndarray, new_masses) -> np.ndarray:
    """Extend an *unnormalized* float64 chunk-mass prefix sum in place of a
    full rebuild — the live plane's CDF-append path.

    `np.cumsum` is a sequential left fold (``c[i] = c[i-1] + m[i]``), so
    continuing the fold from the existing tail reproduces, bit for bit, the
    prefix sum a cold pass over the concatenated mass vector would compute.
    That identity is what lets incremental ingestion extend per-shard
    chunk-mass CDFs without re-reading any old chunk while staying
    bitwise-equal to a cold engine rebuild (`tests/test_live.py` property-
    tests the split-vs-full equality).

    >>> full = np.cumsum(np.asarray([0.3, 0.2, 0.5, 0.1], np.float64))
    >>> grown = append_cdf(np.cumsum(np.asarray([0.3, 0.2], np.float64)),
    ...                    [0.5, 0.1])
    >>> bool(np.array_equal(full, grown))
    True
    """
    new = np.asarray(new_masses, np.float64)
    cum = np.asarray(cum, np.float64)
    if cum.size == 0:
        return np.cumsum(new)
    if new.size == 0:
        return cum.copy()
    # Seed the cumsum with the existing tail so the fold *continues* —
    # ``cum[-1] + np.cumsum(new)`` would regroup the additions and drift.
    return np.concatenate(
        [cum, np.cumsum(np.concatenate([cum[-1:], new]))[1:]])


def chunk_mass_cdf(raw: np.ndarray, sizes: np.ndarray, z: float,
                   kappa: float, n_total: int) -> Tuple[float, np.ndarray]:
    """One shard's (total mass, normalized chunk-mass CDF) for the
    hierarchical draw — the single construction path shared by cold engine
    builds and the ingest plane's epoch extensions, so both produce
    bit-identical sampling state from identical chunk masses."""
    m_c = defensive_chunk_mass(raw, sizes, z, kappa, n_total)
    total = float(m_c.sum())
    if not total > 0:
        raise ValueError(
            "shard has no sampling mass (kappa=0 with an all-zero proxy?)")
    return total, append_cdf(np.empty(0, np.float64), m_c) / total


def defensive_probs(scores_chunk, scheme: str, z: float, kappa: float,
                    n_total: int) -> np.ndarray:
    """Global draw probabilities p(x) for the records of one chunk.

    Bit-identical to the formula the dense per-record path used (float32
    p values), so the hierarchical draw's m(x) factors match the dense
    sampler's exactly at matched records.
    """
    z = max(float(z), 1e-30)
    a = np.clip(np.asarray(scores_chunk, np.float32), 0.0, 1.0)
    raw = np.sqrt(a) if scheme == "sqrt" else a
    return ((1.0 - kappa) * raw / z + kappa / n_total).astype(np.float32)


@functools.partial(jax.jit, static_argnames=("s", "scheme", "defensive"))
def draw_oracle_sample(key, scores, s, scheme="sqrt", defensive=True):
    """One-stop sampler used by the query layer.

    scheme: 'uniform' | 'sqrt' (Theorem 1 optimal) | 'prop' (baseline).
    """
    n = scores.shape[0]
    if scheme == "uniform":
        return sample_uniform(key, n, s)
    if scheme == "sqrt":
        probs = sqrt_proxy_weights(scores, defensive=defensive)
    elif scheme == "prop":
        probs = proportional_proxy_weights(scores, defensive=defensive)
    else:
        raise ValueError(f"unknown sampling scheme: {scheme}")
    return sample_weighted(key, probs, s)
