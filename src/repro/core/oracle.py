"""Oracle / proxy UDF interfaces and budget accounting.

The paper's operational model (Section 4.1): the user supplies
  * a proxy model A(x) in [0,1] — cheap, executed over the complete dataset
    (in this framework: a distributed `serve` pass of one of the configured
    architectures, see launch/serve.py), and
  * an oracle predicate O(x) in {0,1} — expensive (human, or an oracle-grade
    model), rate-limited by the query's ORACLE LIMIT.

`BudgetedOracle` wraps the user's callback with hard budget enforcement and
deduplicated-call accounting (repeat draws of the same record — possible
under with-replacement sampling — are answered from a cache and do NOT
consume budget, matching how a batch labeling system would behave).
"""
from __future__ import annotations

from typing import Callable

import numpy as np


class BudgetExceededError(RuntimeError):
    """Raised when a query attempts to exceed its ORACLE LIMIT."""


class BudgetedOracle:
    def __init__(self, fn: Callable[[np.ndarray], np.ndarray], budget: int):
        self._fn = fn
        self.budget = int(budget)
        self.calls_used = 0
        self._cache: dict[int, float] = {}

    @property
    def remaining(self) -> int:
        return self.budget - self.calls_used

    def __call__(self, indices) -> np.ndarray:
        """Label a batch of record indices; returns float32 {0,1} labels."""
        idx = np.asarray(indices).reshape(-1)
        out = np.empty(idx.shape[0], np.float32)
        missing_pos, missing_idx = [], []
        for pos, i in enumerate(idx):
            key = int(i)
            if key in self._cache:
                out[pos] = self._cache[key]
            else:
                missing_pos.append(pos)
                missing_idx.append(key)
        # Deduplicate new indices (with-replacement draws repeat records).
        uniq = sorted(set(missing_idx))
        if uniq:
            if self.calls_used + len(uniq) > self.budget:
                raise BudgetExceededError(
                    f"oracle budget {self.budget} exceeded: "
                    f"{self.calls_used} used, {len(uniq)} requested")
            labels = np.asarray(self._fn(np.asarray(uniq, np.int64)),
                                np.float32).reshape(-1)
            if labels.shape[0] != len(uniq):
                raise ValueError("oracle returned wrong number of labels")
            self.calls_used += len(uniq)
            lookup = dict(zip(uniq, labels))
            self._cache.update(lookup)
            for pos, key in zip(missing_pos, missing_idx):
                out[pos] = self._cache[key]
        return out

    def labeled_positives(self) -> np.ndarray:
        """Indices labeled positive so far — the R1 component of Algorithm 1."""
        return np.asarray(
            [i for i, v in self._cache.items() if v > 0.5], np.int64)


def array_oracle(labels) -> Callable[[np.ndarray], np.ndarray]:
    """Oracle backed by a ground-truth label array (tests / benchmarks)."""
    arr = np.asarray(labels, np.float32)

    def fn(indices):
        return arr[np.asarray(indices, np.int64)]

    return fn
