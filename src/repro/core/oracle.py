"""Oracle / proxy UDF interfaces, batched labeling, and budget accounting.

The paper's operational model (Section 4.1): the user supplies
  * a proxy model A(x) in [0,1] — cheap, executed over the complete dataset
    (in this framework: a distributed `serve` pass of one of the configured
    architectures, see launch/serve.py), and
  * an oracle predicate O(x) in {0,1} — expensive (human, or an oracle-grade
    model), rate-limited by the query's ORACLE LIMIT.

Because the oracle is the rate-limited resource, a serving system's
throughput is set by how well it *coalesces* oracle calls. This module
therefore splits the old monolithic `BudgetedOracle` into three parts:

`OracleClient` protocol — the batched labeling channel
    ``submit(indices, ledger=...) -> Ticket`` enqueues a labeling request;
    ``drain()`` is the explicit barrier that resolves everything pending.
    Plans and sessions speak only this protocol, so the expensive callable
    is invoked at the *channel's* cadence, not the caller's. Clients may
    additionally expose ``drain_async() -> DrainHandle`` — the overlapped
    drain surface: the pending set is snapshotted at call time and resolved
    on a dedicated drain thread so callers keep computing while oracle I/O
    is in flight. ``drain()`` stays the synchronous wrapper with identical
    semantics, so every existing caller works unchanged.

`BatchingOracle` — the one real implementation
    Coalesces pending requests from any number of concurrent queries into
    micro-batches of at most ``max_batch`` unique records per underlying
    ``fn`` call, backed by one process-wide label cache per client
    instance. `SelectionEngine.session()` funnels every in-flight query of
    a `QuerySession` through a single `BatchingOracle`, which is what makes
    cross-query batching (and cross-query cache reuse) happen.

`BudgetLedger` — per-query budget *views* over the shared channel
    Budget semantics under a shared cache:

      * a record is *charged* the moment the channel has to invoke ``fn``
        for it, and it is charged to exactly one ledger — the earliest
        submitted ticket (in `submit` order) that requested it;
      * a record whose label is already cached (labeled earlier for any
        query of the same client/session) is **free**: query B never pays
        for what query A already bought. `ledger.charged` is therefore an
        *attribution*, not a per-query isolation guarantee — at different
        session concurrency levels the same query can be charged
        differently, while its labels (and hence its selection) are
        identical for any pure ``fn``;
      * enforcement is still strictly per query: inside one coalesced
        drain, a ticket whose charge would push its ledger past its
        ORACLE LIMIT fails with `BudgetExceededError` *alone* — co-batched
        tickets still resolve, and indices requested only by the failing
        ticket are neither sent to ``fn`` nor cached (no label leaks from
        an over-budget query);
      * `ledger.labeled_positives()` sees only records the *owning query*
        requested (Algorithm 1's R1 must not absorb other queries'
        samples), returned sorted so results never depend on how batches
        interleaved across queries.

`as_oracle_client` adapts a plain ``indices -> labels`` callable into a
private `BatchingOracle`, so every legacy entry point (`run`, `run_joint`,
`run_many`, `queries.run_query`) keeps accepting bare callables unchanged.
`BudgetedOracle` survives as the back-compat callable facade — one private
client plus one ledger — with the original semantics: repeat draws of the
same record (possible under with-replacement sampling) are answered from
the cache and do NOT consume budget, matching how a batch labeling system
behaves.

Fault tolerance (`core.resilience`): the channel treats transport
failures exactly like budget failures — *per ticket*, never per drain.
Each micro-batch is validated (length + finiteness; a torn or NaN
response is rejected before caching and raised as
`OracleMalformedError`), optionally watchdogged (`call_timeout_s` →
`OracleTimeoutError`), retried per an injectable `RetryPolicy`
(transient errors only), and gated by a `CircuitBreaker`. Only when a
micro-batch exhausts its retries (or fails fatally) do the tickets
whose records sat in that micro-batch fail — with the typed transport
error — while co-batched tickets whose records labeled cleanly still
resolve. Ledgers are charged per *completed* micro-batch only, and the
shared label cache never holds unpaid or malformed labels.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import threading
import time
from typing import Callable, List, Optional, Protocol, Tuple, \
    runtime_checkable

import numpy as np

from repro.core.resilience import (CircuitBreaker, CircuitOpenError,
                                   OracleMalformedError, OracleTimeoutError,
                                   RetryPolicy, call_with_timeout,
                                   is_retryable)


class BudgetExceededError(RuntimeError):
    """Raised when a query attempts to exceed its ORACLE LIMIT."""


# ---------------------------------------------------------------------------
# Vectorized label cache
# ---------------------------------------------------------------------------

class _LabelCache:
    """Sorted-array label cache with vectorized membership.

    Replaces the per-element Python dict probe loop: lookups are one
    `searchsorted` over the batch (a 1e6-index probe is a single numpy
    pass), inserts are one merge. Keys are unique int64 record ids.
    """

    def __init__(self):
        self._keys = np.empty(0, np.int64)
        self._vals = np.empty(0, np.float32)

    def __len__(self) -> int:
        return int(self._keys.size)

    def lookup(self, idx: np.ndarray):
        """Vectorized probe: returns (labels, known_mask) aligned to idx.

        Unknown positions carry 0.0 in `labels`; callers must consult
        `known_mask` before trusting them.
        """
        idx = np.asarray(idx, np.int64)
        if self._keys.size == 0:
            return (np.zeros(idx.shape[0], np.float32),
                    np.zeros(idx.shape[0], bool))
        pos = np.searchsorted(self._keys, idx)
        pos = np.minimum(pos, self._keys.size - 1)
        known = self._keys[pos] == idx
        out = np.where(known, self._vals[pos], 0.0).astype(np.float32)
        return out, known

    def missing(self, idx: np.ndarray) -> np.ndarray:
        """Sorted unique indices from `idx` not present in the cache."""
        uniq = np.unique(np.asarray(idx, np.int64))
        if self._keys.size == 0:
            return uniq
        _, known = self.lookup(uniq)
        return uniq[~known]

    def insert(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Merge new keys into the sorted store.

        `keys` must be sorted, unique, and disjoint from the store (every
        caller passes `np.unique`/`missing()` output). Both sides being
        sorted, this is a linear two-way merge — O(N + k), not the
        O(N log N) re-sort that would make a long-lived session's drains
        quadratic in cumulative cache size.
        """
        keys = np.asarray(keys, np.int64)
        if keys.size == 0:
            return
        vals = np.asarray(vals, np.float32)
        if self._keys.size == 0:
            self._keys, self._vals = keys.copy(), vals.copy()
            return
        ins = np.searchsorted(self._keys, keys) + np.arange(keys.size)
        out_k = np.empty(self._keys.size + keys.size, np.int64)
        out_v = np.empty(out_k.size, np.float32)
        out_k[ins], out_v[ins] = keys, vals
        old = np.ones(out_k.size, bool)
        old[ins] = False
        out_k[old], out_v[old] = self._keys, self._vals
        self._keys, self._vals = out_k, out_v

    def positives(self) -> np.ndarray:
        """Sorted indices with a positive cached label."""
        return self._keys[self._vals > 0.5].copy()


# ---------------------------------------------------------------------------
# Budget ledgers — per-query views over a shared channel
# ---------------------------------------------------------------------------

class BudgetLedger:
    """One query's budget view of a (possibly shared) labeling channel.

    Tracks how many `fn` labels were *attributed* to this query
    (`charged`, capped at `budget` — see the module docstring for the
    attribution rule under a shared cache) and which records this query
    requested, so `labeled_positives()` reflects exactly this query's
    sample — never co-batched queries' labels.

    Ledgers chain: `parent` names a coarser shared ledger (the serving
    plane's per-tenant quota) that every charge flows through as well.
    Enforcement covers the whole chain — a charge that fits the query's
    own budget but would blow the tenant quota fails exactly like a
    per-query overrun (`BudgetExceededError`, the failing ticket alone),
    so a tenant exhausting its quota mid-drain cannot starve co-batched
    queries of other tenants. `label` names the ledger in error messages
    ("tenant 'abc' quota") so clients can tell a quota rejection from a
    per-query ORACLE LIMIT.

    >>> tenant = BudgetLedger(5, label="tenant 'abc' quota")
    >>> q1, q2 = BudgetLedger(4, parent=tenant), BudgetLedger(4, parent=tenant)
    >>> q1.charge(3); (q1.remaining, q2.remaining)   # parent caps q2 at 2
    (1, 2)
    >>> try:
    ...     q2.charge(3)
    ... except BudgetExceededError as e:
    ...     print(e)
    oracle budget 5 exceeded (tenant 'abc' quota): 3 used, 3 requested
    """

    def __init__(self, budget: int, *,
                 parent: Optional["BudgetLedger"] = None,
                 label: Optional[str] = None):
        self.budget = int(budget)
        self.charged = 0
        self.parent = parent
        self.label = label
        self._seen = _LabelCache()   # records this query requested

    def chain(self) -> List["BudgetLedger"]:
        """This ledger followed by its ancestors (query -> tenant -> ...)."""
        out, node = [], self
        while node is not None:
            out.append(node)
            node = node.parent
        return out

    @property
    def remaining(self) -> int:
        """Headroom left on the tightest ledger of the chain."""
        return min(l.budget - l.charged for l in self.chain())

    def charge(self, k: int) -> None:
        """Commit `k` attributed labels to every ledger of the chain.

        Checked before committed, so a chain whose parent rejects leaves
        the child uncharged (the drain's pre-check makes rejection here
        unreachable on the batched path, but direct callers keep atomic
        semantics)."""
        for led in self.chain():
            if led.charged + k > led.budget:
                raise led.exceeded(led.charged, int(k))
        for led in self.chain():
            led.charged += int(k)

    def exceeded(self, used: int, requested: int) -> "BudgetExceededError":
        """Build this ledger's budget-overrun error (labelled for quotas)."""
        tag = f" ({self.label})" if self.label else ""
        return BudgetExceededError(
            f"oracle budget {self.budget} exceeded{tag}: "
            f"{used} used, {requested} requested")

    def record(self, idx: np.ndarray, labels: np.ndarray) -> None:
        """Attach resolved labels for records this query requested."""
        idx = np.asarray(idx, np.int64)
        uniq, first = np.unique(idx, return_index=True)
        _, known = self._seen.lookup(uniq)
        if not known.all():
            self._seen.insert(uniq[~known],
                              np.asarray(labels, np.float32)[first[~known]])

    def labeled_positives(self) -> np.ndarray:
        """Sorted positive-labeled records among this query's requests —
        the R1 component of Algorithm 1. Sorted by construction so the
        result is independent of batch interleaving across queries."""
        return self._seen.positives()


@dataclasses.dataclass
class OracleRequest:
    """What a query plan yields when it needs labels: a batch of record
    indices plus the ledger the resulting charges belong to. `ledger=None`
    requests uncapped, unattributed labeling (used nowhere by the built-in
    plans; JT verification carries an explicit n_total-capped ledger)."""
    indices: np.ndarray
    ledger: Optional[BudgetLedger] = None


# ---------------------------------------------------------------------------
# The batched labeling channel
# ---------------------------------------------------------------------------

class Ticket:
    """Handle for one submitted labeling request. `result()` blocks the
    logical exchange: it drains the owning channel if the ticket is still
    pending, then returns labels aligned to the submitted indices (or
    raises this ticket's error — e.g. `BudgetExceededError` when the
    coalesced drain rejected this query's charge)."""

    __slots__ = ("indices", "ledger", "_owner", "_labels", "_error", "_done")

    def __init__(self, owner: "BatchingOracle", indices: np.ndarray,
                 ledger: Optional[BudgetLedger]):
        self._owner = owner
        self.indices = indices
        self.ledger = ledger
        self._labels: Optional[np.ndarray] = None
        self._error: Optional[BaseException] = None
        self._done = False

    @property
    def done(self) -> bool:
        """True once a drain resolved (or poisoned) this ticket."""
        return self._done

    def result(self) -> np.ndarray:
        """Labels aligned to the submitted indices (drains if pending)."""
        if not self._done:
            self._owner.drain()
        if self._error is not None:
            raise self._error
        if not self._done:
            # Never hand back labels for a ticket a drain dropped.
            raise RuntimeError("ticket unresolved after drain")
        return self._labels


class DrainHandle:
    """Completion handle for one asynchronous drain.

    Settles exactly once, with either success or the drain's error; the
    error also poisons every ticket the drain had popped (the same
    semantics a synchronous `drain()` has), so awaiting the handle and
    then reading tickets observes one consistent outcome. Callers must
    `wait()`/`exception()`/`result()` the handle *before* calling
    `result()` on any ticket the drain owns — a ticket poked mid-flight
    would trigger a useless synchronous drain of an empty pending set.
    `duration_s` is the wall time the resolve spent in flight (0.0 for
    the empty-drain fast path) — the overlap metric sessions report.
    `retries` / `timeouts` / `batch_failures` / `batch_sheds` are this
    drain's slice of the channel's resilience counters (snapshotted
    under the channel lock, so concurrent drains never double-count) —
    `SessionStats` aggregates them per session.
    """

    __slots__ = ("_event", "_error", "tickets", "duration_s",
                 "retries", "timeouts", "batch_failures", "batch_sheds")

    def __init__(self, tickets: int = 0):
        self._event = threading.Event()
        self._error: Optional[BaseException] = None
        self.tickets = int(tickets)
        self.duration_s = 0.0
        self.retries = 0
        self.timeouts = 0
        self.batch_failures = 0
        self.batch_sheds = 0

    def _finish(self, error: Optional[BaseException],
                duration_s: float = 0.0) -> None:
        self._error = error
        self.duration_s = float(duration_s)
        self._event.set()

    @property
    def done(self) -> bool:
        """True once the drain has settled (success or failure)."""
        return self._event.is_set()

    def wait(self) -> None:
        """Block until the drain settles (success or failure)."""
        self._event.wait()

    def exception(self) -> Optional[BaseException]:
        """Block until settled; return the drain's error, or None."""
        self._event.wait()
        return self._error

    def result(self) -> None:
        """Block until settled; raise the drain's error if it failed."""
        err = self.exception()
        if err is not None:
            raise err


@runtime_checkable
class OracleClient(Protocol):
    """The batched labeling channel protocol query plans are driven over.

    `submit`/`drain` are the required surface. Implementations may also
    provide ``drain_async() -> DrainHandle`` (see `BatchingOracle`);
    schedulers probe for it with `getattr` and fall back to the
    synchronous `drain`, so third-party clients stay protocol-complete
    without it."""

    def submit(self, indices,
               ledger: Optional[BudgetLedger] = None) -> Ticket:
        """Enqueue a labeling request; resolved at the next drain."""
        ...

    def drain(self) -> None:
        """Barrier: resolve every pending ticket."""
        ...


class BatchingOracle:
    """`OracleClient` that coalesces concurrent queries' requests into
    micro-batches over one shared label cache.

    `submit` only enqueues (auto-draining once the pending *new-to-cache*
    record count reaches `max_batch`); `drain` is the explicit barrier:
    it walks pending tickets in submission order, attributes each
    new-to-cache record to the earliest ticket requesting it, enforces
    each ledger's budget over its attributed records (a failing ticket
    errors alone; its exclusive records are dropped from the batch and
    never cached), then invokes ``fn`` on the surviving unique records in
    sorted micro-batches of at most `max_batch`.

    `fn_calls` / `records_labeled` / `cache_hits` count underlying oracle
    invocations, labeled records, and requested records answered without
    a new labeling (from the cache, or coalesced into an earlier
    co-batched ticket's claim) — the serving-side metrics a session
    exists to minimize. Thread-safe: `submit` and `drain` serialize on
    one lock (drain runs ``fn`` while holding it, so concurrent
    submitters observe either the pre- or post-drain cache, never a
    partial one).

    `pacer`, when given, is the serving plane's rate-limiter hook: it is
    called with the micro-batch size right before each ``fn`` invocation
    (see `repro.serve.TokenBucket`), so oracle pacing composes with
    `drain_async` — a paced drain blocks on the drain thread while plan
    compute keeps running. A pacer that *raises* is classified through
    the same taxonomy as ``fn`` failures: a transient throttle error is
    retried per policy, while `serve.RateLimitError` (a request that can
    never fit the bucket) fails the micro-batch's tickets alone — it
    never kills the drain, the drain thread, or co-batched tickets.

    Fault tolerance (`retry` / `call_timeout_s` / `breaker` — see
    `core.resilience`): each micro-batch invocation is validated (a
    wrong-length or non-finite response raises `OracleMalformedError`
    *before* anything is cached), optionally watchdogged
    (`call_timeout_s` seconds per call, overruns raise
    `OracleTimeoutError` and the late result is discarded), and retried
    per `retry` while the error classifies transient — with
    deterministic backoff on the draining thread. Only when a
    micro-batch exhausts its attempts (or fails fatally, or the
    `breaker` is open) do the tickets whose records were in that
    micro-batch fail, carrying the typed error; tickets whose records
    all labeled cleanly still resolve in the same drain, and ledgers
    are only ever charged for completed micro-batches. The breaker
    records one failure per exhausted micro-batch and trips open after
    its threshold; while open, micro-batches fail fast with
    `CircuitOpenError` until the cooldown grants a half-open probe —
    the probe's grant covers every retry attempt of its micro-batch,
    and the chunk's final outcome (success / exhaustion) settles it.
    `retries` / `timeouts` / `batch_failures` / `batch_sheds` count fn
    re-invocations, watchdog overruns, micro-batches that exhausted
    their retries (or failed fatally), and micro-batches shed by the
    open circuit (sheds are load the breaker refused, not channel
    failures, so the two counters never mix).

    When `call_timeout_s` is set, a timed-out invocation's thread is
    abandoned, not killed — so the retry that follows may run while the
    abandoned call is still executing. ``fn`` must therefore tolerate
    concurrent invocation when watchdogged (pure array lookups and
    `testing.FaultInjector` qualify; an oracle with shared mutable
    state needs its own lock).

    >>> import numpy as np
    >>> calls = []
    >>> def fn(idx):
    ...     calls.append(len(idx))
    ...     return (np.asarray(idx) % 2).astype(np.float32)
    >>> client = BatchingOracle(fn)
    >>> a = client.submit([3, 4, 5], ledger=BudgetLedger(8))
    >>> b = client.submit([4, 5, 6], ledger=BudgetLedger(8))
    >>> client.drain()                  # one coalesced fn micro-batch
    >>> calls, client.fn_calls, client.cache_hits
    ([4], 1, 2)
    >>> [int(v) for v in b.result()]    # labels aligned to b's indices
    [0, 1, 0]

    `drain_async` is the overlapped-drain surface: it pops the pending
    tickets *at call time* (so later submits deterministically belong to
    the next drain) and resolves them on a lazily created, dedicated drain
    thread, returning a `DrainHandle`. Exception-poisoning semantics are
    identical to the synchronous path — a failed resolve marks every
    popped ticket with the error before the handle settles. The drain
    thread only exists once `drain_async` has been used; `close()` reaps
    it (pure-`drain()` clients never pay for one).
    """

    def __init__(self, fn: Callable[[np.ndarray], np.ndarray],
                 max_batch: Optional[int] = None,
                 pacer: Optional[Callable[[int], object]] = None,
                 retry: Optional[RetryPolicy] = None,
                 call_timeout_s: Optional[float] = None,
                 breaker: Optional[CircuitBreaker] = None):
        if max_batch is not None and max_batch <= 0:
            raise ValueError("max_batch must be positive")
        if call_timeout_s is not None and call_timeout_s <= 0:
            raise ValueError("call_timeout_s must be positive")
        self._fn = fn
        self.max_batch = max_batch
        # The rate-limiter hook on the drain path: called with the
        # micro-batch size immediately before each underlying `fn`
        # invocation (a `serve.TokenBucket` blocks here until the batch
        # is inside the configured rate). Because resolution runs on the
        # drain thread under `drain_async`, pacing throttles the channel
        # while plan compute keeps overlapping it.
        self._pacer = pacer
        self.retry = retry
        self.call_timeout_s = call_timeout_s
        self.breaker = breaker
        self._cache = _LabelCache()
        self._pending: List[Ticket] = []
        self._pending_new = 0
        self._lock = threading.RLock()
        self._drain_worker: Optional[
            concurrent.futures.ThreadPoolExecutor] = None
        self.fn_calls = 0
        self.records_labeled = 0
        self.cache_hits = 0
        self.retries = 0          # fn re-invocations after transient errors
        self.timeouts = 0         # watchdogged calls that overran the deadline
        self.batch_failures = 0   # micro-batches that exhausted retries/fatal
        self.batch_sheds = 0      # micro-batches shed by the open circuit

    @property
    def cache_size(self) -> int:
        """Number of distinct records with a cached label."""
        return len(self._cache)

    def submit(self, indices,
               ledger: Optional[BudgetLedger] = None) -> Ticket:
        """Enqueue a labeling request; resolved at the next drain (or
        immediately, if the pending new-record count trips `max_batch`)."""
        idx = np.asarray(indices, np.int64).reshape(-1)
        with self._lock:
            t = Ticket(self, idx, ledger)
            self._pending.append(t)
            # The new-to-cache probe exists only to arm the auto-drain
            # threshold; without a max_batch cap it would be pure waste
            # (drain recomputes the missing sets anyway).
            if self.max_batch is not None:
                self._pending_new += int(self._cache.missing(idx).size)
                if self._pending_new >= self.max_batch:
                    self._drain_locked()
            return t

    def drain(self) -> None:
        """Barrier: resolve every pending ticket on the calling thread."""
        with self._lock:
            self._drain_locked()

    def _drain_locked(self) -> None:
        tickets, self._pending = self._pending, []
        self._pending_new = 0
        self._resolve_guarded(tickets)

    def _resolve_guarded(self, tickets: List[Ticket]) -> None:
        if not tickets:
            return
        try:
            self._resolve(tickets)
        except BaseException as err:
            # Poisoned drain: every popped ticket must leave resolved —
            # the ones the failure skipped carry the failure itself, so a
            # later result() raises instead of returning stale labels
            # (already-cached earlier micro-batches stay; they were
            # labeled correctly).
            for t in tickets:
                if not t._done:
                    t._error, t._done = err, True
            raise

    def drain_async(self) -> DrainHandle:
        """Start resolving everything pending on the drain thread.

        The pending set is snapshotted under the lock *now*: tickets
        submitted after this call belong to the next drain, so overlap
        never changes which drain owns a request. With nothing pending
        the returned handle is already settled and no thread is touched.
        Await the handle before calling `result()` on any popped ticket.
        """
        with self._lock:
            tickets, self._pending = self._pending, []
            self._pending_new = 0
            handle = DrainHandle(len(tickets))
            if not tickets:
                handle._finish(None)
                return handle
            if self._drain_worker is None:
                self._drain_worker = concurrent.futures.ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="repro-drain")

            def resolve_snapshot():
                t0 = time.perf_counter()
                err: Optional[BaseException] = None
                try:
                    with self._lock:
                        # Counter deltas are exact per drain: the whole
                        # resolve runs under the channel lock, so no
                        # concurrent drain can interleave its counts.
                        before = (self.retries, self.timeouts,
                                  self.batch_failures, self.batch_sheds)
                        try:
                            self._resolve_guarded(tickets)
                        finally:
                            handle.retries = self.retries - before[0]
                            handle.timeouts = self.timeouts - before[1]
                            handle.batch_failures = (
                                self.batch_failures - before[2])
                            handle.batch_sheds = (
                                self.batch_sheds - before[3])
                except BaseException as e:  # noqa: BLE001 — handle carries
                    err = e
                handle._finish(err, time.perf_counter() - t0)

            # Enqueued under the lock: concurrent drain_async calls hit
            # the single drain thread in pop order, so snapshots resolve
            # in the order their tickets were claimed.
            self._drain_worker.submit(resolve_snapshot)
        return handle

    def close(self) -> None:
        """Reap the drain thread (if `drain_async` ever created one),
        waiting for any in-flight `drain_async` resolve to settle its
        `DrainHandle` first. Loops because a concurrent `drain_async`
        may install a fresh worker after we popped the old one — close
        must reap that one too, or its thread leaks. Safe to call
        multiple times; the client stays usable for synchronous
        submit/drain afterwards."""
        while True:
            with self._lock:
                worker, self._drain_worker = self._drain_worker, None
            if worker is None:
                return
            worker.shutdown(wait=True)

    def _resolve(self, tickets: List[Ticket]) -> None:
        # 1. attribution + enforcement, in submission order: each record
        #    not in the cache is claimed by the earliest ticket requesting
        #    it; a ticket whose claims would blow any ledger of its chain
        #    (its own ORACLE LIMIT or a shared parent quota) fails alone
        #    and its exclusive claims are released (later tickets may
        #    re-claim them).
        claimed = np.empty(0, np.int64)          # sorted union of claims
        claims: List = []                        # (ticket, its new records)
        drain_charge: dict = {}                  # ledger -> pending charge
        for t in tickets:
            uniq_requested = int(np.unique(t.indices).size)
            new = self._cache.missing(t.indices)
            if claimed.size:
                new = new[~np.isin(new, claimed)]
            if t.ledger is not None:
                chain = t.ledger.chain()
                over = next(
                    (led for led in chain
                     if (led.charged + drain_charge.get(id(led), 0)
                         + new.size > led.budget)), None)
                if over is not None:
                    used = over.charged + drain_charge.get(id(over), 0)
                    t._error = over.exceeded(used, int(new.size))
                    t._done = True
                    continue
                for led in chain:
                    drain_charge[id(led)] = (
                        drain_charge.get(id(led), 0) + int(new.size))
            self.cache_hits += uniq_requested - int(new.size)
            claims.append((t, new))
            claimed = np.union1d(claimed, new)
        # 2. label the surviving union in sorted micro-batches <= max_batch,
        #    charging each ledger the moment a micro-batch *completes*:
        #    if a micro-batch fails (retries exhausted / fatal / circuit
        #    open), the records already labeled (and cached) stay paid
        #    for, the failed chunk is never charged, and the remaining
        #    chunks still run — real oracle usage can never exceed the
        #    sum of what the ledgers were charged.
        failed: List[Tuple[np.ndarray, BaseException]] = []
        step = self.max_batch or max(int(claimed.size), 1)
        for start in range(0, int(claimed.size), step):
            chunk = claimed[start:start + step]
            try:
                labels = self._label_chunk(chunk)
            except BaseException as err:  # noqa: BLE001 — fail-alone below
                failed.append((chunk, err))
                continue
            self.fn_calls += 1
            self.records_labeled += int(chunk.size)
            self._cache.insert(chunk, labels)
            for t, new in claims:
                if t.ledger is not None and new.size:
                    lo = np.searchsorted(new, chunk[0])
                    hi = np.searchsorted(new, chunk[-1], side="right")
                    if hi > lo:
                        t.ledger.charge(hi - lo)
        # 3. resolve. A ticket with any record still unlabeled owned a
        #    failed micro-batch (the cache holds every completed chunk),
        #    so it fails alone with that chunk's error; co-batched
        #    tickets whose records all landed resolve normally.
        for t, new in claims:
            labels, known = self._cache.lookup(t.indices)
            if not bool(known.all()):
                err = next(
                    (e for ch, e in failed if np.isin(t.indices, ch).any()),
                    failed[0][1] if failed else
                    RuntimeError("oracle drain lost labels"))
                t._error, t._done = err, True
                continue
            if t.ledger is not None:
                t.ledger.record(t.indices, labels)
            t._labels, t._done = labels, True

    def _label_chunk(self, chunk: np.ndarray) -> np.ndarray:
        """Label one micro-batch through the resilience stack: circuit
        check -> pacer -> (watchdogged) `fn` -> shape/finiteness
        validation, retried per `self.retry` with deterministic
        per-chunk backoff. Raises the final error once attempts are
        exhausted, the error is fatal, or the circuit is open; callers
        (`_resolve`) translate that into fail-alone ticket poisoning.

        The breaker is consulted exactly once per chunk, *before* the
        attempt loop: a granted half-open probe slot covers every retry
        attempt of this chunk (re-asking `allow()` per attempt would
        reject the probe's own retries and wedge the breaker half-open
        with no failure ever recorded). The chunk's final outcome then
        settles the probe — `record_success` closes the circuit,
        `record_failure` on exhaustion re-opens it and restarts the
        cooldown."""
        if self.breaker is not None and not self.breaker.allow():
            # Shed, not a channel failure: counted as `batch_sheds`
            # (never `batch_failures` — during an outage every chunk of
            # every drain sheds, which would swamp the retry-exhaustion
            # signal) and never recorded on the breaker.
            self.batch_sheds += 1
            raise CircuitOpenError(
                "oracle circuit open — shedding micro-batch",
                retry_after_s=self.breaker.retry_after_s())
        policy = self.retry
        attempts = policy.max_attempts if policy is not None else 1
        salt = int(chunk[0]) if chunk.size else 0
        attempt = 1
        while True:
            try:
                if self._pacer is not None:
                    self._pacer(int(chunk.size))
                if self.call_timeout_s is not None:
                    labels = call_with_timeout(
                        self._fn, chunk, self.call_timeout_s)
                else:
                    labels = self._fn(chunk)
                labels = np.asarray(labels, np.float32).reshape(-1)
                if labels.shape[0] != chunk.shape[0]:
                    raise OracleMalformedError(
                        "oracle returned wrong number of labels "
                        f"({labels.shape[0]} for {chunk.shape[0]} records)")
                if not bool(np.isfinite(labels).all()):
                    raise OracleMalformedError(
                        "oracle returned non-finite labels")
            except BaseException as err:  # noqa: BLE001 — classified below
                if isinstance(err, OracleTimeoutError):
                    self.timeouts += 1
                retryable = (policy.retryable(err) if policy is not None
                             else is_retryable(err))
                if not retryable or attempt >= attempts:
                    self.batch_failures += 1
                    if self.breaker is not None:
                        self.breaker.record_failure()
                    raise
                self.retries += 1
                policy.sleep(policy.backoff_s(attempt, salt))
                attempt += 1
                continue
            if self.breaker is not None:
                self.breaker.record_success()
            return labels


def as_oracle_client(oracle,
                     max_batch: Optional[int] = None,
                     retry: Optional[RetryPolicy] = None,
                     call_timeout_s: Optional[float] = None,
                     breaker: Optional[CircuitBreaker] = None,
                     ) -> OracleClient:
    """Adapter: pass `OracleClient`s through, wrap plain ``indices ->
    labels`` callables in a private `BatchingOracle` — the shim that keeps
    bare callables working across `run`, `run_joint`, `run_many`,
    `queries.run_query`, and `SelectionEngine.session()`. The resilience
    kwargs (`retry`, `call_timeout_s`, `breaker`) configure the private
    channel; passing any of them alongside a ready-made `OracleClient`
    is an error — configure that client directly instead."""
    if isinstance(oracle, OracleClient):
        if retry is not None or call_timeout_s is not None \
                or breaker is not None:
            raise ValueError(
                "retry/call_timeout_s/breaker apply to the private "
                "channel wrapped around a bare callable; configure "
                "your OracleClient directly instead")
        return oracle
    if callable(oracle):
        return BatchingOracle(oracle, max_batch=max_batch, retry=retry,
                              call_timeout_s=call_timeout_s,
                              breaker=breaker)
    raise TypeError(
        f"oracle must be an OracleClient or an indices->labels callable, "
        f"got {type(oracle).__name__}")


# ---------------------------------------------------------------------------
# Back-compat facade
# ---------------------------------------------------------------------------

class BudgetedOracle:
    """Callable facade with the original single-query semantics: one
    private `BatchingOracle` channel plus one `BudgetLedger`.

    Each `__call__` is a submit + drain exchange, so behavior matches the
    historical class — hard budget enforcement, dedup accounting (repeat
    draws of the same record are answered from the cache and do NOT
    consume budget) — but the cache probe is the vectorized `_LabelCache`
    pass instead of a per-element dict loop, and `labeled_positives()` is
    sorted (dict insertion order is not deterministic once batches
    interleave across a session's queries).
    """

    def __init__(self, fn: Callable[[np.ndarray], np.ndarray], budget: int):
        self._client = BatchingOracle(fn)
        self.ledger = BudgetLedger(budget)

    @property
    def budget(self) -> int:
        """The query's ORACLE LIMIT."""
        return self.ledger.budget

    @property
    def calls_used(self) -> int:
        """Labels charged so far (repeat draws are free, see class doc)."""
        return self.ledger.charged

    @property
    def remaining(self) -> int:
        """Budget headroom left."""
        return self.ledger.remaining

    def __call__(self, indices) -> np.ndarray:
        """Label a batch of record indices; returns float32 {0,1} labels."""
        return self._client.submit(indices, ledger=self.ledger).result()

    def labeled_positives(self) -> np.ndarray:
        """Sorted indices labeled positive so far — Algorithm 1's R1."""
        return self.ledger.labeled_positives()


def array_oracle(labels) -> Callable[[np.ndarray], np.ndarray]:
    """Oracle backed by a ground-truth label array (tests / benchmarks)."""
    arr = np.asarray(labels, np.float32)

    def fn(indices):
        return arr[np.asarray(indices, np.int64)]

    return fn
