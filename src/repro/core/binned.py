"""Binned score sketches — the cluster-scale selection data plane.

At production scale the proxy scores A(x) for ~1e9 records live sharded
across data-parallel hosts; a literal port of the paper would centrally sort
them (O(n log n), one host). We adapt: all *global* quantities the SUPG
algorithms need are derivable from a one-pass fixed-width histogram sketch:

  counts[b]    |{x : A(x) in bin b}|      -> |D(tau)| set sizes, rank->tau
  sum_w[b]     sum of sqrt(A(x)) in bin b -> normalization of Theorem-1 weights
  sum_a[b]     sum of A(x) in bin b       -> normalization of 'prop' weights

The sample-side statistics (s <= ~1e4 labeled records) stay exact and are
gathered to every host; only the dataset-side reductions are sketched. The
D'-cutoff snap is *conservative* (rounds the threshold down a bin, enlarging
D'), which preserves validity: stage-2 restriction is an efficiency device,
never a correctness requirement.

The per-shard sketch pass is the HBM-bandwidth hot spot and runs through the
fused Pallas kernel (kernels/score_hist) by default whenever the bin count is
tile-aligned — compiled on TPU, `interpret=True` emulation on CPU — with the
pure-jnp scatter-add formulation kept as the reference/fallback path.

`weight_normalizers` feeds the SelectionEngine's cached sampling state: the
global Σ sqrt(A), Σ A and n extracted from one merged sketch are the only
cross-shard quantities the defensive-mixture draw probabilities need, so the
engine never re-reduces raw shards per query. `chunk_sketch_stats` is the
per-chunk unit of the engine's streaming construction pass: it fuses the
sketch reduction with the float64 per-chunk raw masses the hierarchical
(shard → chunk → record) sampler persists, so bounded-memory importance
sampling costs no extra data pass.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

DEFAULT_BINS = 4096


class ScoreSketch(NamedTuple):
    counts: jnp.ndarray   # (B,) float32 record counts per bin
    sum_w: jnp.ndarray    # (B,) float32 sum of sqrt(A) per bin
    sum_a: jnp.ndarray    # (B,) float32 sum of A per bin

    @property
    def num_bins(self):
        return self.counts.shape[0]

    @property
    def total(self):
        return jnp.sum(self.counts)


def bin_index(scores, num_bins=DEFAULT_BINS):
    """Bin id in [0, B) for scores in [0, 1]; bin b covers [b/B, (b+1)/B)."""
    s = jnp.clip(jnp.asarray(scores, jnp.float32), 0.0, 1.0)
    return jnp.minimum((s * num_bins).astype(jnp.int32), num_bins - 1)


def build_sketch(scores, num_bins=DEFAULT_BINS, use_kernel=None):
    """One-pass sketch of a score shard.

    use_kernel: True forces the fused Pallas kernel, False forces the jnp
    scatter-add reference, None (default) auto-selects the kernel whenever
    the bin count matches its tile layout (TPU compiled / CPU interpret).
    """
    if use_kernel is None:
        from repro.kernels.score_hist import ops as hist_ops
        use_kernel = hist_ops.kernel_supported(num_bins)
    if use_kernel:
        from repro.kernels.score_hist import ops as hist_ops
        return ScoreSketch(*hist_ops.score_hist(scores, num_bins))
    scores = jnp.asarray(scores, jnp.float32)
    idx = bin_index(scores, num_bins)
    # Mask the -1 "unscored" sentinel exactly like the kernel path does —
    # partially-scored ScoreStore shards must sketch identically across
    # backends (the sentinel used to be clipped into bin 0 here).
    valid = (scores >= 0.0).astype(jnp.float32)
    a = jnp.clip(scores, 0.0, 1.0)
    counts = jnp.zeros(num_bins, jnp.float32).at[idx].add(valid)
    sum_w = jnp.zeros(num_bins, jnp.float32).at[idx].add(
        jnp.sqrt(a) * valid)
    sum_a = jnp.zeros(num_bins, jnp.float32).at[idx].add(a * valid)
    return ScoreSketch(counts, sum_w, sum_a)


def chunk_sketch_stats(scores_chunk, num_bins=DEFAULT_BINS, use_kernel=None
                       ) -> Tuple[ScoreSketch, float, float]:
    """One streaming-pass unit over a chunk: its ScoreSketch plus the raw
    sampling masses (float64 Σ sqrt(A), Σ A) the hierarchical sampler
    persists per chunk.

    The chunk is already in cache for the sketch reduction, so the two
    extra sums are effectively free — this is what lets the engine cache
    O(n / chunk_records) sampling state instead of per-record CDFs.
    """
    from repro.core import sampling

    chunk32 = np.ascontiguousarray(scores_chunk, np.float32)
    sketch = build_sketch(jnp.asarray(chunk32), num_bins,
                          use_kernel=use_kernel)
    s_sqrt, s_a = sampling.chunk_raw_masses(chunk32)
    return sketch, s_sqrt, s_a


def merge_sketches(*sketches):
    return ScoreSketch(
        sum(s.counts for s in sketches),
        sum(s.sum_w for s in sketches),
        sum(s.sum_a for s in sketches))


def rank_to_threshold(sketch: ScoreSketch, rank):
    """Conservative tau with |{A >= tau}| >= rank, from bin counts.

    Scans bins from the top; returns the *lower edge* of the bin where the
    cumulative count first reaches `rank` (rounding tau down => superset).
    """
    b = sketch.num_bins
    desc_counts = sketch.counts[::-1]
    cum = jnp.cumsum(desc_counts)
    reached = cum >= jnp.asarray(rank, jnp.float32)
    j = jnp.where(jnp.any(reached), jnp.argmax(reached), b - 1)
    bin_id = (b - 1) - j          # original bin index
    return bin_id.astype(jnp.float32) / b


def selection_size(sketch: ScoreSketch, tau):
    """Upper bound on |{x : A(x) >= tau}| from bin counts (bin-granular)."""
    b = sketch.num_bins
    lo_bin = jnp.floor(jnp.clip(tau, 0.0, 1.0) * b).astype(jnp.int32)
    mask = jnp.arange(b) >= lo_bin
    return jnp.sum(sketch.counts * mask)


def weight_normalizers(sketch: ScoreSketch):
    """Global Σ sqrt(A), Σ A and n — denominators for Theorem-1 / prop weights.

    With defensive mixing at some kappa, a record x in a shard has sampling
    probability
        p(x) = (1-kappa) * sqrt(A(x)) / Z_sqrt + kappa / n_total
    computable shard-locally once (Z_sqrt, n_total) are known globally; the
    normalizers themselves are kappa-independent.
    """
    return jnp.sum(sketch.sum_w), jnp.sum(sketch.sum_a), jnp.sum(sketch.counts)
