"""Distributed SUPG selection engine — the production query executor.

The engine is a *precomputation-cached, vectorized, sketch-driven* data
plane: all O(n) work happens once at construction, after which any number of
RT / PT / JT queries are served off cached per-shard state.

Construction (one chunked pass over the shards, ChunkPlan-driven):

  1. per-chunk `binned.chunk_sketch_stats` — the fused Pallas score_hist
     sketch (compiled on TPU, interpret-mode on CPU; jnp fallback for
     non-tile-aligned bin counts) plus the chunk's float64 raw sampling
     masses (Σ sqrt(A), Σ A) in the same pass — merged into per-shard and
     global sketches (one psum of 48 KiB on a fleet),
  2. hierarchical sampling state: the per-chunk raw masses are the *only*
     persistent per-data sampling state — O(n / chunk_records) floats per
     (shard, scheme), never per-record arrays. Per (scheme, kappa) the
     engine caches the per-shard chunk-mass CDFs (a chunk's defensive mass
     is (1-kappa)·Σraw/Z + kappa·|chunk|/n, from the cached sums alone);
     the normalizers (Z_sqrt, Z_prop, n) come from
     `binned.weight_normalizers` on the merged sketch,
  3. shard-level sampling masses for the (shard → chunk → record) draw are
     the per-shard sums of those chunk masses.

Every chunked walk — sketch construction, selection emission, the PT
stage-2 region draw, and query-time chunk-draw resolution — iterates the
same `data.pipeline.ChunkPlan` and runs through `pipeline.parallel_map`:
with `workers > 1` a small thread pool drives the spans concurrently
(memmap reads, the numpy threshold_select path and the float64 chunk
reductions all release the GIL), with results written to preassigned
slots so thread count never changes any output bit. Sinks carry the
matching thread-safety contract (`SelectionSink` docstring).

Query execution (zero O(n) *state* per query):

  * `draw_sample`   — multinomial over cached shard masses, then an
                      inverse-CDF draw over the cached chunk-mass CDF, then
                      an exact within-chunk inverse-CDF draw over freshly
                      computed weights streaming *only the allocated
                      chunks*; chunk mass × within-chunk p reproduces the
                      defensive-mixture p(x) exactly, so the m(x) factors
                      are globally correct with O(chunk) transient memory,
  * `score_at`      — `np.searchsorted` shard routing + per-shard fancy
                      gathers (no per-element Python loop),
  * tau estimation  — the exact sample-level estimators (Algorithms 2-5;
                      the sample is tiny, so estimation is never distributed),
  * D' restriction  — rank → conservative bin edge through the sketch
                      (superset property),
  * selection       — *streamed*, never materialized: each shard is walked
                      in fixed-size chunks through the fused
                      `kernels/threshold_select` pass (compare + count +
                      index compaction; compiled on TPU, numpy nonzero
                      reference off-TPU) and the selected indices are
                      emitted into a `data.pipeline.SelectionSink`
                      (in-memory `IndexSink` by default, memmap
                      `BitmaskStore` for out-of-core output, `CallbackSink`
                      / `SelectionStream` for service streaming). Labeled
                      positives (Algorithm 1's R1) are folded in as a
                      sink-level merge of the positives *below* tau, so
                      emission and folding stay disjoint and per-shard
                      counts are exact without dedup state.

A query over a 1e8-record memmap store therefore peaks at O(chunk) host
memory *for every method, importance-weighted included*: no full-corpus
boolean mask or per-record CDF is ever allocated, `ShardedSelection` is a
lazy view whose `total_selected` comes from per-shard counts, boolean masks
only materialize if a caller explicitly asks for them, and the PT stage-2
uniform-in-D' draw is rank-routed through the same chunked pass. The former
O(n) surface — dense per-record inverse-CDF state behind `method="is"` —
is gone: persistent sampling state is ≤ n / chunk_records entries per
(shard, scheme) and record-level draws stream only their allocated chunks,
so the `weight_schemes=()` escape hatch is no longer needed (the argument
is kept as a cache pre-warm hint).

Multi-query execution is built on *resumable query plans* and a shared
labeling channel. The bodies of `run`/`run_joint` are generators
(`_run_plan` / `_run_joint_plan`) that *yield* `OracleRequest`s instead of
calling the oracle inline; everything between two yields is pure compute
off the cached state. A single query drives its plan through a trivial
trampoline (submit → drain → resume). `SelectionEngine.session()` returns a
`QuerySession` that schedules N plans concurrently: each round it advances
every in-flight plan to its next oracle request through the PR-3
`pipeline.parallel_map` worker pool (the emission passes are embarrassingly
parallel given the cached state), funnels all yielded requests through one
`core.oracle.BatchingOracle`, drains once, and resumes the plans with their
labels. The session therefore coalesces the expensive oracle across
queries — one `fn` micro-batch can serve every in-flight query — while
per-query `BudgetLedger` views keep ORACLE LIMIT enforcement per query
(see `core/oracle.py` for the shared-cache budget semantics).

`run_many` is a thin wrapper over a session (`concurrency=` knob) serving a
*batch* of queries — SUPGQuery (RT/PT) and JointSUPGQuery (JT, Appendix A) —
amortizing the sketch, the cached sampling state, *and the oracle channel*
across the whole batch; this is the serving-plane entry point. Per-query
sinks make it the streaming fan-out point for a service. Because plans are
pure given (key, labels) and a pure oracle answers identically regardless
of batching, `run_many` output (tau, counts, sink contents) is bit-for-bit
identical at any `concurrency`; only the per-query `oracle_calls`
*attribution* can shift when queries overlap (the shared cache answers
later queries for free).

Shards are host-local float32 arrays: plain np.ndarray, np.memmap, or
`data.pipeline.ScoreStore` objects (consumed zero-copy through `.scores`, so
out-of-core corpora work end-to-end; sketch construction over shards larger
than `chunk_records` is itself chunked and merged, so even engine build never
materializes a full shard). On a real fleet each worker holds its shard and
the driver runs where the coordinator lives; the collective math matches
core/distributed.py.
"""
from __future__ import annotations

import dataclasses
import os
from typing import (Dict, Generator, List, Optional, Sequence, Tuple,
                    Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binned, sampling, thresholds
from repro.core.oracle import (BudgetLedger, OracleClient, OracleRequest,
                               as_oracle_client)
from repro.core.queries import JointSUPGQuery, SUPGQuery
from repro.data import pipeline
from repro.kernels.threshold_select import ops as select_ops


def _close_quietly(sink: "pipeline.SelectionSink") -> None:
    """Best-effort close on an error path: the sink must come back
    reusable (the double-open guard would otherwise wedge it), but the
    original exception owns the outcome — a close failure is secondary."""
    try:
        sink.close()
    except Exception:  # noqa: BLE001 — error path; original exc wins
        pass


class ShardedSelection:
    """Lazy view over one query's selection.

    Sink-backed (the engine's streaming output) or mask-backed (direct
    construction, kept for compatibility). In the sink-backed form nothing
    O(corpus) lives here: `total_selected` and `shard_counts` come from the
    per-shard counts the sink accumulated during emission, `indices(shard)`
    reads the sink, and `masks` materializes per-shard boolean views only
    when explicitly accessed (state-holding sinks only — a CallbackSink
    selection retains counts alone).
    """

    def __init__(self, masks: Optional[List[np.ndarray]] = None,
                 tau: float = 0.0, oracle_calls: int = 0,
                 sampled_positive_global: Optional[np.ndarray] = None,
                 sink: Optional[pipeline.SelectionSink] = None,
                 shard_sizes: Optional[Sequence[int]] = None,
                 counts: Optional[np.ndarray] = None):
        if masks is None and sink is None:
            raise ValueError("need per-shard masks or a SelectionSink")
        self.tau = float(tau)
        self.oracle_calls = int(oracle_calls)
        self.sampled_positive_global = (
            np.empty(0, np.int64) if sampled_positive_global is None
            else np.asarray(sampled_positive_global, np.int64))
        self.sink = sink
        self._masks = list(masks) if masks is not None else None
        if shard_sizes is None:
            if self._masks is not None:
                shard_sizes = [int(m.shape[0]) for m in self._masks]
            elif getattr(sink, "shard_sizes", None) is not None:
                shard_sizes = sink.shard_sizes   # an opened sink knows them
            else:
                raise ValueError(
                    "shard_sizes required when the sink has not been opened")
        self.shard_sizes = [int(n) for n in shard_sizes]
        self._counts = (None if counts is None
                        else np.asarray(counts, np.int64))

    @property
    def num_shards(self) -> int:
        return len(self.shard_sizes)

    @property
    def shard_counts(self) -> np.ndarray:
        """Per-shard selected counts (no mask materialization needed)."""
        if self._counts is not None:
            return self._counts.copy()
        return np.asarray([int(m.sum()) for m in self.masks], np.int64)

    @property
    def total_selected(self) -> int:
        if self._counts is not None:
            return int(self._counts.sum())
        return int(sum(int(m.sum()) for m in self.masks))

    def indices(self, shard_id: int) -> np.ndarray:
        """Sorted shard-local selected indices for one shard."""
        if self._masks is not None:
            return np.nonzero(self._masks[shard_id])[0].astype(np.int64)
        return np.asarray(self.sink.indices(shard_id), np.int64)

    @property
    def masks(self) -> List[np.ndarray]:
        """Per-shard boolean masks, materialized lazily from the sink.

        Allocates O(corpus) booleans — for large stores prefer
        `shard_counts` / `indices` / the sink itself.
        """
        if self._masks is None:
            self._masks = [self.sink.mask(i)
                           for i in range(self.num_shards)]
        return self._masks


@dataclasses.dataclass
class _ShardChunkState:
    """Cached per-shard hierarchical draw state for one (scheme, kappa):
    the shard's total defensive mass and its normalized chunk-mass CDF —
    O(n_chunks) persistent floats, never per-record arrays."""
    mass: float            # shard total defensive mass (unnormalized)
    cdf: np.ndarray        # (n_chunks,) float64 normalized chunk-mass CDF


class SelectionEngine:
    """Executes batches of SUPG queries over a list of score shards."""

    def __init__(self, shards: Sequence, num_bins: int = 4096,
                 use_kernel: Optional[bool] = None,
                 weight_schemes: Sequence[str] = ("sqrt",),
                 kappa: float = sampling.DEFENSIVE_KAPPA,
                 cache_flat: Optional[bool] = None,
                 select_backend: Optional[str] = None,
                 chunk_records: Optional[int] = None,
                 workers: Optional[int] = None):
        # ScoreStore (or anything exposing `.scores`) passes its memmap
        # through untouched; ndarray shards are viewed, not copied.
        raw_shards = [getattr(s, "scores", s) for s in shards]
        # Flat gather cache: for in-RAM shards a one-time concatenation
        # turns score_at into a single fancy gather. Defaults off for
        # memmap-backed (out-of-core) shards, which keep the routed path.
        # (Decide on the raw objects: np.asarray strips the memmap subclass.)
        if cache_flat is None:
            cache_flat = not any(isinstance(s, np.memmap)
                                 for s in raw_shards)
        self.shards = [np.asarray(s) for s in raw_shards]
        self.offsets = np.concatenate(
            [[0], np.cumsum([s.shape[0] for s in self.shards])]).astype(
                np.int64)
        self.n_total = int(self.offsets[-1])
        self.num_bins = num_bins
        self.kappa = float(kappa)
        # Streaming emission knobs: chunk_records bounds per-query peak
        # memory; select_backend picks the threshold_select path (compiled
        # Pallas on TPU, numpy reference elsewhere by default — interpret
        # emulation stays available for kernel validation).
        self.chunk_records = int(chunk_records or pipeline.CHUNK_RECORDS)
        self.select_backend = (select_ops.default_backend()
                               if select_backend is None else select_backend)
        self.workers = max(1, int(workers)) if workers else 1
        self.plan = pipeline.ChunkPlan(
            [int(s.shape[0]) for s in self.shards], self.chunk_records)
        self._flat = (np.concatenate(
            [np.asarray(s, np.float32) for s in self.shards])
            if cache_flat and self.shards else None)

        # 1. chunked construction pass (ChunkPlan-driven, threaded): each
        #    span yields its ScoreSketch *and* its raw sampling masses in
        #    one touch of the data. Sketches merge additively into
        #    per-shard and global sketches, so even memmap shards never
        #    materialize whole; the per-chunk masses become the persistent
        #    O(n / chunk_records) hierarchical sampling state.
        spans = list(self.plan)
        stats = pipeline.parallel_map(
            lambda sp: binned.chunk_sketch_stats(
                self.shards[sp.shard_id][sp.start:sp.stop], num_bins,
                use_kernel=use_kernel),
            spans, self.workers)
        parts: List[List] = [[] for _ in self.shards]
        sums: List[List[Tuple[float, float, int]]] = [[] for _ in self.shards]
        for sp, (sk, s_sqrt, s_a) in zip(spans, stats):
            parts[sp.shard_id].append(sk)
            sums[sp.shard_id].append((s_sqrt, s_a, sp.size))
        # Empty shards get an all-zero sketch via the jnp path (the kernel
        # grid cannot span a zero-length operand).
        self.shard_sketches = [
            binned.merge_sketches(*p) if p else
            binned.build_sketch(jnp.zeros((0,), jnp.float32), num_bins,
                                use_kernel=False)
            for p in parts]
        self.sketch = binned.merge_sketches(*self.shard_sketches)
        self._chunk_masses = [
            sampling.ChunkMasses(
                np.asarray([t[0] for t in ss], np.float64),
                np.asarray([t[1] for t in ss], np.float64),
                np.asarray([t[2] for t in ss], np.int64))
            if ss else sampling.ChunkMasses.empty()
            for ss in sums]

        # 2. global weight normalizers from the merged sketch — the only
        #    cross-shard reductions sampling ever needs.
        z_sqrt, z_prop, _ = binned.weight_normalizers(self.sketch)
        self._z = {"sqrt": float(z_sqrt), "prop": float(z_prop)}

        # 3. chunk-mass CDFs per (scheme, kappa) — O(n_chunks) each.
        #    `weight_schemes` is a pre-warm hint only: since the dense
        #    per-record CDFs are gone, every scheme is bounded-memory and
        #    un-warmed schemes build lazily on first use.
        self._sampling_cache: Dict[Tuple[str, float], List[
            _ShardChunkState]] = {}
        for scheme in weight_schemes:
            self._sampling_state(scheme, self.kappa)

    # -- cached state ---------------------------------------------------

    def _sampling_state(self, scheme: str,
                        kappa: float) -> List[_ShardChunkState]:
        cache_key = (scheme, float(kappa))
        if cache_key not in self._sampling_cache:
            states = []
            for cm in self._chunk_masses:
                if cm.sizes.size == 0:   # empty shard: zero mass, no draws
                    states.append(_ShardChunkState(
                        mass=0.0, cdf=np.empty(0, np.float64)))
                    continue
                m_c = sampling.defensive_chunk_mass(
                    cm.raw(scheme), cm.sizes, self._z[scheme], kappa,
                    self.n_total)
                total = float(m_c.sum())
                if not total > 0:
                    raise ValueError(
                        "shard has no sampling mass (kappa=0 with an "
                        "all-zero proxy?)")
                states.append(_ShardChunkState(
                    mass=total, cdf=np.cumsum(m_c) / total))
            self._sampling_cache[cache_key] = states
        return self._sampling_cache[cache_key]

    def _shard_masses(self, scheme: str, kappa: float) -> np.ndarray:
        states = self._sampling_state(scheme, kappa)
        mass = np.asarray([st.mass for st in states], np.float64)
        return mass / mass.sum()

    # -- sampling -------------------------------------------------------

    @staticmethod
    def _group_sorted(values: np.ndarray, order: np.ndarray):
        """Split `order` (an argsort of `values`) into runs of equal value.

        Yields (value, positions) — the argsort-grouping trick `score_at`
        uses, so grouping s draws over k groups costs one sort instead of
        k boolean mask scans.
        """
        if order.size == 0:
            return
        sorted_vals = values[order]
        cuts = np.flatnonzero(np.diff(sorted_vals)) + 1
        for grp in np.split(order, cuts):
            yield int(values[grp[0]]), grp

    def draw_sample(self, key, s: int, scheme: str = "sqrt",
                    kappa: Optional[float] = None):
        """Global with-replacement draws; returns (global_idx, m).

        Hierarchical (shard → chunk → record): multinomial over cached
        shard masses, inverse-CDF over each shard's cached chunk-mass CDF,
        then an exact within-chunk inverse-CDF draw over freshly computed
        p(x) — only the allocated chunks are ever streamed, so transient
        memory is O(chunk) and persistent state O(n_chunks). The joint
        draw probability telescopes to the global defensive-mixed p(x)
        (shard mass = Σ chunk masses, chunk mass = Σ p(x) over the chunk),
        so m(x) = (1/n) / p(x) is globally correct. Draws are grouped by
        shard and chunk with argsorts (no per-shard mask scans) and chunk
        resolution runs through the worker pool; outputs land in
        preassigned slots, so results are identical at any worker count.
        """
        if scheme == "uniform":
            idx = jax.random.randint(key, (s,), 0, self.n_total)
            return np.asarray(idx, np.int64), np.ones(s, np.float32)
        kappa = self.kappa if kappa is None else kappa
        states = self._sampling_state(scheme, kappa)
        mass = self._shard_masses(scheme, kappa)
        k_alloc, k_chunk, k_rec = jax.random.split(key, 3)
        alloc = np.asarray(jax.random.categorical(
            k_alloc, jnp.log(jnp.asarray(mass, jnp.float32)), shape=(s,)))
        u_chunk = np.asarray(jax.random.uniform(k_chunk, (s,)), np.float64)
        u_rec = np.asarray(jax.random.uniform(k_rec, (s,)), np.float64)
        out_idx = np.empty(s, np.int64)
        out_m = np.empty(s, np.float32)
        work = []    # (shard_id, chunk_id, draw positions into [0, s))
        for sh, seg in self._group_sorted(alloc,
                                          np.argsort(alloc, kind="stable")):
            chunk_ids = sampling.draw_from_cdf(states[sh].cdf, u_chunk[seg])
            for ci, grp in self._group_sorted(
                    chunk_ids, np.argsort(chunk_ids, kind="stable")):
                work.append((sh, ci, seg[grp]))

        chunk = self.plan.chunk_records

        def resolve(item):
            sh, ci, pos = item
            start = ci * chunk
            p = sampling.defensive_probs(
                self.shards[sh][start:start + chunk], scheme,
                self._z[scheme], kappa, self.n_total)
            local = sampling.draw_from_cdf(sampling.normalized_cdf(p),
                                           u_rec[pos])
            out_idx[pos] = self.offsets[sh] + start + local
            out_m[pos] = (1.0 / self.n_total) / np.maximum(
                p[local], 1e-38)

        pipeline.parallel_map(resolve, work, self.workers)
        return out_idx, out_m

    def score_at(self, global_idx) -> np.ndarray:
        """Vectorized gather: one flat fancy gather when the concatenation
        cache is live, else searchsorted shard routing + per-shard fancy
        indexing (works unchanged on memmap shards)."""
        gi = np.asarray(global_idx, np.int64)
        if self._flat is not None:
            return self._flat[gi]
        sh = np.searchsorted(self.offsets, gi, side="right") - 1
        local = gi - self.offsets[sh]
        out = np.empty(gi.shape[0], np.float32)
        # Group draws by shard with one argsort, then gather each shard's
        # segment with a single fancy index (one touch per shard).
        order = np.argsort(sh, kind="stable")
        seg_bounds = np.searchsorted(sh[order],
                                     np.arange(len(self.shards) + 1))
        for shard_id in range(len(self.shards)):
            seg = order[seg_bounds[shard_id]:seg_bounds[shard_id + 1]]
            if seg.size:
                out[seg] = np.asarray(
                    self.shards[shard_id][local[seg]], np.float32)
        return out

    # -- query plans ------------------------------------------------------

    def _run_plan(self, key, query: SUPGQuery, *,
                  sink: Optional[pipeline.SelectionSink] = None,
                  chunk_records: Optional[int] = None) \
            -> Generator[OracleRequest, np.ndarray, ShardedSelection]:
        """Resumable plan for one RT/PT query.

        Yields `OracleRequest`s wherever the old body called the oracle
        inline and receives the label array back at the same point;
        everything between yields is pure compute off the cached state, so
        a scheduler may interleave any number of plans and answer their
        requests from one coalesced labeling channel. Returns the
        ShardedSelection via StopIteration.value.
        """
        key = jax.random.PRNGKey(0) if key is None else key
        ledger = BudgetLedger(query.budget)
        s = query.budget
        if query.target == "recall":
            scheme = {"is": query.weight_scheme, "uniform": "uniform",
                      "noci": "uniform"}[query.method]
            idx, m = self.draw_sample(key, s, scheme)
            o_s = yield OracleRequest(idx, ledger)
            a_s = self.score_at(idx)
            if query.method == "noci":
                res = thresholds.tau_unoci_r(a_s, o_s, query.gamma)
            else:
                res = thresholds.tau_ci_r(a_s, o_s, m, query.gamma,
                                          query.delta)
            tau = float(res.tau)
        else:
            k0, k1 = jax.random.split(key)
            if query.method == "is" and query.two_stage:
                idx0, m0 = self.draw_sample(k0, s // 2, query.weight_scheme)
                o0 = yield OracleRequest(idx0, ledger)
                _, rank = thresholds.pt_stage1_nmatch(
                    o0, m0, self.n_total, query.gamma, query.delta)
                tau_dp = float(binned.rank_to_threshold(self.sketch,
                                                        int(rank)))
                # stage 2: uniform on D' via per-shard masked draws
                idx1 = self._uniform_in_region(k1, s - s // 2, tau_dp)
                o1 = yield OracleRequest(idx1, ledger)
                a1 = self.score_at(idx1)
                res = thresholds.tau_ci_p(a1, o1, query.gamma,
                                          query.delta / 2.0,
                                          min_step=query.min_step)
            else:
                scheme = ("uniform" if query.method in ("uniform", "noci")
                          else query.weight_scheme)
                idx, m = self.draw_sample(k0, s, scheme)
                o_s = yield OracleRequest(idx, ledger)
                a_s = self.score_at(idx)
                if query.method == "noci":
                    res = thresholds.tau_unoci_p(a_s, o_s, query.gamma)
                else:
                    res = thresholds.tau_ci_p(
                        a_s, o_s, query.gamma, query.delta,
                        m_s=None if scheme == "uniform" else m,
                        min_step=query.min_step)
            tau = float(res.tau)

        pos = ledger.labeled_positives()
        return self._emit_selection(tau, pos, ledger.charged, sink,
                                    chunk_records)

    def _run_joint_plan(self, key, query: JointSUPGQuery, *,
                        sink: Optional[pipeline.SelectionSink] = None,
                        chunk_records: Optional[int] = None) \
            -> Generator[OracleRequest, np.ndarray, ShardedSelection]:
        """Resumable plan for one JT query (Appendix A): the RT sub-plan
        (delegated via `yield from`, so its oracle requests ride the same
        channel), then chunked verification requests over the candidate
        set. The verification ledger is capped at n_total — unbounded by
        design — and exists for `oracle_calls` attribution only."""
        rt = SUPGQuery(target="recall", gamma=query.gamma_recall,
                       delta=query.delta, budget=query.stage_budget,
                       method=query.method)
        cand = yield from self._run_plan(key, rt,
                                         chunk_records=chunk_records)
        vledger = BudgetLedger(self.n_total)
        out = pipeline.IndexSink() if sink is None else sink
        chunk = int(chunk_records or self.chunk_records)
        sizes = [int(s.shape[0]) for s in self.shards]
        out.open(sizes)
        try:
            for sh in range(len(self.shards)):
                local = cand.indices(sh)
                for start in range(0, local.size, chunk):
                    seg = local[start:start + chunk]
                    labels = yield OracleRequest(self.offsets[sh] + seg,
                                                 vledger)
                    out.emit(sh, seg[labels > 0.5])
        except BaseException:
            # Failed (or abandoned — GeneratorExit) mid-verification:
            # release the sink so sequential reuse still works; its
            # partial contents are owned by the raised error.
            _close_quietly(out)
            raise
        counts = out.close()
        return ShardedSelection(
            tau=cand.tau,
            oracle_calls=cand.oracle_calls + vledger.charged,
            sampled_positive_global=cand.sampled_positive_global,
            sink=out, shard_sizes=sizes, counts=counts)

    def _plan_for(self, key, query, *, sink=None, chunk_records=None):
        if isinstance(query, JointSUPGQuery):
            return self._run_joint_plan(key, query, sink=sink,
                                        chunk_records=chunk_records)
        return self._run_plan(key, query, sink=sink,
                              chunk_records=chunk_records)

    # -- query entry points -----------------------------------------------

    def run(self, key, oracle_fn, query: SUPGQuery, *,
            sink: Optional[pipeline.SelectionSink] = None,
            chunk_records: Optional[int] = None) -> ShardedSelection:
        """Execute one RT/PT query, streaming the selection through `sink`.

        `oracle_fn` is a plain ``indices -> labels`` callable (adapted
        into a private labeling channel — exactly the historical
        per-query-budget semantics) or an `OracleClient` such as a shared
        `BatchingOracle`, in which case its label cache carries over.
        With no sink the selection lands in an in-memory `IndexSink`
        (O(selected) host memory); pass a `BitmaskStore` for out-of-core
        output or a `CallbackSink` to consume chunks as they are emitted.
        """
        return _drive_plan(
            self._run_plan(key, query, sink=sink,
                           chunk_records=chunk_records),
            as_oracle_client(oracle_fn))

    def run_joint(self, key, oracle_fn, query: JointSUPGQuery, *,
                  sink: Optional[pipeline.SelectionSink] = None,
                  chunk_records: Optional[int] = None) -> ShardedSelection:
        """Engine-level JT query (Appendix A): RT stage at gamma_recall,
        then exhaustive oracle filtering of the candidate set. The RT stage
        streams into an internal IndexSink; verification then re-walks the
        candidate indices in chunks, emitting only oracle-verified positives
        into `sink` (precision exactly 1.0; oracle usage beyond the RT
        stage is unbounded by design). Both stages ride one labeling
        channel, so verification gets RT-stage labels from the cache for
        free."""
        return _drive_plan(
            self._run_joint_plan(key, query, sink=sink,
                                 chunk_records=chunk_records),
            as_oracle_client(oracle_fn))

    def session(self, oracle_fn, *, concurrency: Optional[int] = None,
                max_batch: Optional[int] = None) -> "QuerySession":
        """Open a `QuerySession`: the multi-query scheduler + shared
        batched-oracle channel. Use as a context manager::

            with engine.session(oracle_fn, concurrency=8) as sess:
                handles = [sess.submit(q, key=k) for q, k in work]
                results = [h.result() for h in handles]

        All in-flight plans' oracle requests funnel through one
        `BatchingOracle` (unless `oracle_fn` is already an `OracleClient`,
        which is then shared as-is), so overlapping samples are labeled
        once and micro-batches span queries. `concurrency` caps in-flight
        plans (default: unbounded — every submitted query joins the next
        round); `max_batch` caps records per underlying oracle call.
        """
        return QuerySession(self, oracle_fn, concurrency=concurrency,
                            max_batch=max_batch)

    def run_many(self, key, oracle_fn,
                 queries: Sequence[Union[SUPGQuery, JointSUPGQuery]], *,
                 sinks: Optional[Sequence[
                     Optional[pipeline.SelectionSink]]] = None,
                 chunk_records: Optional[int] = None,
                 concurrency: Optional[int] = None) \
            -> List[ShardedSelection]:
        """Serve a batch of RT / PT / JT queries off one cached state —
        a thin wrapper over `session()`.

        The sketch, shard masses, and per-scheme CDFs were built once at
        construction; each query only pays O(s) sampling + one streamed
        O(n) emission pass, and the whole batch shares one labeling
        channel (overlapping samples are labeled once; oracle calls are
        coalesced across queries into micro-batches). Budgets are enforced
        per query via `BudgetLedger` views. `concurrency` caps in-flight
        plans (default: the whole batch); output (tau, counts, sink
        contents) is bit-for-bit identical at any concurrency for a pure
        oracle. `sinks`, when given, supplies one sink per query (None
        entries fall back to a fresh IndexSink) — the streaming fan-out
        point for a service; one sink object cannot serve two queries
        (their emissions would interleave).
        """
        if sinks is None:
            sinks = [None] * len(queries)
        # Validate the sink list before any key splitting so a malformed
        # call fails on the actual mistake, not a shape error downstream.
        if len(sinks) != len(queries):
            raise ValueError(
                f"need exactly one sink (or None) per query: got "
                f"{len(sinks)} sinks for {len(queries)} queries")
        live = [id(s) for s in sinks if s is not None]
        if len(live) != len(set(live)):
            raise ValueError(
                "one sink object is shared by multiple queries; their "
                "emissions would interleave — give each query its own sink")
        if not len(queries):
            return []
        keys = jax.random.split(
            jax.random.PRNGKey(0) if key is None else key, len(queries))
        with self.session(oracle_fn, concurrency=concurrency) as sess:
            handles = [sess.submit(q, key=k, sink=snk,
                                   chunk_records=chunk_records)
                       for k, q, snk in zip(keys, queries, sinks)]
            return [h.result() for h in handles]

    # -- streaming emission ---------------------------------------------

    def _emit_selection(self, tau: float, pos: np.ndarray,
                        oracle_calls: int,
                        sink: Optional[pipeline.SelectionSink],
                        chunk_records: Optional[int]) -> ShardedSelection:
        """Stream {A >= tau} ∪ labeled-positives through a sink.

        The ChunkPlan spans are walked through the fused threshold_select
        pass — concurrently across the worker pool when workers > 1 (the
        sink serializes its own consumption; see its thread-safety
        contract) — so peak host memory is O(chunk) and per-shard counts
        accumulate in the sink; no full-corpus boolean mask is ever
        allocated. Labeled positives are folded as a sink-level merge of
        the positives *below* tau (those at/above tau stream out of their
        own chunks), keeping fold/emit disjoint and counts exact. Unscored
        records (the -1 sentinel) are never emitted by the threshold pass;
        an unscored labeled positive still folds in, exactly like the
        materialized path selected it.
        """
        sink = pipeline.IndexSink() if sink is None else sink
        chunk = int(chunk_records or self.chunk_records)
        sizes = [int(s.shape[0]) for s in self.shards]
        plan = (self.plan if chunk == self.chunk_records
                else pipeline.ChunkPlan(sizes, chunk))
        sink.open(sizes)

        def emit_span(span):
            block = self.shards[span.shard_id][span.start:span.stop]
            local = select_ops.threshold_select(
                block, tau, backend=self.select_backend)
            if local.size:
                sink.emit(span.shard_id, span.start + local)

        try:
            if pos.size:
                below = pos[self.score_at(pos) < tau]
                if below.size:
                    sh_ids = np.searchsorted(self.offsets, below,
                                             side="right") - 1
                    for shard_id in np.unique(sh_ids):
                        loc = (below[sh_ids == shard_id]
                               - self.offsets[shard_id])
                        sink.fold(int(shard_id), np.unique(loc))
            pipeline.parallel_map(emit_span, plan, self.workers)
        except BaseException:
            # Emission died (e.g. a CallbackSink consumer raised): release
            # the sink so sequential reuse still works.
            _close_quietly(sink)
            raise
        counts = sink.close()
        return ShardedSelection(tau=float(tau), oracle_calls=oracle_calls,
                                sampled_positive_global=pos, sink=sink,
                                shard_sizes=sizes, counts=counts)

    def _uniform_in_region(self, key, s, tau):
        """Uniform draws from {A >= tau} across shards, chunk-streamed.

        One ChunkPlan counting pass (threaded over spans) yields per-chunk
        region sizes; draws are then rank-routed through those cached
        counts, so the resolution pass re-runs threshold_select only on
        chunks that actually received draws — chunks whose region is empty
        carry zero rank mass and are skipped for free. The PT stage-2
        restriction therefore runs at O(chunk) peak memory like selection
        emission: no full-shard mask or nonzero is ever materialized
        (unscored sentinel records are excluded, like emission).

        Shards whose region is empty get exactly zero categorical mass (no
        floor), so draws can never be clamped onto records below tau. If the
        region is globally empty the draws fall back to uniform over all
        records — tau estimation then sees an unrestricted uniform sample,
        which keeps the estimator valid (D' restriction is an efficiency
        device, never a correctness requirement).
        """
        plan = self.plan
        spans = list(plan)

        def count_span(span):
            # Count through the exact same selection pass the resolve step
            # uses: any dtype/backend rounding disagreement between the two
            # would desynchronize ranks from region sizes.
            return select_ops.threshold_select(
                self.shards[span.shard_id][span.start:span.stop], tau,
                backend=self.select_backend).size

        span_counts = pipeline.parallel_map(count_span, spans, self.workers)
        per_shard = [np.zeros(plan.num_chunks(sh), np.int64)
                     for sh in range(len(self.shards))]
        for span, c in zip(spans, span_counts):
            per_shard[span.shard_id][span.chunk_id] = c
        counts = np.asarray([pc.sum() for pc in per_shard], np.float64)
        total = counts.sum()
        if total == 0:
            idx = jax.random.randint(key, (s,), 0, self.n_total)
            return np.asarray(idx, np.int64)
        mass = counts / total
        k_alloc, k_draw = jax.random.split(key)
        # log(0) = -inf => empty shards are excluded from the categorical.
        alloc = np.asarray(jax.random.categorical(
            k_alloc, jnp.log(jnp.asarray(mass, jnp.float32)), shape=(s,)))
        out = np.empty(s, np.int64)
        dkeys = jax.random.split(k_draw, len(self.shards))
        work = []    # (shard_id, chunk_id, positions, in-chunk region ranks)
        for sh, seg in self._group_sorted(alloc,
                                          np.argsort(alloc, kind="stable")):
            cum = np.concatenate([[0], np.cumsum(per_shard[sh])])
            # uniform region ranks, then rank -> (chunk, offset-in-chunk);
            # only chunks with nonzero region counts can be hit.
            r = np.asarray(jax.random.randint(
                dkeys[sh], (seg.size,), 0, int(cum[-1])), np.int64)
            ch = np.searchsorted(cum, r, side="right") - 1
            corder = np.argsort(ch, kind="stable")
            for ci, grp in self._group_sorted(ch, corder):
                work.append((sh, ci, seg[grp], r[grp] - cum[ci]))

        chunk = plan.chunk_records

        def resolve(item):
            sh, ci, pos, ranks = item
            start = ci * chunk
            region = select_ops.threshold_select(
                self.shards[sh][start:start + chunk], tau,
                backend=self.select_backend)
            out[pos] = self.offsets[sh] + start + region[ranks]

        pipeline.parallel_map(resolve, work, self.workers)
        return out


# ---------------------------------------------------------------------------
# Query scheduling — the async multi-query execution plane
# ---------------------------------------------------------------------------

def _drive_plan(plan, client: OracleClient) -> ShardedSelection:
    """Sequential trampoline: advance one plan to each OracleRequest,
    answer it through the channel (submit + result, which drains), resume.
    This is exactly the single-query execution path of `run`/`run_joint`.

    A channel error is thrown *into* the plan at its yield point, not
    raised from here directly: the suspended generator would otherwise
    stay alive on the exception's traceback with its cleanup (sink
    release) never run."""
    send = None
    while True:
        try:
            req = plan.send(send)
        except StopIteration as done:
            return done.value
        try:
            send = client.submit(req.indices, ledger=req.ledger).result()
        except BaseException as err:  # noqa: BLE001 — rethrown in plan
            try:
                plan.throw(err)       # runs the plan's except/finally
            except StopIteration as done:
                return done.value     # plan absorbed the error gracefully
            raise RuntimeError(
                "plan yielded again after its oracle request failed")


_START = object()       # inbox sentinel: plan not yet started


class QueryHandle:
    """Future for one query submitted to a `QuerySession`.

    `result()` pumps the session's scheduler until this query's plan
    completes, then returns its `ShardedSelection` — or raises the plan's
    error (`BudgetExceededError` if this query's ledger was rejected in a
    coalesced drain; other queries are unaffected).
    """

    def __init__(self, session: "QuerySession", query, sink):
        self.query = query
        self.sink = sink
        self._session = session
        self._result: Optional[ShardedSelection] = None
        self._error: Optional[BaseException] = None
        self._done = False

    @property
    def done(self) -> bool:
        return self._done

    def result(self) -> ShardedSelection:
        if not self._done:
            self._session._pump(until=self)
        if self._error is not None:
            raise self._error
        return self._result


class QuerySession:
    """Scheduler that drives N query plans concurrently over one shared,
    batched labeling channel — `SelectionEngine.session()`'s return value.

    Scheduling is round-based and deterministic: every round, all
    in-flight plans advance to their next `OracleRequest` concurrently
    through `pipeline.parallel_map` (each step is pure compute — sampling,
    tau estimation, streamed emission — off the engine's cached state);
    the driver then submits every yielded request to the shared
    `BatchingOracle` *in submission order*, drains once, and resumes each
    plan with its labels. One drain therefore coalesces the oracle across
    every in-flight query, and the fixed submission order keeps charge
    attribution reproducible at a given concurrency. Plans that finish
    leave the round; queued plans join up to `concurrency` in submission
    order. A plan whose ticket failed (e.g. `BudgetExceededError`) has the
    error thrown into it at its yield point — that query's handle raises,
    co-batched queries are untouched.

    The scheduler itself runs on whichever thread pumps it (a
    `handle.result()` call or the context-manager exit) — there is no
    background thread, so results are deterministic functions of
    (keys, queries, oracle, concurrency).
    """

    def __init__(self, engine: SelectionEngine, oracle_fn, *,
                 concurrency: Optional[int] = None,
                 max_batch: Optional[int] = None):
        self.engine = engine
        self.client = as_oracle_client(oracle_fn, max_batch=max_batch)
        self.concurrency = (None if concurrency is None
                            else max(1, int(concurrency)))
        self._queued: List[Tuple[QueryHandle, Generator]] = []
        self._active: List[List] = []    # [handle, plan, inbox]
        self._closed = False

    # -- submission -------------------------------------------------------

    def submit(self, query, *, key=None,
               sink: Optional[pipeline.SelectionSink] = None,
               chunk_records: Optional[int] = None) -> QueryHandle:
        """Enqueue one RT/PT/JT query; returns its `QueryHandle`.

        `key` defaults to PRNGKey(0) (pass distinct keys for distinct
        samples — `run_many` splits one key across its batch). The plan
        starts when a scheduler round has a free slot (`concurrency`).
        """
        if self._closed:
            raise RuntimeError("QuerySession is closed")
        handle = QueryHandle(self, query, sink)
        plan = self.engine._plan_for(key, query, sink=sink,
                                     chunk_records=chunk_records)
        self._queued.append((handle, plan))
        return handle

    def drain(self) -> None:
        """Explicit barrier on the shared channel (pending tickets only —
        plans advance when the scheduler is pumped)."""
        self.client.drain()

    # -- scheduler --------------------------------------------------------

    def _pump(self, until: Optional[QueryHandle] = None) -> None:
        """Run scheduler rounds until `until` (or everything) completes."""
        while not (until._done if until is not None
                   else not (self._active or self._queued)):
            cap = self.concurrency or (len(self._active)
                                       + len(self._queued))
            while self._queued and len(self._active) < cap:
                handle, plan = self._queued.pop(0)
                self._active.append([handle, plan, _START])
            if not self._active:
                raise RuntimeError(
                    "pumped a handle that is neither queued nor active")
            self._round()

    def _round(self) -> None:
        """One scheduler round: step all plans, coalesce, drain, resume."""

        def step(slot):
            _, plan, inbox = slot
            try:
                if inbox is _START:
                    return ("req", plan.send(None))
                if isinstance(inbox, BaseException):
                    return ("req", plan.throw(inbox))
                return ("req", plan.send(inbox))
            except StopIteration as done:
                return ("done", done.value)
            except BaseException as err:  # noqa: BLE001 — owned by handle
                return ("err", err)

        # Step-pool width: in-flight plans, the concurrency cap, and the
        # machine (stepping 8 emission passes on 2 cores just thrashes).
        # Thread count never changes outputs — steps land in their slots.
        workers = min(len(self._active),
                      self.concurrency or len(self._active),
                      os.cpu_count() or 1)
        outcomes = pipeline.parallel_map(step, self._active, workers)

        survivors: List[List] = []
        requests: List[Tuple[List, OracleRequest]] = []
        for slot, (kind, value) in zip(self._active, outcomes):
            handle = slot[0]
            if kind == "done":
                handle._result, handle._done = value, True
            elif kind == "err":
                handle._error, handle._done = value, True
            else:
                requests.append((slot, value))
                survivors.append(slot)
        # Commit the new round state *before* touching the channel: both
        # submit (whose max_batch auto-drain can run fn) and the explicit
        # drain may blow up on a broken oracle, and when they do, finished
        # plans must already be gone from _active and every surviving slot
        # must still get a definitive inbox below — never a stale one that
        # would silently resume its plan with the previous round's payload.
        self._active = survivors
        pending: List[Tuple[List, object]] = []
        drain_err: Optional[BaseException] = None
        try:
            for slot, req in requests:
                pending.append((slot, self.client.submit(
                    req.indices, ledger=req.ledger)))
            self.client.drain()
        except BaseException as err:  # noqa: BLE001 — surfaced below
            drain_err = err
        for slot, ticket in pending:
            try:
                # A poisoned drain marks every popped ticket with its
                # error, so this resolves to labels or to the exception
                # that the next round will throw into the plan.
                slot[2] = ticket.result()
            except BaseException as err:  # noqa: BLE001 — rethrown in plan
                slot[2] = err
        if drain_err is not None:
            submitted = {id(slot) for slot, _ in pending}
            for slot, _ in requests:
                if id(slot) not in submitted:
                    slot[2] = drain_err    # failed before this submit ran
            raise drain_err

    # -- lifecycle --------------------------------------------------------

    def close(self, abandon: bool = False) -> None:
        """Finish the session: pump every submitted query to completion
        (unless `abandon`), then reject stragglers and close their plans."""
        if self._closed:
            return
        if not abandon:
            self._pump()
        self._closed = True
        leftovers = self._queued + [(s[0], s[1]) for s in self._active]
        self._queued, self._active = [], []
        for handle, plan in leftovers:
            plan.close()
            if not handle._done:
                handle._error = RuntimeError("QuerySession abandoned")
                handle._done = True

    def __enter__(self) -> "QuerySession":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close(abandon=exc_type is not None)
        return False
