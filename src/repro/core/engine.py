"""Distributed SUPG selection engine — the production query executor.

The engine is a *precomputation-cached, vectorized, sketch-driven* data
plane: all O(n) work happens once at construction, after which any number of
RT / PT / JT queries are served off cached per-shard state.

Construction (one chunked pass over the shards, ChunkPlan-driven):

  1. per-chunk `binned.chunk_sketch_stats` — the fused Pallas score_hist
     sketch (compiled on TPU, interpret-mode on CPU; jnp fallback for
     non-tile-aligned bin counts) plus the chunk's float64 raw sampling
     masses (Σ sqrt(A), Σ A) in the same pass — merged into per-shard and
     global sketches (one psum of 48 KiB on a fleet),
  2. hierarchical sampling state: the per-chunk raw masses are the *only*
     persistent per-data sampling state — O(n / chunk_records) floats per
     (shard, scheme), never per-record arrays. Per (scheme, kappa) the
     engine caches the per-shard chunk-mass CDFs (a chunk's defensive mass
     is (1-kappa)·Σraw/Z + kappa·|chunk|/n, from the cached sums alone);
     the normalizers (Z_sqrt, Z_prop, n) come from
     `binned.weight_normalizers` on the merged sketch,
  3. shard-level sampling masses for the (shard → chunk → record) draw are
     the per-shard sums of those chunk masses.

Every chunked walk — sketch construction, selection emission, the PT
stage-2 region draw, and query-time chunk-draw resolution — iterates the
same `data.pipeline.ChunkPlan` and runs through the engine's persistent
`pipeline.WorkerPool`: with `workers > 1` the long-lived pool drives the
spans concurrently (memmap reads, the numpy threshold_select path and the
float64 chunk reductions all release the GIL), with results written to
preassigned slots so thread count never changes any output bit. The pool
is built once per engine (thread spin-up is not paid per walk), sized to
at most `os.cpu_count()` (requesting more is oversubscription — the clamp
is logged once; `clamp_workers=False` opts out for tests that need real
thread interleaving on small machines), and released by `engine.close()`
or the engine's context manager. Sinks carry the matching thread-safety
contract (`SelectionSink` docstring).

Query execution (zero O(n) *state* per query):

  * `draw_sample`   — multinomial over cached shard masses, then an
                      inverse-CDF draw over the cached chunk-mass CDF, then
                      an exact within-chunk inverse-CDF draw over freshly
                      computed weights streaming *only the allocated
                      chunks*; chunk mass × within-chunk p reproduces the
                      defensive-mixture p(x) exactly, so the m(x) factors
                      are globally correct with O(chunk) transient memory,
  * `score_at`      — `np.searchsorted` shard routing + per-shard fancy
                      gathers (no per-element Python loop),
  * tau estimation  — the exact sample-level estimators (Algorithms 2-5;
                      the sample is tiny, so estimation is never distributed),
  * D' restriction  — rank → conservative bin edge through the sketch
                      (superset property),
  * selection       — *streamed*, never materialized: each shard is walked
                      in fixed-size chunks through the fused
                      `kernels/threshold_select` pass (compare + count +
                      index compaction; compiled on TPU, numpy nonzero
                      reference off-TPU) and the selected indices are
                      emitted into a `data.pipeline.SelectionSink`
                      (in-memory `IndexSink` by default, memmap
                      `BitmaskStore` for out-of-core output, `CallbackSink`
                      / `SelectionStream` for service streaming). Labeled
                      positives (Algorithm 1's R1) are folded in as a
                      sink-level merge of the positives *below* tau, so
                      emission and folding stay disjoint and per-shard
                      counts are exact without dedup state.

A query over a 1e8-record memmap store therefore peaks at O(chunk) host
memory *for every method, importance-weighted included*: no full-corpus
boolean mask or per-record CDF is ever allocated, `ShardedSelection` is a
lazy view whose `total_selected` comes from per-shard counts, boolean masks
only materialize if a caller explicitly asks for them, and the PT stage-2
uniform-in-D' draw is rank-routed through the same chunked pass. The former
O(n) surface — dense per-record inverse-CDF state behind `method="is"` —
is gone: persistent sampling state is ≤ n / chunk_records entries per
(shard, scheme) and record-level draws stream only their allocated chunks,
so the `weight_schemes=()` escape hatch is no longer needed (the argument
is kept as a cache pre-warm hint).

Multi-query execution is built on *resumable query plans* and a shared
labeling channel. The bodies of `run`/`run_joint` are generators
(`_run_plan` / `_run_joint_plan`) that *yield* `OracleRequest`s wherever
the old bodies called the oracle inline, and yield a `pipeline.ChunkWalk`
for their selection-emission pass; everything between two yields is pure
compute off the cached state. A single query drives its plan through a
trivial trampoline (submit → drain → resume, walks run on the engine
pool). `SelectionEngine.session()` returns a `QuerySession` scheduling N
plans concurrently with *double-buffered rounds*: in-flight plans are
split into two cohorts, A and B, and the scheduler alternates turns —
while cohort A's coalesced oracle drain is in flight on the channel's
dedicated drain thread (`BatchingOracle.drain_async`), cohort B's pure
plan steps (sampling, tau estimation, emission, `_uniform_in_region`
walks) already run on the engine's worker pool::

    driver   | step A₀ | step B₀ | step A₁ | step B₁ | step A₂ | ...
    channel  |         |·drain A₀·|·drain B₀·|·drain A₁·|·drain B₁·|

so oracle I/O and compute overlap instead of strictly alternating — the
"expensive predicate is the scarce resource, everything else must overlap
it" posture of the paper's rate-limited oracle model. All `ChunkWalk`s a
cohort yields in one turn are fused into a single span list
(`ChunkPlan.fuse`): eight concurrent queries' emission passes touch each
shard chunk once, not eight times. At most one drain is ever in flight,
a cohort is only stepped after its previous drain's tickets resolved, and
the scheduler commits round state before any channel call — so results
(tau / counts / sink contents) stay bit-for-bit equal to the sequential
path at any worker count and overlap depth; a pure oracle answers
identically regardless of batching, and only the per-query `oracle_calls`
*attribution* can shift with concurrency. The session coalesces the
expensive oracle across queries — one `fn` micro-batch can serve every
in-flight query — while per-query `BudgetLedger` views keep ORACLE LIMIT
enforcement per query (see `core/oracle.py` for the shared-cache budget
semantics). Per-session overlap accounting lands in `SessionStats`
(drain in-flight time vs driver wait time, fused vs raw span counts).

`run_many` is a thin wrapper over a session (`concurrency=` knob) serving a
*batch* of queries — SUPGQuery (RT/PT) and JointSUPGQuery (JT, Appendix A) —
amortizing the sketch, the cached sampling state, *and the oracle channel*
across the whole batch; this is the serving-plane entry point. Per-query
sinks make it the streaming fan-out point for a service. Because plans are
pure given (key, labels) and a pure oracle answers identically regardless
of batching, `run_many` output (tau, counts, sink contents) is bit-for-bit
identical at any `concurrency`; only the per-query `oracle_calls`
*attribution* can shift when queries overlap (the shared cache answers
later queries for free).

Shards are host-local float32 arrays: plain np.ndarray, np.memmap, or
`data.pipeline.ScoreStore` objects (consumed zero-copy through `.scores`, so
out-of-core corpora work end-to-end; sketch construction over shards larger
than `chunk_records` is itself chunked and merged, so even engine build never
materializes a full shard). On a real fleet each worker holds its shard and
the driver runs where the coordinator lives; the collective math matches
core/distributed.py.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import threading
import time
from typing import (Dict, Generator, List, Optional, Sequence, Tuple,
                    Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binned, sampling, thresholds
from repro.core.oracle import (BudgetLedger, DrainHandle, OracleClient,
                               OracleRequest, as_oracle_client)
from repro.core.queries import JointSUPGQuery, SUPGQuery
from repro.data import pipeline
from repro.kernels.threshold_select import ops as select_ops

logger = logging.getLogger(__name__)

_clamp_logged = False


def _effective_workers(requested: Optional[int], clamp: bool) -> int:
    """Resolve the engine's pool width. Requesting more threads than the
    machine has cores is pure oversubscription for these GIL-releasing
    numpy walks (contended cores run *slower* — see the w8 < w4 cold-build
    regression in BENCH_PR4), so the default clamps to `os.cpu_count()`
    and logs once. `clamp=False` keeps the literal request — tests that
    exercise real thread interleaving on small machines need it."""
    global _clamp_logged
    workers = max(1, int(requested)) if requested else 1
    if not clamp:
        return workers
    cpus = os.cpu_count() or 1
    if workers > cpus:
        if not _clamp_logged:
            logger.info(
                "clamping engine workers=%d to cpu_count=%d "
                "(oversubscribing GIL-releasing chunk walks is a slowdown; "
                "pass clamp_workers=False to override)", workers, cpus)
            _clamp_logged = True
        return cpus
    return workers


def _close_quietly(sink: "pipeline.SelectionSink") -> None:
    """Best-effort close on an error path: the sink must come back
    reusable (the double-open guard would otherwise wedge it), but the
    original exception owns the outcome — a close failure is secondary."""
    try:
        sink.close()
    except Exception:  # noqa: BLE001 — error path; original exc wins
        pass


class ShardedSelection:
    """Lazy view over one query's selection.

    Sink-backed (the engine's streaming output) or mask-backed (direct
    construction, kept for compatibility). In the sink-backed form nothing
    O(corpus) lives here: `total_selected` and `shard_counts` come from the
    per-shard counts the sink accumulated during emission, `indices(shard)`
    reads the sink, and `masks` materializes per-shard boolean views only
    when explicitly accessed (state-holding sinks only — a CallbackSink
    selection retains counts alone).
    """

    def __init__(self, masks: Optional[List[np.ndarray]] = None,
                 tau: float = 0.0, oracle_calls: int = 0,
                 sampled_positive_global: Optional[np.ndarray] = None,
                 sink: Optional[pipeline.SelectionSink] = None,
                 shard_sizes: Optional[Sequence[int]] = None,
                 counts: Optional[np.ndarray] = None):
        if masks is None and sink is None:
            raise ValueError("need per-shard masks or a SelectionSink")
        self.tau = float(tau)
        self.oracle_calls = int(oracle_calls)
        self.sampled_positive_global = (
            np.empty(0, np.int64) if sampled_positive_global is None
            else np.asarray(sampled_positive_global, np.int64))
        self.sink = sink
        self._masks = list(masks) if masks is not None else None
        if shard_sizes is None:
            if self._masks is not None:
                shard_sizes = [int(m.shape[0]) for m in self._masks]
            elif getattr(sink, "shard_sizes", None) is not None:
                shard_sizes = sink.shard_sizes   # an opened sink knows them
            else:
                raise ValueError(
                    "shard_sizes required when the sink has not been opened")
        self.shard_sizes = [int(n) for n in shard_sizes]
        self._counts = (None if counts is None
                        else np.asarray(counts, np.int64))

    @property
    def num_shards(self) -> int:
        """Number of score shards this selection spans."""
        return len(self.shard_sizes)

    @property
    def shard_counts(self) -> np.ndarray:
        """Per-shard selected counts (no mask materialization needed)."""
        if self._counts is not None:
            return self._counts.copy()
        return np.asarray([int(m.sum()) for m in self.masks], np.int64)

    @property
    def total_selected(self) -> int:
        """Total selected records (from counts — no mask materialization)."""
        if self._counts is not None:
            return int(self._counts.sum())
        return int(sum(int(m.sum()) for m in self.masks))

    def indices(self, shard_id: int) -> np.ndarray:
        """Sorted shard-local selected indices for one shard."""
        if self._masks is not None:
            return np.nonzero(self._masks[shard_id])[0].astype(np.int64)
        return np.asarray(self.sink.indices(shard_id), np.int64)

    @property
    def masks(self) -> List[np.ndarray]:
        """Per-shard boolean masks, materialized lazily from the sink.

        Allocates O(corpus) booleans — for large stores prefer
        `shard_counts` / `indices` / the sink itself.
        """
        if self._masks is None:
            self._masks = [self.sink.mask(i)
                           for i in range(self.num_shards)]
        return self._masks


@dataclasses.dataclass
class _ShardChunkState:
    """Cached per-shard hierarchical draw state for one (scheme, kappa):
    the shard's total defensive mass and its normalized chunk-mass CDF —
    O(n_chunks) persistent floats, never per-record arrays."""
    mass: float            # shard total defensive mass (unnormalized)
    cdf: np.ndarray        # (n_chunks,) float64 normalized chunk-mass CDF


@dataclasses.dataclass
class CorpusState:
    """One immutable corpus *epoch*: every piece of engine state an append
    replaces as a unit.

    The live plane (`repro.live`) grows the corpus by building a new
    `CorpusState` from the current one plus the appended shards and
    installing it with a single attribute assignment — old snapshots stay
    fully valid (shard arrays are never mutated, only the lists are
    extended into fresh objects), so an in-flight plan that pinned its
    epoch at the first step keeps computing against a frozen, consistent
    corpus no matter how many appends land meanwhile. Results over a
    pinned epoch are bit-for-bit what a cold engine build over exactly
    that corpus would produce.
    """

    epoch: int                          # 0 at construction, +1 per append
    shards: List[np.ndarray]            # score shards (views, never copies)
    offsets: np.ndarray                 # (n_shards+1,) int64 global offsets
    n_total: int                        # total records this epoch
    plan: pipeline.ChunkPlan            # the epoch's canonical chunk plan
    shard_sketches: List                # per-shard binned.ScoreSketch
    sketch: object                      # global merged ScoreSketch
    chunk_masses: List[sampling.ChunkMasses]   # per-shard raw chunk masses
    z: Dict[str, float]                 # global weight normalizers
    flat: Optional[np.ndarray]          # score_at gather cache (or None)
    sampling_cache: Dict[Tuple[str, float],
                         List[_ShardChunkState]] = dataclasses.field(
                             default_factory=dict)
    pins: int = 0                       # live references (engine._gc_lock)


class SelectionEngine:
    """Executes batches of SUPG queries over a list of score shards.

    Construction pays all O(n) work once (sketch + hierarchical sampling
    state, see the module docstring); queries then run off the cache.
    Use as a context manager so the engine's worker pool is released:

    >>> import numpy as np
    >>> from repro.core.queries import SUPGQuery
    >>> scores = np.linspace(0.0, 1.0, 512, dtype=np.float32)
    >>> labels = (scores > 0.75).astype(np.float32)
    >>> q = SUPGQuery(target="recall", gamma=0.9, delta=0.1,
    ...               budget=128, method="is")
    >>> with SelectionEngine([scores[:256], scores[256:]], num_bins=32,
    ...                      use_kernel=False) as eng:
    ...     sel = eng.run(None, lambda idx: labels[idx], q)
    ...     bool(0.0 <= sel.tau <= 1.0), sel.total_selected > 0
    (True, True)
    """

    def __init__(self, shards: Sequence, num_bins: int = 4096,
                 use_kernel: Optional[bool] = None,
                 weight_schemes: Sequence[str] = ("sqrt",),
                 kappa: float = sampling.DEFENSIVE_KAPPA,
                 cache_flat: Optional[bool] = None,
                 select_backend: Optional[str] = None,
                 chunk_records: Optional[int] = None,
                 workers: Optional[int] = None,
                 clamp_workers: bool = True):
        # ScoreStore (or anything exposing `.scores`) passes its memmap
        # through untouched; ndarray shards are viewed, not copied.
        raw_shards = [getattr(s, "scores", s) for s in shards]
        # Flat gather cache: for in-RAM shards a one-time concatenation
        # turns score_at into a single fancy gather. Defaults off for
        # memmap-backed (out-of-core) shards, which keep the routed path.
        # (Decide on the raw objects: np.asarray strips the memmap subclass.)
        if cache_flat is None:
            cache_flat = not any(isinstance(s, np.memmap)
                                 for s in raw_shards)
        arrs = [np.asarray(s) for s in raw_shards]
        self.num_bins = num_bins
        self.kappa = float(kappa)
        # Streaming emission knobs: chunk_records bounds per-query peak
        # memory; select_backend picks the threshold_select path (compiled
        # Pallas on TPU, numpy reference elsewhere by default — interpret
        # emulation stays available for kernel validation).
        self.chunk_records = int(chunk_records or pipeline.CHUNK_RECORDS)
        self.select_backend = (select_ops.default_backend()
                               if select_backend is None else select_backend)
        # One persistent pool per engine: thread spin-up is paid at most
        # once (lazily, on the first threaded walk), not per chunk walk.
        self.workers = _effective_workers(workers, clamp_workers)
        self.pool = pipeline.WorkerPool(self.workers)
        # Appends (the live plane's `_append_shards`) sketch under this
        # lock and publish their new CorpusState with one assignment.
        self._use_kernel = use_kernel
        self._ingest_lock = threading.Lock()
        # Epoch refcounting: `pin`/`unpin` count live references under
        # this lock; superseded epochs queue here until `gc_epochs` frees
        # the ones no plan still pins.
        self._gc_lock = threading.Lock()
        self._superseded: List[CorpusState] = []
        self.epochs_freed = 0
        plan = pipeline.ChunkPlan([int(s.shape[0]) for s in arrs],
                                  self.chunk_records)
        flat = (np.concatenate([np.asarray(s, np.float32) for s in arrs])
                if cache_flat and arrs else None)

        # 1. chunked construction pass (ChunkPlan-driven, threaded): each
        #    span yields its ScoreSketch *and* its raw sampling masses in
        #    one touch of the data. Sketches merge additively into
        #    per-shard and global sketches, so even memmap shards never
        #    materialize whole; the per-chunk masses become the persistent
        #    O(n / chunk_records) hierarchical sampling state. The same
        #    pass, restricted to appended shards only, is how the live
        #    plane extends an epoch (`_append_shards`).
        shard_sketches, chunk_masses = self._sketch_shards(
            arrs, plan, 0, use_kernel)
        sketch = binned.merge_sketches(*shard_sketches)

        # 2. global weight normalizers from the merged sketch — the only
        #    cross-shard reductions sampling ever needs.
        z_sqrt, z_prop, _ = binned.weight_normalizers(sketch)

        offsets = np.concatenate(
            [[0], np.cumsum([s.shape[0] for s in arrs])]).astype(np.int64)
        self._state = CorpusState(
            epoch=0, shards=arrs, offsets=offsets,
            n_total=int(offsets[-1]), plan=plan,
            shard_sketches=shard_sketches, sketch=sketch,
            chunk_masses=chunk_masses,
            z={"sqrt": float(z_sqrt), "prop": float(z_prop)}, flat=flat)

        # 3. chunk-mass CDFs per (scheme, kappa) — O(n_chunks) each.
        #    `weight_schemes` is a pre-warm hint only: since the dense
        #    per-record CDFs are gone, every scheme is bounded-memory and
        #    un-warmed schemes build lazily on first use.
        for scheme in weight_schemes:
            self._sampling_state(scheme, self.kappa)

    def _sketch_shards(self, shards: List[np.ndarray],
                       plan: pipeline.ChunkPlan, first_shard: int,
                       use_kernel: Optional[bool]):
        """Chunked sketch + raw-mass pass over ``shards[first_shard:]``.

        Returns (per-shard sketches, per-shard ChunkMasses) for exactly
        those shards. The construction pass calls this with
        ``first_shard=0``; `_append_shards` calls it with the old shard
        count so only appended data is ever touched — and because both
        paths share this one implementation (same span order, same
        per-chunk `chunk_sketch_stats`, same merge fold), the delta path's
        per-shard results are bit-for-bit the cold build's.
        """
        spans = [sp for sp in plan if sp.shard_id >= first_shard]
        stats = self.pool.map(
            lambda sp: binned.chunk_sketch_stats(
                shards[sp.shard_id][sp.start:sp.stop], self.num_bins,
                use_kernel=use_kernel),
            spans)
        k = len(shards) - first_shard
        parts: List[List] = [[] for _ in range(k)]
        sums: List[List[Tuple[float, float, int]]] = [[] for _ in range(k)]
        for sp, (sk, s_sqrt, s_a) in zip(spans, stats):
            parts[sp.shard_id - first_shard].append(sk)
            sums[sp.shard_id - first_shard].append((s_sqrt, s_a, sp.size))
        # Empty shards get an all-zero sketch via the jnp path (the kernel
        # grid cannot span a zero-length operand).
        sketches = [
            binned.merge_sketches(*p) if p else
            binned.build_sketch(jnp.zeros((0,), jnp.float32), self.num_bins,
                                use_kernel=False)
            for p in parts]
        masses = [
            sampling.ChunkMasses(
                np.asarray([t[0] for t in ss], np.float64),
                np.asarray([t[1] for t in ss], np.float64),
                np.asarray([t[2] for t in ss], np.int64))
            if ss else sampling.ChunkMasses.empty()
            for ss in sums]
        return sketches, masses

    # -- lifecycle ------------------------------------------------------

    def close(self) -> None:
        """Release the engine's worker pool (joins its threads).
        Idempotent. A closed engine still serves `workers == 1` queries
        (the inline fast path owns no threads)."""
        self.pool.close()

    def __enter__(self) -> "SelectionEngine":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- cached state (epoch snapshots) ---------------------------------

    def pin(self) -> CorpusState:
        """Snapshot the current corpus epoch.

        Pass the returned `CorpusState` to `draw_sample` / `score_at` /
        `QuerySession.submit(state=...)` to keep a multi-step computation
        on one frozen, consistent corpus while `repro.live` appends land
        concurrently. Counts as a live reference: call `unpin` when the
        computation finishes so `gc_epochs` can free superseded epochs."""
        with self._gc_lock:
            st = self._state
            st.pins += 1
            return st

    def unpin(self, state: CorpusState) -> None:
        """Release a reference taken by `pin`. Unbalanced unpins raise."""
        with self._gc_lock:
            if state.pins <= 0:
                raise ValueError(
                    f"unpin of epoch {state.epoch} with no live pins")
            state.pins -= 1

    def gc_epochs(self) -> int:
        """Free superseded epochs with no live pins; returns the count.

        Frees each dead epoch's *per-epoch* host memory — the O(n) flat
        gather cache, the chunk-mass CDFs, the sketch and plan objects —
        by dropping the references. Shard arrays themselves are shared
        across epochs (appends extend the list, never copy members), so
        they stay alive exactly as long as any live epoch includes them.
        Called from `SelectionServer.snapshot()`; safe to call anytime.
        """
        with self._gc_lock:
            live = [st for st in self._superseded if st.pins > 0]
            dead = [st for st in self._superseded if st.pins <= 0]
            self._superseded = live
            self.epochs_freed += len(dead)
        for st in dead:
            st.shards = []
            st.shard_sketches = []
            st.chunk_masses = []
            st.sampling_cache = {}
            st.sketch = None
            st.flat = None
            st.plan = None
        return len(dead)

    @property
    def epochs_live(self) -> int:
        """Epochs still holding host memory: current + unfreed superseded."""
        with self._gc_lock:
            return 1 + len(self._superseded)

    @property
    def epoch(self) -> int:
        """Current corpus epoch: 0 at construction, +1 per append."""
        return self._state.epoch

    @property
    def shards(self) -> List[np.ndarray]:
        """Score shards of the current epoch (views, never copies)."""
        return self._state.shards

    @property
    def offsets(self) -> np.ndarray:
        """(n_shards+1,) int64 global record offsets, current epoch."""
        return self._state.offsets

    @property
    def n_total(self) -> int:
        """Total records in the current epoch."""
        return self._state.n_total

    @property
    def plan(self) -> pipeline.ChunkPlan:
        """The current epoch's canonical ChunkPlan."""
        return self._state.plan

    @property
    def sketch(self):
        """Global merged ScoreSketch of the current epoch."""
        return self._state.sketch

    @property
    def shard_sketches(self) -> List:
        """Per-shard ScoreSketches of the current epoch."""
        return self._state.shard_sketches

    @property
    def _chunk_masses(self) -> List[sampling.ChunkMasses]:
        return self._state.chunk_masses

    @property
    def _z(self) -> Dict[str, float]:
        return self._state.z

    @property
    def _flat(self) -> Optional[np.ndarray]:
        return self._state.flat

    @property
    def _sampling_cache(self) -> Dict[Tuple[str, float],
                                      List[_ShardChunkState]]:
        return self._state.sampling_cache

    def _append_shards(self, shards: Sequence,
                       use_kernel: Optional[bool] = None) -> CorpusState:
        """Extend the corpus by `shards`, delta-updating engine state.

        The incremental-ingestion core (`repro.live.IngestPlane` is the
        public face): sketch *only* the appended shards via the shared
        `_sketch_shards` pass, fold them into the global sketch
        (`merge_sketches` is a left fold starting at 0, so folding the new
        per-shard sketches onto the old global reproduces the cold fold
        bit-for-bit), refresh the normalizers, rebuild the O(n_chunks)
        per-(scheme, kappa) CDFs for every cached scheme (Z and n change
        on every append, but the rebuild reads only cached chunk masses —
        no old data is re-walked), and install the new `CorpusState`
        atomically. Existing epochs pinned by in-flight plans stay valid.
        Returns the new state.
        """
        raw_new = [getattr(s, "scores", s) for s in shards]
        arrs = [np.asarray(s) for s in raw_new]
        kernel = self._use_kernel if use_kernel is None else use_kernel
        with self._ingest_lock:
            st = self._state
            all_shards = st.shards + arrs
            sizes = [int(s.shape[0]) for s in all_shards]
            plan = pipeline.ChunkPlan(sizes, self.chunk_records)
            new_sketches, new_masses = self._sketch_shards(
                all_shards, plan, len(st.shards), kernel)
            sketch = (binned.merge_sketches(st.sketch, *new_sketches)
                      if new_sketches else st.sketch)
            z_sqrt, z_prop, _ = binned.weight_normalizers(sketch)
            offsets = np.concatenate(
                [[0], np.cumsum(sizes)]).astype(np.int64)
            if st.flat is None or any(isinstance(s, np.memmap)
                                      for s in raw_new):
                flat = None     # out-of-core data keeps the routed path
            elif arrs:
                flat = np.concatenate(
                    [st.flat] + [np.asarray(a, np.float32) for a in arrs])
            else:
                flat = st.flat
            new_state = CorpusState(
                epoch=st.epoch + 1, shards=all_shards, offsets=offsets,
                n_total=int(offsets[-1]), plan=plan,
                shard_sketches=st.shard_sketches + new_sketches,
                sketch=sketch, chunk_masses=st.chunk_masses + new_masses,
                z={"sqrt": float(z_sqrt), "prop": float(z_prop)},
                flat=flat)
            # Pre-warm every (scheme, kappa) the outgoing epoch served so
            # the first post-append query pays no lazy build.
            for scheme, kappa in list(st.sampling_cache):
                self._sampling_state(scheme, kappa, state=new_state)
            # Install under the GC lock so pin() never races the swap,
            # and queue the outgoing epoch for gc_epochs().
            with self._gc_lock:
                self._superseded.append(st)
                self._state = new_state
            return new_state

    def _sampling_state(self, scheme: str, kappa: float,
                        state: Optional[CorpusState] = None) \
            -> List[_ShardChunkState]:
        st = self._state if state is None else state
        cache_key = (scheme, float(kappa))
        if cache_key not in st.sampling_cache:
            states = []
            for cm in st.chunk_masses:
                if cm.sizes.size == 0:   # empty shard: zero mass, no draws
                    states.append(_ShardChunkState(
                        mass=0.0, cdf=np.empty(0, np.float64)))
                    continue
                total, cdf = sampling.chunk_mass_cdf(
                    cm.raw(scheme), cm.sizes, st.z[scheme], kappa,
                    st.n_total)
                states.append(_ShardChunkState(mass=total, cdf=cdf))
            st.sampling_cache[cache_key] = states
        return st.sampling_cache[cache_key]

    def _shard_masses(self, scheme: str, kappa: float,
                      state: Optional[CorpusState] = None) -> np.ndarray:
        states = self._sampling_state(scheme, kappa, state=state)
        mass = np.asarray([st.mass for st in states], np.float64)
        return mass / mass.sum()

    # -- sampling -------------------------------------------------------

    @staticmethod
    def _group_sorted(values: np.ndarray, order: np.ndarray):
        """Split `order` (an argsort of `values`) into runs of equal value.

        Yields (value, positions) — the argsort-grouping trick `score_at`
        uses, so grouping s draws over k groups costs one sort instead of
        k boolean mask scans.
        """
        if order.size == 0:
            return
        sorted_vals = values[order]
        cuts = np.flatnonzero(np.diff(sorted_vals)) + 1
        for grp in np.split(order, cuts):
            yield int(values[grp[0]]), grp

    def draw_sample(self, key, s: int, scheme: str = "sqrt",
                    kappa: Optional[float] = None,
                    state: Optional[CorpusState] = None):
        """Global with-replacement draws; returns (global_idx, m).

        Hierarchical (shard → chunk → record): multinomial over cached
        shard masses, inverse-CDF over each shard's cached chunk-mass CDF,
        then an exact within-chunk inverse-CDF draw over freshly computed
        p(x) — only the allocated chunks are ever streamed, so transient
        memory is O(chunk) and persistent state O(n_chunks). The joint
        draw probability telescopes to the global defensive-mixed p(x)
        (shard mass = Σ chunk masses, chunk mass = Σ p(x) over the chunk),
        so m(x) = (1/n) / p(x) is globally correct. Draws are grouped by
        shard and chunk with argsorts (no per-shard mask scans) and chunk
        resolution runs through the worker pool; outputs land in
        preassigned slots, so results are identical at any worker count.
        `state` pins a specific corpus epoch (default: current).
        """
        st = self._state if state is None else state
        if scheme == "uniform":
            idx = jax.random.randint(key, (s,), 0, st.n_total)
            return np.asarray(idx, np.int64), np.ones(s, np.float32)
        kappa = self.kappa if kappa is None else kappa
        states = self._sampling_state(scheme, kappa, state=st)
        mass = self._shard_masses(scheme, kappa, state=st)
        k_alloc, k_chunk, k_rec = jax.random.split(key, 3)
        alloc = np.asarray(jax.random.categorical(
            k_alloc, jnp.log(jnp.asarray(mass, jnp.float32)), shape=(s,)))
        u_chunk = np.asarray(jax.random.uniform(k_chunk, (s,)), np.float64)
        u_rec = np.asarray(jax.random.uniform(k_rec, (s,)), np.float64)
        out_idx = np.empty(s, np.int64)
        out_m = np.empty(s, np.float32)
        work = []    # (shard_id, chunk_id, draw positions into [0, s))
        for sh, seg in self._group_sorted(alloc,
                                          np.argsort(alloc, kind="stable")):
            chunk_ids = sampling.draw_from_cdf(states[sh].cdf, u_chunk[seg])
            for ci, grp in self._group_sorted(
                    chunk_ids, np.argsort(chunk_ids, kind="stable")):
                work.append((sh, ci, seg[grp]))

        chunk = st.plan.chunk_records

        def resolve(item):
            sh, ci, pos = item
            start = ci * chunk
            p = sampling.defensive_probs(
                st.shards[sh][start:start + chunk], scheme,
                st.z[scheme], kappa, st.n_total)
            local = sampling.draw_from_cdf(sampling.normalized_cdf(p),
                                           u_rec[pos])
            out_idx[pos] = st.offsets[sh] + start + local
            out_m[pos] = (1.0 / st.n_total) / np.maximum(
                p[local], 1e-38)

        self.pool.map(resolve, work)
        return out_idx, out_m

    def score_at(self, global_idx,
                 state: Optional[CorpusState] = None) -> np.ndarray:
        """Vectorized gather: one flat fancy gather when the concatenation
        cache is live, else searchsorted shard routing + per-shard fancy
        indexing (works unchanged on memmap shards). `state` pins a
        specific corpus epoch (default: current)."""
        st = self._state if state is None else state
        gi = np.asarray(global_idx, np.int64)
        if st.flat is not None:
            return st.flat[gi]
        sh = np.searchsorted(st.offsets, gi, side="right") - 1
        local = gi - st.offsets[sh]
        out = np.empty(gi.shape[0], np.float32)
        # Group draws by shard with one argsort, then gather each shard's
        # segment with a single fancy index (one touch per shard).
        order = np.argsort(sh, kind="stable")
        seg_bounds = np.searchsorted(sh[order],
                                     np.arange(len(st.shards) + 1))
        for shard_id in range(len(st.shards)):
            seg = order[seg_bounds[shard_id]:seg_bounds[shard_id + 1]]
            if seg.size:
                out[seg] = np.asarray(
                    st.shards[shard_id][local[seg]], np.float32)
        return out

    # -- query plans ------------------------------------------------------

    def _run_plan(self, key, query: SUPGQuery, *,
                  sink: Optional[pipeline.SelectionSink] = None,
                  chunk_records: Optional[int] = None,
                  ledger_parent: Optional[BudgetLedger] = None,
                  state: Optional[CorpusState] = None) \
            -> Generator[object, Optional[np.ndarray], ShardedSelection]:
        """Resumable plan for one RT/PT query.

        Yields `OracleRequest`s wherever the old body called the oracle
        inline and receives the label array back at the same point, and
        yields one `pipeline.ChunkWalk` for the selection-emission pass
        (resumed with None once its spans have run — a scheduler fuses
        all in-flight plans' walks into one pass; `_drive_plan` runs it
        directly). Everything between yields is pure compute off the
        cached state, so a scheduler may interleave any number of plans
        and answer their requests from one coalesced labeling channel.
        `ledger_parent` chains the query's budget ledger under a coarser
        shared ledger (the serving plane's per-tenant quota) — see
        `core.oracle.BudgetLedger`. The plan pins one `CorpusState` at
        its first step (`state` overrides which) and computes against
        that frozen epoch end to end, so live-plane appends landing
        mid-plan can never mix corpora. A plan that pins for itself
        unpins on exit (normal return, error, or abandonment) so
        `gc_epochs` can free the epoch; a caller passing `state=` owns
        that pin. Returns the ShardedSelection via StopIteration.value.
        """
        st = self.pin() if state is None else state
        try:
            result = yield from self._run_plan_pinned(
                key, query, sink=sink, chunk_records=chunk_records,
                ledger_parent=ledger_parent, st=st)
            return result
        finally:
            if state is None:
                self.unpin(st)

    def _run_plan_pinned(self, key, query: SUPGQuery, *,
                         sink: Optional[pipeline.SelectionSink] = None,
                         chunk_records: Optional[int] = None,
                         ledger_parent: Optional[BudgetLedger] = None,
                         st: CorpusState) \
            -> Generator[object, Optional[np.ndarray], ShardedSelection]:
        key = jax.random.PRNGKey(0) if key is None else key
        ledger = BudgetLedger(query.budget, parent=ledger_parent)
        s = query.budget
        if query.target == "recall":
            scheme = {"is": query.weight_scheme, "uniform": "uniform",
                      "noci": "uniform"}[query.method]
            idx, m = self.draw_sample(key, s, scheme, state=st)
            o_s = yield OracleRequest(idx, ledger)
            a_s = self.score_at(idx, state=st)
            if query.method == "noci":
                res = thresholds.tau_unoci_r(a_s, o_s, query.gamma)
            else:
                res = thresholds.tau_ci_r(a_s, o_s, m, query.gamma,
                                          query.delta)
            tau = float(res.tau)
        else:
            k0, k1 = jax.random.split(key)
            if query.method == "is" and query.two_stage:
                idx0, m0 = self.draw_sample(k0, s // 2,
                                            query.weight_scheme, state=st)
                o0 = yield OracleRequest(idx0, ledger)
                _, rank = thresholds.pt_stage1_nmatch(
                    o0, m0, st.n_total, query.gamma, query.delta)
                tau_dp = float(binned.rank_to_threshold(st.sketch,
                                                        int(rank)))
                # stage 2: uniform on D' via per-shard masked draws
                idx1 = self._uniform_in_region(k1, s - s // 2, tau_dp,
                                               state=st)
                o1 = yield OracleRequest(idx1, ledger)
                a1 = self.score_at(idx1, state=st)
                res = thresholds.tau_ci_p(a1, o1, query.gamma,
                                          query.delta / 2.0,
                                          min_step=query.min_step)
            else:
                scheme = ("uniform" if query.method in ("uniform", "noci")
                          else query.weight_scheme)
                idx, m = self.draw_sample(k0, s, scheme, state=st)
                o_s = yield OracleRequest(idx, ledger)
                a_s = self.score_at(idx, state=st)
                if query.method == "noci":
                    res = thresholds.tau_unoci_p(a_s, o_s, query.gamma)
                else:
                    res = thresholds.tau_ci_p(
                        a_s, o_s, query.gamma, query.delta,
                        m_s=None if scheme == "uniform" else m,
                        min_step=query.min_step)
            tau = float(res.tau)

        pos = ledger.labeled_positives()
        walk, out_sink, finish = self._emission_walk(tau, pos, sink,
                                                     chunk_records,
                                                     state=st)
        try:
            yield walk
        except BaseException:
            # Emission died (a CallbackSink consumer raised, the walk was
            # poisoned, or the plan was abandoned at this yield): release
            # the sink so sequential reuse still works.
            _close_quietly(out_sink)
            raise
        return finish(ledger.charged)

    def _run_joint_plan(self, key, query: JointSUPGQuery, *,
                        sink: Optional[pipeline.SelectionSink] = None,
                        chunk_records: Optional[int] = None,
                        ledger_parent: Optional[BudgetLedger] = None,
                        state: Optional[CorpusState] = None) \
            -> Generator[object, Optional[np.ndarray], ShardedSelection]:
        """Resumable plan for one JT query (Appendix A): the RT sub-plan
        (delegated via `yield from`, so its oracle requests ride the same
        channel), then chunked verification requests over the candidate
        set. The verification ledger is capped at n_total — unbounded by
        design — and exists for `oracle_calls` attribution; under a
        `ledger_parent` (tenant quota) verification labels are metered
        against the parent too, so a quota-capped JT query fails loudly
        instead of labeling past its tenant's allowance. One pinned
        `CorpusState` spans both stages (unpinned on exit when this plan
        took the pin; a caller passing `state=` owns theirs)."""
        st = self.pin() if state is None else state
        try:
            result = yield from self._run_joint_plan_pinned(
                key, query, sink=sink, chunk_records=chunk_records,
                ledger_parent=ledger_parent, st=st)
            return result
        finally:
            if state is None:
                self.unpin(st)

    def _run_joint_plan_pinned(self, key, query: JointSUPGQuery, *,
                               sink=None, chunk_records=None,
                               ledger_parent=None, st: CorpusState) \
            -> Generator[object, Optional[np.ndarray], ShardedSelection]:
        rt = SUPGQuery(target="recall", gamma=query.gamma_recall,
                       delta=query.delta, budget=query.stage_budget,
                       method=query.method)
        cand = yield from self._run_plan(key, rt,
                                         chunk_records=chunk_records,
                                         ledger_parent=ledger_parent,
                                         state=st)
        vledger = BudgetLedger(st.n_total, parent=ledger_parent)
        out = pipeline.IndexSink() if sink is None else sink
        chunk = int(chunk_records or self.chunk_records)
        sizes = [int(s.shape[0]) for s in st.shards]
        out.open(sizes)
        try:
            for sh in range(len(st.shards)):
                local = cand.indices(sh)
                for start in range(0, local.size, chunk):
                    seg = local[start:start + chunk]
                    labels = yield OracleRequest(st.offsets[sh] + seg,
                                                 vledger)
                    out.emit(sh, seg[labels > 0.5])
        except BaseException:
            # Failed (or abandoned — GeneratorExit) mid-verification:
            # release the sink so sequential reuse still works; its
            # partial contents are owned by the raised error.
            _close_quietly(out)
            raise
        counts = out.close()
        return ShardedSelection(
            tau=cand.tau,
            oracle_calls=cand.oracle_calls + vledger.charged,
            sampled_positive_global=cand.sampled_positive_global,
            sink=out, shard_sizes=sizes, counts=counts)

    def _plan_for(self, key, query, *, sink=None, chunk_records=None,
                  ledger_parent=None, state=None):
        if isinstance(query, JointSUPGQuery):
            return self._run_joint_plan(key, query, sink=sink,
                                        chunk_records=chunk_records,
                                        ledger_parent=ledger_parent,
                                        state=state)
        return self._run_plan(key, query, sink=sink,
                              chunk_records=chunk_records,
                              ledger_parent=ledger_parent, state=state)

    # -- query entry points -----------------------------------------------

    def run(self, key, oracle_fn, query: SUPGQuery, *,
            sink: Optional[pipeline.SelectionSink] = None,
            chunk_records: Optional[int] = None) -> ShardedSelection:
        """Execute one RT/PT query, streaming the selection through `sink`.

        `oracle_fn` is a plain ``indices -> labels`` callable (adapted
        into a private labeling channel — exactly the historical
        per-query-budget semantics) or an `OracleClient` such as a shared
        `BatchingOracle`, in which case its label cache carries over.
        With no sink the selection lands in an in-memory `IndexSink`
        (O(selected) host memory); pass a `BitmaskStore` for out-of-core
        output or a `CallbackSink` to consume chunks as they are emitted.
        """
        return _drive_plan(
            self._run_plan(key, query, sink=sink,
                           chunk_records=chunk_records),
            as_oracle_client(oracle_fn), self.pool)

    def run_joint(self, key, oracle_fn, query: JointSUPGQuery, *,
                  sink: Optional[pipeline.SelectionSink] = None,
                  chunk_records: Optional[int] = None) -> ShardedSelection:
        """Engine-level JT query (Appendix A): RT stage at gamma_recall,
        then exhaustive oracle filtering of the candidate set. The RT stage
        streams into an internal IndexSink; verification then re-walks the
        candidate indices in chunks, emitting only oracle-verified positives
        into `sink` (precision exactly 1.0; oracle usage beyond the RT
        stage is unbounded by design). Both stages ride one labeling
        channel, so verification gets RT-stage labels from the cache for
        free."""
        return _drive_plan(
            self._run_joint_plan(key, query, sink=sink,
                                 chunk_records=chunk_records),
            as_oracle_client(oracle_fn), self.pool)

    def session(self, oracle_fn, *, concurrency: Optional[int] = None,
                max_batch: Optional[int] = None,
                retry=None, call_timeout_s: Optional[float] = None,
                breaker=None) -> "QuerySession":
        """Open a `QuerySession`: the multi-query scheduler + shared
        batched-oracle channel. Use as a context manager::

            with engine.session(oracle_fn, concurrency=8) as sess:
                handles = [sess.submit(q, key=k) for q, k in work]
                results = [h.result() for h in handles]

        All in-flight plans' oracle requests funnel through one
        `BatchingOracle` (unless `oracle_fn` is already an `OracleClient`,
        which is then shared as-is), so overlapping samples are labeled
        once and micro-batches span queries. Scheduling is double-buffered
        (see the module docstring): one cohort's coalesced drain runs on
        the channel's drain thread while the other cohort's plan steps run
        on the engine's worker pool, and all of a round's emission walks
        fuse into one chunk pass. `concurrency` caps in-flight plans
        (default: unbounded — every submitted query joins the next round);
        `max_batch` caps records per underlying oracle call. Overlap
        accounting is on `session.stats` (a `SessionStats`).

        `retry` (a `core.resilience.RetryPolicy`), `call_timeout_s`, and
        `breaker` (a `core.resilience.CircuitBreaker`) configure the
        private channel's fault tolerance when `oracle_fn` is a bare
        callable — failed micro-batches are retried per policy, and a
        query whose records exhaust retries fails alone while co-batched
        queries complete. Retry accounting lands on `session.stats`.
        """
        return QuerySession(self, oracle_fn, concurrency=concurrency,
                            max_batch=max_batch, retry=retry,
                            call_timeout_s=call_timeout_s, breaker=breaker)

    def run_many(self, key, oracle_fn,
                 queries: Sequence[Union[SUPGQuery, JointSUPGQuery]], *,
                 sinks: Optional[Sequence[
                     Optional[pipeline.SelectionSink]]] = None,
                 chunk_records: Optional[int] = None,
                 concurrency: Optional[int] = None) \
            -> List[ShardedSelection]:
        """Serve a batch of RT / PT / JT queries off one cached state —
        a thin wrapper over `session()`.

        The sketch, shard masses, and per-scheme CDFs were built once at
        construction; each query only pays O(s) sampling + one streamed
        O(n) emission pass, and the whole batch shares one labeling
        channel (overlapping samples are labeled once; oracle calls are
        coalesced across queries into micro-batches). Budgets are enforced
        per query via `BudgetLedger` views. `concurrency` caps in-flight
        plans (default: the whole batch); output (tau, counts, sink
        contents) is bit-for-bit identical at any concurrency for a pure
        oracle. `sinks`, when given, supplies one sink per query (None
        entries fall back to a fresh IndexSink) — the streaming fan-out
        point for a service; one sink object cannot serve two queries
        (their emissions would interleave).
        """
        if sinks is None:
            sinks = [None] * len(queries)
        # Validate the sink list before any key splitting so a malformed
        # call fails on the actual mistake, not a shape error downstream.
        if len(sinks) != len(queries):
            raise ValueError(
                f"need exactly one sink (or None) per query: got "
                f"{len(sinks)} sinks for {len(queries)} queries")
        live = [id(s) for s in sinks if s is not None]
        if len(live) != len(set(live)):
            raise ValueError(
                "one sink object is shared by multiple queries; their "
                "emissions would interleave — give each query its own sink")
        if not len(queries):
            return []
        keys = jax.random.split(
            jax.random.PRNGKey(0) if key is None else key, len(queries))
        with self.session(oracle_fn, concurrency=concurrency) as sess:
            handles = [sess.submit(q, key=k, sink=snk,
                                   chunk_records=chunk_records)
                       for k, q, snk in zip(keys, queries, sinks)]
            return [h.result() for h in handles]

    # -- streaming emission ---------------------------------------------

    def _emission_walk(self, tau: float, pos: np.ndarray,
                       sink: Optional[pipeline.SelectionSink],
                       chunk_records: Optional[int],
                       state: Optional[CorpusState] = None,
                       shard_ids: Optional[Sequence[int]] = None):
        """Prepare the streamed {A >= tau} ∪ labeled-positives emission.

        Opens the sink, folds the labeled positives *below* tau (those
        at/above tau stream out of their own chunks — fold/emit stay
        disjoint and counts exact), and returns ``(walk, sink, finish)``:
        the `ChunkWalk` whose spans run the fused threshold_select pass,
        the opened sink, and the closure that closes the sink and builds
        the `ShardedSelection` once every span has run. Splitting the walk
        from its bookkeeping is what lets a `QuerySession` fuse all
        in-flight plans' emission passes into one span list per round.
        The sink serializes its own consumption (see its thread-safety
        contract), peak host memory is O(chunk), and no full-corpus
        boolean mask is ever allocated. Unscored records (the -1 sentinel)
        are never emitted by the threshold pass; an unscored labeled
        positive still folds in, exactly like the materialized path
        selected it. If the fold itself dies (e.g. a CallbackSink consumer
        raised) the sink is released before the error propagates.

        `state` pins the corpus epoch walked; `shard_ids` restricts the
        walk to those shards only (the live plane's standing re-emission
        over appended shards — the sink still opens with the epoch's full
        shard sizes, so global offsets stay correct).
        """
        st = self._state if state is None else state
        sink = pipeline.IndexSink() if sink is None else sink
        chunk = int(chunk_records or self.chunk_records)
        sizes = [int(s.shape[0]) for s in st.shards]
        if shard_ids is not None:
            plan = pipeline.ChunkPlan(sizes, chunk, shard_ids=shard_ids)
        else:
            plan = (st.plan if chunk == self.chunk_records
                    else pipeline.ChunkPlan(sizes, chunk))
        sink.open(sizes)
        try:
            if pos.size:
                below = pos[self.score_at(pos, state=st) < tau]
                if below.size:
                    sh_ids = np.searchsorted(st.offsets, below,
                                             side="right") - 1
                    for shard_id in np.unique(sh_ids):
                        loc = (below[sh_ids == shard_id]
                               - st.offsets[shard_id])
                        sink.fold(int(shard_id), np.unique(loc))
        except BaseException:
            _close_quietly(sink)
            raise

        def emit_span(span):
            block = st.shards[span.shard_id][span.start:span.stop]
            local = select_ops.threshold_select(
                block, tau, backend=self.select_backend)
            if local.size:
                sink.emit(span.shard_id, span.start + local)

        def finish(oracle_calls: int) -> ShardedSelection:
            counts = sink.close()
            return ShardedSelection(
                tau=float(tau), oracle_calls=oracle_calls,
                sampled_positive_global=pos, sink=sink,
                shard_sizes=sizes, counts=counts)

        return pipeline.ChunkWalk(plan, emit_span), sink, finish

    def _emit_selection(self, tau: float, pos: np.ndarray,
                        oracle_calls: int,
                        sink: Optional[pipeline.SelectionSink],
                        chunk_records: Optional[int],
                        state: Optional[CorpusState] = None) \
            -> ShardedSelection:
        """Synchronous emission: `_emission_walk` run to completion on the
        engine's pool — the non-scheduled path (and benches)."""
        walk, out_sink, finish = self._emission_walk(tau, pos, sink,
                                                     chunk_records,
                                                     state=state)
        err = pipeline.run_fused([walk], self.pool)[0]
        if err is not None:
            # Emission died (e.g. a CallbackSink consumer raised): release
            # the sink so sequential reuse still works.
            _close_quietly(out_sink)
            raise err
        return finish(oracle_calls)

    def _uniform_in_region(self, key, s, tau, state=None):
        """Uniform draws from {A >= tau} across shards, chunk-streamed.

        One ChunkPlan counting pass (threaded over spans) yields per-chunk
        region sizes; draws are then rank-routed through those cached
        counts, so the resolution pass re-runs threshold_select only on
        chunks that actually received draws — chunks whose region is empty
        carry zero rank mass and are skipped for free. The PT stage-2
        restriction therefore runs at O(chunk) peak memory like selection
        emission: no full-shard mask or nonzero is ever materialized
        (unscored sentinel records are excluded, like emission).

        Shards whose region is empty get exactly zero categorical mass (no
        floor), so draws can never be clamped onto records below tau. If the
        region is globally empty the draws fall back to uniform over all
        records — tau estimation then sees an unrestricted uniform sample,
        which keeps the estimator valid (D' restriction is an efficiency
        device, never a correctness requirement).
        """
        st = self._state if state is None else state
        plan = st.plan
        spans = list(plan)

        def count_span(span):
            # Count through the exact same selection pass the resolve step
            # uses: any dtype/backend rounding disagreement between the two
            # would desynchronize ranks from region sizes.
            return select_ops.threshold_select(
                st.shards[span.shard_id][span.start:span.stop], tau,
                backend=self.select_backend).size

        span_counts = self.pool.map(count_span, spans)
        per_shard = [np.zeros(plan.num_chunks(sh), np.int64)
                     for sh in range(len(st.shards))]
        for span, c in zip(spans, span_counts):
            per_shard[span.shard_id][span.chunk_id] = c
        counts = np.asarray([pc.sum() for pc in per_shard], np.float64)
        total = counts.sum()
        if total == 0:
            idx = jax.random.randint(key, (s,), 0, st.n_total)
            return np.asarray(idx, np.int64)
        mass = counts / total
        k_alloc, k_draw = jax.random.split(key)
        # log(0) = -inf => empty shards are excluded from the categorical.
        alloc = np.asarray(jax.random.categorical(
            k_alloc, jnp.log(jnp.asarray(mass, jnp.float32)), shape=(s,)))
        out = np.empty(s, np.int64)
        dkeys = jax.random.split(k_draw, len(st.shards))
        work = []    # (shard_id, chunk_id, positions, in-chunk region ranks)
        for sh, seg in self._group_sorted(alloc,
                                          np.argsort(alloc, kind="stable")):
            cum = np.concatenate([[0], np.cumsum(per_shard[sh])])
            # uniform region ranks, then rank -> (chunk, offset-in-chunk);
            # only chunks with nonzero region counts can be hit.
            r = np.asarray(jax.random.randint(
                dkeys[sh], (seg.size,), 0, int(cum[-1])), np.int64)
            ch = np.searchsorted(cum, r, side="right") - 1
            corder = np.argsort(ch, kind="stable")
            for ci, grp in self._group_sorted(ch, corder):
                work.append((sh, ci, seg[grp], r[grp] - cum[ci]))

        chunk = plan.chunk_records

        def resolve(item):
            sh, ci, pos, ranks = item
            start = ci * chunk
            region = select_ops.threshold_select(
                st.shards[sh][start:start + chunk], tau,
                backend=self.select_backend)
            out[pos] = st.offsets[sh] + start + region[ranks]

        self.pool.map(resolve, work)
        return out


# ---------------------------------------------------------------------------
# Query scheduling — the async multi-query execution plane
# ---------------------------------------------------------------------------

def _drive_plan(plan, client: OracleClient,
                pool: Optional[pipeline.WorkerPool] = None) \
        -> ShardedSelection:
    """Sequential trampoline: advance one plan to each yield point —
    `OracleRequest`s are answered through the channel (submit + result,
    which drains), `ChunkWalk`s run to completion on the engine pool —
    then resume. This is exactly the single-query execution path of
    `run`/`run_joint`.

    A channel or walk error is thrown *into* the plan at its yield point,
    not raised from here directly: the suspended generator would otherwise
    stay alive on the exception's traceback with its cleanup (sink
    release) never run."""
    send = None
    while True:
        try:
            req = plan.send(send)
        except StopIteration as done:
            return done.value
        try:
            if isinstance(req, pipeline.ChunkWalk):
                walk_err = pipeline.run_fused([req], pool)[0]
                if walk_err is not None:
                    raise walk_err
                send = None
            else:
                send = client.submit(req.indices,
                                     ledger=req.ledger).result()
        except BaseException as err:  # noqa: BLE001 — rethrown in plan
            try:
                plan.throw(err)       # runs the plan's except/finally
            except StopIteration as done:
                return done.value     # plan absorbed the error gracefully
            raise RuntimeError(
                "plan yielded again after its request failed")


_START = object()       # inbox sentinel: plan not yet started


class QueryHandle:
    """Future for one query submitted to a `QuerySession`.

    `result()` pumps the session's scheduler until this query's plan
    completes, then returns its `ShardedSelection` — or raises the plan's
    error (`BudgetExceededError` if this query's ledger was rejected in a
    coalesced drain; other queries are unaffected).
    """

    def __init__(self, session: "QuerySession", query, sink):
        self.query = query
        self.sink = sink
        self._session = session
        self._result: Optional[ShardedSelection] = None
        self._error: Optional[BaseException] = None
        self._done = False

    @property
    def done(self) -> bool:
        """True once this query's plan has completed (or failed)."""
        return self._done

    def result(self) -> ShardedSelection:
        """This query's `ShardedSelection` (pumps the session if needed)."""
        if not self._done:
            self._session._pump(until=self)
        if self._error is not None:
            raise self._error
        return self._result


@dataclasses.dataclass
class SessionStats:
    """Per-session scheduler accounting — the observability surface the
    double-buffered overlap is judged by.

    `drain_busy_s` is total wall time coalesced drains were in flight on
    the channel; `drain_wait_s` is how long the driver actually blocked
    waiting for them. Their difference (`overlap_hidden_s`) is oracle
    latency hidden under the other cohort's compute. `walk_spans` counts
    chunk spans the round's emission walks would have cost run separately;
    `fused_spans` is what the fused pass actually walked — the gap
    (`spans_saved`) is data chunks touched once instead of k times."""

    rounds: int = 0            # scheduler turns taken
    plan_steps: int = 0        # generator resumptions
    drains: int = 0            # coalesced drains launched
    drain_busy_s: float = 0.0  # wall time drains spent in flight
    drain_wait_s: float = 0.0  # driver time blocked awaiting drains
    fused_walks: int = 0       # emission walks executed through fusion
    walk_spans: int = 0        # spans those walks would cost unfused
    fused_spans: int = 0       # spans the fused passes actually ran
    retries: int = 0           # oracle calls re-attempted (resilience)
    timeouts: int = 0          # oracle calls killed by the watchdog
    batch_failures: int = 0    # micro-batches that exhausted retries/fatal
    batch_sheds: int = 0       # micro-batches shed by the open circuit

    @property
    def overlap_hidden_s(self) -> float:
        """Oracle in-flight time the driver never blocked on."""
        return max(0.0, self.drain_busy_s - self.drain_wait_s)

    @property
    def spans_saved(self) -> int:
        """Chunk touches eliminated by per-round walk fusion."""
        return self.walk_spans - self.fused_spans


class QuerySession:
    """Scheduler that drives N query plans concurrently over one shared,
    batched labeling channel — `SelectionEngine.session()`'s return value.

    Scheduling is *double-buffered* and deterministic: in-flight plans are
    split across two cohorts that take strictly alternating turns. One
    turn advances every plan of the current cohort to its next yield
    through the engine's persistent `WorkerPool` (each step is pure
    compute — sampling, tau estimation, emission — off the engine's
    cached state; all `ChunkWalk`s the cohort yields are fused into one
    span list, so k emission passes touch each shard chunk once), then
    resolves the *other* cohort's in-flight drain, submits this cohort's
    requests in submission order, and launches their coalesced drain
    asynchronously (`BatchingOracle.drain_async`) before handing the turn
    over. The drain is therefore in flight on the channel's dedicated
    drain thread exactly while the other cohort computes. At most one
    drain is ever outstanding, a cohort is stepped only after its own
    drain's tickets resolved, and cohort state commits before any channel
    call — so results are bit-for-bit the sequential path's at any worker
    count and overlap depth, and the fixed submission order keeps charge
    attribution reproducible at a given concurrency.

    Plans that finish leave their cohort; queued plans join cohorts in
    submission order, balanced so both cohorts carry work. A plan whose
    ticket failed (e.g. `BudgetExceededError`) has the error thrown into
    it at its yield point on its next turn — that query's handle raises,
    co-batched queries are untouched; a poisoned drain reaches every
    ticket it owned, so nothing fails silently.

    The scheduler itself runs on whichever thread pumps it (a
    `handle.result()` call, a `step()` loop, or the context-manager
    exit) — the only background activity is the channel's drain thread,
    which never touches plan or engine state, so results are
    deterministic functions of (keys, queries, oracle, concurrency).

    >>> import jax, numpy as np
    >>> from repro.core.queries import SUPGQuery
    >>> scores = np.linspace(0.0, 1.0, 512, dtype=np.float32)
    >>> labels = (scores > 0.75).astype(np.float32)
    >>> qs = [SUPGQuery(target="recall", gamma=0.9, delta=0.1,
    ...                 budget=128, method="is") for _ in range(3)]
    >>> keys = jax.random.split(jax.random.PRNGKey(0), 3)
    >>> with SelectionEngine([scores], num_bins=32,
    ...                      use_kernel=False) as eng:
    ...     with eng.session(lambda idx: labels[idx]) as sess:
    ...         handles = [sess.submit(q, key=k)
    ...                    for q, k in zip(qs, keys)]
    ...         results = [h.result() for h in handles]
    >>> len(results), sess.client.fn_calls <= len(qs)  # coalesced drains
    (3, True)
    """

    def __init__(self, engine: SelectionEngine, oracle_fn, *,
                 concurrency: Optional[int] = None,
                 max_batch: Optional[int] = None,
                 retry=None, call_timeout_s: Optional[float] = None,
                 breaker=None):
        self.engine = engine
        self._owns_client = not isinstance(oracle_fn, OracleClient)
        self.client = as_oracle_client(oracle_fn, max_batch=max_batch,
                                       retry=retry,
                                       call_timeout_s=call_timeout_s,
                                       breaker=breaker)
        self.concurrency = (None if concurrency is None
                            else max(1, int(concurrency)))
        self.stats = SessionStats()
        self._queued: List[Tuple[QueryHandle, Generator]] = []
        # Two cohorts of slots [handle, plan, inbox]; _turn picks the one
        # stepped next. _outstanding is the in-flight drain of the cohort
        # whose turn just ended: (DrainHandle, [(slot, ticket), ...]).
        self._bufs: List[List[List]] = [[], []]
        self._turn = 0
        self._outstanding: Optional[
            Tuple[DrainHandle, List[Tuple[List, object]]]] = None
        self._closed = False

    # -- submission -------------------------------------------------------

    def submit(self, query, *, key=None,
               sink: Optional[pipeline.SelectionSink] = None,
               chunk_records: Optional[int] = None,
               ledger_parent: Optional[BudgetLedger] = None,
               state: Optional[CorpusState] = None) -> QueryHandle:
        """Enqueue one RT/PT/JT query; returns its `QueryHandle`.

        `key` defaults to PRNGKey(0) (pass distinct keys for distinct
        samples — `run_many` splits one key across its batch). The plan
        starts when a scheduler turn has a free cohort slot
        (`concurrency` caps the two cohorts' combined size).
        `ledger_parent` chains the query's budget ledger under a shared
        quota ledger — the serving plane passes each tenant's here.
        `state` pins the plan to a specific corpus epoch (`engine.pin()`)
        so a caller racing live-plane appends controls exactly which
        corpus the query certifies; default is the epoch current at the
        plan's first step.
        """
        if self._closed:
            raise RuntimeError("QuerySession is closed")
        handle = QueryHandle(self, query, sink)
        plan = self.engine._plan_for(key, query, sink=sink,
                                     chunk_records=chunk_records,
                                     ledger_parent=ledger_parent,
                                     state=state)
        self._queued.append((handle, plan))
        return handle

    def submit_plan(self, plan: Generator, *, query=None,
                    sink: Optional[pipeline.SelectionSink] = None) \
            -> QueryHandle:
        """Enqueue a pre-built resumable plan; returns its `QueryHandle`.

        The escape hatch for plans that are not SUPG queries but speak
        the same yield protocol (`OracleRequest` / `pipeline.ChunkWalk`):
        the live plane's standing re-emission walks enter here, joining
        the same cohorts, walk fusion, and coalesced drains as ordinary
        queries. `query`/`sink` only annotate the returned handle.
        """
        if self._closed:
            raise RuntimeError("QuerySession is closed")
        handle = QueryHandle(self, query, sink)
        self._queued.append((handle, plan))
        return handle

    def drain(self) -> None:
        """Explicit barrier on the shared channel (pending tickets only —
        plans advance when the scheduler is pumped)."""
        self.client.drain()

    # -- scheduler --------------------------------------------------------

    def _work_left(self) -> bool:
        return bool(self._queued or self._bufs[0] or self._bufs[1]
                    or self._outstanding is not None)

    @property
    def in_flight(self) -> int:
        """Queries admitted or queued but not yet completed."""
        return (len(self._queued) + len(self._bufs[0])
                + len(self._bufs[1]))

    def step(self) -> bool:
        """Advance the scheduler by exactly one turn; True if work remains.

        The incremental pump a long-lived host (the `repro.serve` plane)
        drives from its own scheduler thread: submit() any number of
        queries, call `step()` until it returns False (or poll handles'
        `done` between turns), and new submissions join the next turn's
        admission. Equivalent to the internal pumping `result()` does,
        exposed one turn at a time so a server can interleave admission,
        timeout bookkeeping, and completion delivery with plan progress.
        """
        if self._work_left():
            self._round()
        return self._work_left()

    def _pump(self, until: Optional[QueryHandle] = None) -> None:
        """Run scheduler turns until `until` (or everything) completes."""
        while not (until._done if until is not None
                   else not self._work_left()):
            if not self._work_left():
                raise RuntimeError(
                    "pumped a handle that is neither queued nor active")
            self._round()

    def _admit(self, buf: List[List]) -> None:
        """Move queued plans into `buf`, keeping the cohorts balanced:
        each cohort is filled to at most half the concurrency cap, so a
        full session always has a second cohort to compute under the
        first one's drain."""
        active = len(self._bufs[0]) + len(self._bufs[1])
        cap = self.concurrency or (active + len(self._queued))
        half = max(1, -(-cap // 2))
        while self._queued and active < cap and len(buf) < half:
            handle, plan = self._queued.pop(0)
            buf.append([handle, plan, _START])
            active += 1

    def _step_cohort(self, buf: List[List]) -> List[Tuple[str, object]]:
        """Advance every slot of one cohort to its next `OracleRequest`
        or completion. Slots pausing at `ChunkWalk` yields have their
        walks fused (`ChunkPlan.fuse`) and run as one span pass on the
        engine pool between micro-steps, then resume — so the cohort
        leaves this call holding only oracle requests and results.
        Thread count never changes outputs: steps land in their slots,
        and walk errors go back into exactly the plan that owns them."""

        def step(i):
            _, plan, inbox = buf[i]
            try:
                if inbox is _START:
                    out = plan.send(None)
                elif isinstance(inbox, BaseException):
                    out = plan.throw(inbox)
                else:
                    out = plan.send(inbox)
            except StopIteration as done:
                return ("done", done.value)
            except BaseException as err:  # noqa: BLE001 — owned by handle
                return ("err", err)
            if isinstance(out, pipeline.ChunkWalk):
                return ("walk", out)
            return ("req", out)

        outcomes: List[Optional[Tuple[str, object]]] = [None] * len(buf)
        live = list(range(len(buf)))
        while live:
            self.stats.plan_steps += len(live)
            stepped = self.engine.pool.map(step, live)
            walkers: List[int] = []
            for i, res in zip(live, stepped):
                outcomes[i] = res
                if res[0] == "walk":
                    walkers.append(i)
            if not walkers:
                break
            walks = [outcomes[i][1] for i in walkers]
            geoms: Dict[Tuple, pipeline.ChunkPlan] = {}
            for w in walks:
                geoms.setdefault(w.plan.geometry, w.plan)
            self.stats.fused_walks += len(walks)
            self.stats.walk_spans += sum(
                w.plan.total_chunks for w in walks)
            self.stats.fused_spans += sum(
                p.total_chunks for p in geoms.values())
            errs = pipeline.run_fused(walks, self.engine.pool)
            for i, err in zip(walkers, errs):
                # None resumes the plan past its walk; an error is thrown
                # into it (releasing its sink) on the re-step below.
                buf[i][2] = err
            live = walkers
        return outcomes

    def _await_outstanding(self) -> None:
        """Settle the in-flight drain (if any) and deliver its tickets'
        labels — or its poison — into the owning cohort's inboxes."""
        if self._outstanding is None:
            return
        handle, pending = self._outstanding
        self._outstanding = None
        t0 = time.perf_counter()
        handle.wait()
        self.stats.drain_wait_s += time.perf_counter() - t0
        self.stats.drain_busy_s += handle.duration_s
        self.stats.retries += handle.retries
        self.stats.timeouts += handle.timeouts
        self.stats.batch_failures += handle.batch_failures
        self.stats.batch_sheds += handle.batch_sheds
        for slot, ticket in pending:
            try:
                slot[2] = ticket.result()
            except BaseException as err:  # noqa: BLE001 — rethrown in plan
                slot[2] = err

    def _round(self) -> None:
        """One scheduler turn: admit + step the current cohort (fusing
        its walks), commit, resolve the other cohort's drain, then launch
        this cohort's drain asynchronously and hand the turn over."""
        cur = self._turn
        buf = self._bufs[cur]
        self._admit(buf)
        self.stats.rounds += 1
        requests: List[Tuple[List, OracleRequest]] = []
        if buf:
            # This is the compute that overlaps the other cohort's
            # in-flight drain: the drain thread only touches the channel,
            # the steps only touch engine state.
            outcomes = self._step_cohort(buf)
            survivors: List[List] = []
            for slot, (kind, value) in zip(buf, outcomes):
                handle = slot[0]
                if kind == "done":
                    handle._result, handle._done = value, True
                elif kind == "err":
                    handle._error, handle._done = value, True
                else:
                    requests.append((slot, value))
                    survivors.append(slot)
            # Commit the new cohort state *before* touching the channel:
            # submit (whose max_batch auto-drain can run fn) may blow up
            # on a broken oracle, and when it does, finished plans must
            # already be gone and every surviving slot must still get a
            # definitive inbox — never a stale one that would silently
            # resume its plan with the previous turn's payload.
            self._bufs[cur] = buf = survivors
        # Resolve the other cohort's drain before submitting: submits
        # would only block on the channel lock the drain holds anyway,
        # and waiting here keeps drain_wait_s an honest overlap metric.
        self._await_outstanding()
        if requests:
            pending: List[Tuple[List, object]] = []
            try:
                for slot, req in requests:
                    pending.append((slot, self.client.submit(
                        req.indices, ledger=req.ledger)))
            except BaseException as err:  # noqa: BLE001 — into inboxes
                # A submit-time auto-drain failed: its poison already
                # marks every popped ticket; plans see the error at their
                # next turn (loudly — the handles raise it), exactly like
                # an async drain failure.
                submitted = {id(slot) for slot, _ in pending}
                for slot, _ in requests:
                    if id(slot) not in submitted:
                        slot[2] = err     # failed before this submit ran
                for slot, ticket in pending:
                    try:
                        slot[2] = ticket.result()
                    except BaseException as terr:  # noqa: BLE001
                        slot[2] = terr
            else:
                self.stats.drains += 1
                self._outstanding = (self._start_drain(), pending)
        self._turn = 1 - cur

    def _start_drain(self) -> DrainHandle:
        """Launch the pending tickets' coalesced drain, overlapped when
        the client supports it. Third-party `OracleClient`s without
        `drain_async` drain synchronously on the driver thread —
        identical results, no overlap."""
        start = getattr(self.client, "drain_async", None)
        if start is not None:
            return start()
        handle = DrainHandle()
        t0 = time.perf_counter()
        err: Optional[BaseException] = None
        try:
            self.client.drain()
        except BaseException as e:  # noqa: BLE001 — carried by handle
            err = e
        handle._finish(err, time.perf_counter() - t0)
        return handle

    # -- lifecycle --------------------------------------------------------

    def close(self, abandon: bool = False) -> None:
        """Finish the session: pump every submitted query to completion
        (unless `abandon`), then reject stragglers, close their plans,
        and reap the channel's drain thread (for a session-owned client
        only — a caller-shared `OracleClient` outlives the session)."""
        if self._closed:
            return
        if not abandon:
            self._pump()
        self._await_outstanding()    # settle any in-flight drain
        self._closed = True
        leftovers = self._queued + [
            (s[0], s[1]) for s in self._bufs[0] + self._bufs[1]]
        self._queued, self._bufs = [], [[], []]
        for handle, plan in leftovers:
            plan.close()
            if not handle._done:
                handle._error = RuntimeError("QuerySession abandoned")
                handle._done = True
        if self._owns_client:
            close_client = getattr(self.client, "close", None)
            if close_client is not None:
                close_client()

    def __enter__(self) -> "QuerySession":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close(abandon=exc_type is not None)
        return False
