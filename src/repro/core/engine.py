"""Distributed SUPG selection engine — the production query executor.

The engine is a *precomputation-cached, vectorized, sketch-driven* data
plane: all O(n) work happens once at construction, after which any number of
RT / PT / JT queries are served off cached per-shard state.

Construction (one pass over the shards):

  1. per-shard ScoreSketch via the fused Pallas score_hist kernel (compiled
     on TPU, interpret-mode on CPU; jnp fallback for non-tile-aligned bin
     counts), merged into the global sketch (one psum of 48 KiB on a fleet),
  2. cached sampling state per (scheme, kappa): the global defensive-mixture
     draw probabilities p(x) = (1-kappa)·raw(x)/Z + kappa/n and their
     normalized within-shard CDFs for inverse-CDF draws — the normalizers
     (Z_sqrt, Z_prop, n) come from `binned.weight_normalizers` on the merged
     sketch, never from re-reducing raw shards,
  3. shard-level sampling masses for the two-level (shard → record) draw,
     derived from the per-shard sketches.

Query execution (zero O(n) recomputation per query):

  * `draw_sample`   — multinomial over cached shard masses, then vectorized
                      inverse-CDF draws against the cached per-shard CDFs,
                      with globally-correct m(x) factors,
  * `score_at`      — `np.searchsorted` shard routing + per-shard fancy
                      gathers (no per-element Python loop),
  * tau estimation  — the exact sample-level estimators (Algorithms 2-5;
                      the sample is tiny, so estimation is never distributed),
  * D' restriction  — rank → conservative bin edge through the sketch
                      (superset property),
  * selection       — *streamed*, never materialized: each shard is walked
                      in fixed-size chunks through the fused
                      `kernels/threshold_select` pass (compare + count +
                      index compaction; compiled on TPU, numpy nonzero
                      reference off-TPU) and the selected indices are
                      emitted into a `data.pipeline.SelectionSink`
                      (in-memory `IndexSink` by default, memmap
                      `BitmaskStore` for out-of-core output, `CallbackSink`
                      / `SelectionStream` for service streaming). Labeled
                      positives (Algorithm 1's R1) are folded in as a
                      sink-level merge of the positives *below* tau, so
                      emission and folding stay disjoint and per-shard
                      counts are exact without dedup state.

A query over a 1e8-record memmap store therefore peaks at O(chunk) host
memory: no full-corpus boolean mask is ever allocated, `ShardedSelection`
is a lazy view whose `total_selected` comes from per-shard counts, boolean
masks only materialize if a caller explicitly asks for them, and the PT
stage-2 uniform-in-D' draw is rank-routed through the same chunked pass.
(The one remaining O(n) surface is the cached per-record inverse-CDF state
behind importance-weighted sampling — construct with `weight_schemes=()`
and use uniform/noci-method queries for fully bounded memory today; see
the ROADMAP open item for chunking that state.)

`run_many` serves a *batch* of queries — SUPGQuery (RT/PT) and JointSUPGQuery
(JT, Appendix A) — amortizing the sketch and the cached sampling state across
the whole batch; this is the serving-plane entry point. Per-query sinks make
it the streaming fan-out point for a service.

Shards are host-local float32 arrays: plain np.ndarray, np.memmap, or
`data.pipeline.ScoreStore` objects (consumed zero-copy through `.scores`, so
out-of-core corpora work end-to-end; sketch construction over shards larger
than `chunk_records` is itself chunked and merged, so even engine build never
materializes a full shard). On a real fleet each worker holds its shard and
the driver runs where the coordinator lives; the collective math matches
core/distributed.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binned, sampling, thresholds
from repro.core.oracle import BudgetedOracle
from repro.core.queries import JointSUPGQuery, SUPGQuery
from repro.data import pipeline
from repro.kernels.threshold_select import ops as select_ops


class ShardedSelection:
    """Lazy view over one query's selection.

    Sink-backed (the engine's streaming output) or mask-backed (direct
    construction, kept for compatibility). In the sink-backed form nothing
    O(corpus) lives here: `total_selected` and `shard_counts` come from the
    per-shard counts the sink accumulated during emission, `indices(shard)`
    reads the sink, and `masks` materializes per-shard boolean views only
    when explicitly accessed (state-holding sinks only — a CallbackSink
    selection retains counts alone).
    """

    def __init__(self, masks: Optional[List[np.ndarray]] = None,
                 tau: float = 0.0, oracle_calls: int = 0,
                 sampled_positive_global: Optional[np.ndarray] = None,
                 sink: Optional[pipeline.SelectionSink] = None,
                 shard_sizes: Optional[Sequence[int]] = None,
                 counts: Optional[np.ndarray] = None):
        if masks is None and sink is None:
            raise ValueError("need per-shard masks or a SelectionSink")
        self.tau = float(tau)
        self.oracle_calls = int(oracle_calls)
        self.sampled_positive_global = (
            np.empty(0, np.int64) if sampled_positive_global is None
            else np.asarray(sampled_positive_global, np.int64))
        self.sink = sink
        self._masks = list(masks) if masks is not None else None
        if shard_sizes is None:
            if self._masks is not None:
                shard_sizes = [int(m.shape[0]) for m in self._masks]
            elif getattr(sink, "shard_sizes", None) is not None:
                shard_sizes = sink.shard_sizes   # an opened sink knows them
            else:
                raise ValueError(
                    "shard_sizes required when the sink has not been opened")
        self.shard_sizes = [int(n) for n in shard_sizes]
        self._counts = (None if counts is None
                        else np.asarray(counts, np.int64))

    @property
    def num_shards(self) -> int:
        return len(self.shard_sizes)

    @property
    def shard_counts(self) -> np.ndarray:
        """Per-shard selected counts (no mask materialization needed)."""
        if self._counts is not None:
            return self._counts.copy()
        return np.asarray([int(m.sum()) for m in self.masks], np.int64)

    @property
    def total_selected(self) -> int:
        if self._counts is not None:
            return int(self._counts.sum())
        return int(sum(int(m.sum()) for m in self.masks))

    def indices(self, shard_id: int) -> np.ndarray:
        """Sorted shard-local selected indices for one shard."""
        if self._masks is not None:
            return np.nonzero(self._masks[shard_id])[0].astype(np.int64)
        return np.asarray(self.sink.indices(shard_id), np.int64)

    @property
    def masks(self) -> List[np.ndarray]:
        """Per-shard boolean masks, materialized lazily from the sink.

        Allocates O(corpus) booleans — for large stores prefer
        `shard_counts` / `indices` / the sink itself.
        """
        if self._masks is None:
            self._masks = [self.sink.mask(i)
                           for i in range(self.num_shards)]
        return self._masks


@dataclasses.dataclass
class _ShardSamplingState:
    """Cached per-shard draw state for one (scheme, kappa) pair."""
    p_global: np.ndarray   # (n_shard,) float32 global draw probability p(x)
    cdf: np.ndarray        # (n_shard,) float64 normalized within-shard CDF


class SelectionEngine:
    """Executes batches of SUPG queries over a list of score shards."""

    def __init__(self, shards: Sequence, num_bins: int = 4096,
                 use_kernel: Optional[bool] = None,
                 weight_schemes: Sequence[str] = ("sqrt",),
                 kappa: float = sampling.DEFENSIVE_KAPPA,
                 cache_flat: Optional[bool] = None,
                 select_backend: Optional[str] = None,
                 chunk_records: Optional[int] = None):
        # ScoreStore (or anything exposing `.scores`) passes its memmap
        # through untouched; ndarray shards are viewed, not copied.
        raw_shards = [getattr(s, "scores", s) for s in shards]
        # Flat gather cache: for in-RAM shards a one-time concatenation
        # turns score_at into a single fancy gather. Defaults off for
        # memmap-backed (out-of-core) shards, which keep the routed path.
        # (Decide on the raw objects: np.asarray strips the memmap subclass.)
        if cache_flat is None:
            cache_flat = not any(isinstance(s, np.memmap)
                                 for s in raw_shards)
        self.shards = [np.asarray(s) for s in raw_shards]
        self.offsets = np.concatenate(
            [[0], np.cumsum([s.shape[0] for s in self.shards])]).astype(
                np.int64)
        self.n_total = int(self.offsets[-1])
        self.num_bins = num_bins
        self.kappa = float(kappa)
        # Streaming emission knobs: chunk_records bounds per-query peak
        # memory; select_backend picks the threshold_select path (compiled
        # Pallas on TPU, numpy reference elsewhere by default — interpret
        # emulation stays available for kernel validation).
        self.chunk_records = int(chunk_records or pipeline.CHUNK_RECORDS)
        self.select_backend = (select_ops.default_backend()
                               if select_backend is None else select_backend)
        self._flat = (np.concatenate(
            [np.asarray(s, np.float32) for s in self.shards])
            if cache_flat and self.shards else None)

        # 1. per-shard sketches (kernel path by default) + global merge.
        #    Shards beyond chunk_records are sketched chunk-by-chunk and
        #    merged (sketches are additive), so construction over memmap
        #    shards never materializes a full shard either.
        self.shard_sketches = [
            self._build_shard_sketch(s, num_bins, use_kernel)
            for s in self.shards]
        self.sketch = binned.merge_sketches(*self.shard_sketches)

        # 2. global weight normalizers from the merged sketch — the only
        #    cross-shard reductions sampling ever needs.
        z_sqrt, z_prop, n_sk = binned.weight_normalizers(self.sketch)
        self._z = {"sqrt": float(z_sqrt), "prop": float(z_prop)}
        # 3. shard-level raw masses from the per-shard sketches.
        self._shard_raw = {
            "sqrt": np.asarray([float(jnp.sum(sk.sum_w))
                                for sk in self.shard_sketches]),
            "prop": np.asarray([float(jnp.sum(sk.sum_a))
                                for sk in self.shard_sketches]),
        }
        self._shard_counts = np.asarray(
            [s.shape[0] for s in self.shards], np.float64)

        # 4. cached per-shard sampling state (CDFs) for the requested
        #    schemes; other schemes build lazily on first use.
        self._sampling_cache: Dict[Tuple[str, float], List[
            _ShardSamplingState]] = {}
        for scheme in weight_schemes:
            self._sampling_state(scheme, self.kappa)

    # -- cached state ---------------------------------------------------

    def _build_shard_sketch(self, scores, num_bins, use_kernel):
        n = int(scores.shape[0])
        if n <= self.chunk_records:
            return binned.build_sketch(jnp.asarray(scores, jnp.float32),
                                       num_bins, use_kernel=use_kernel)
        parts = [
            binned.build_sketch(
                jnp.asarray(np.asarray(scores[o:o + self.chunk_records],
                                       np.float32)),
                num_bins, use_kernel=use_kernel)
            for o in range(0, n, self.chunk_records)]
        return binned.merge_sketches(*parts)

    def _sampling_state(self, scheme: str,
                        kappa: float) -> List[_ShardSamplingState]:
        cache_key = (scheme, float(kappa))
        if cache_key not in self._sampling_cache:
            z = max(self._z[scheme], 1e-30)
            states = []
            for scores in self.shards:
                if scores.shape[0] == 0:
                    states.append(_ShardSamplingState(
                        p_global=np.empty(0, np.float32),
                        cdf=np.empty(0, np.float64)))
                    continue
                a = np.clip(np.asarray(scores, np.float32), 0.0, 1.0)
                raw = np.sqrt(a) if scheme == "sqrt" else a
                p_global = ((1.0 - kappa) * raw / z
                            + kappa / self.n_total).astype(np.float32)
                states.append(_ShardSamplingState(
                    p_global=p_global,
                    cdf=sampling.normalized_cdf(p_global)))
            self._sampling_cache[cache_key] = states
        return self._sampling_cache[cache_key]

    def _shard_masses(self, scheme: str, kappa: float) -> np.ndarray:
        raws = self._shard_raw[scheme]
        z = max(self._z[scheme], 1e-30)
        mass = (1.0 - kappa) * raws / z \
            + kappa * self._shard_counts / self.n_total
        return mass / mass.sum()

    # -- sampling -------------------------------------------------------

    def draw_sample(self, key, s: int, scheme: str = "sqrt",
                    kappa: Optional[float] = None):
        """Global with-replacement draws; returns (global_idx, m).

        Two-level: multinomial over cached shard masses, then vectorized
        inverse-CDF draws against the cached per-shard CDFs. The joint draw
        probability equals the global defensive-mixed p(x) exactly (shard
        mass is the shard's total p(x) by construction), so
        m(x) = (1/n) / p(x) is globally correct.
        """
        if scheme == "uniform":
            idx = jax.random.randint(key, (s,), 0, self.n_total)
            return np.asarray(idx, np.int64), np.ones(s, np.float32)
        kappa = self.kappa if kappa is None else kappa
        states = self._sampling_state(scheme, kappa)
        mass = self._shard_masses(scheme, kappa)
        k_alloc, k_draw = jax.random.split(key)
        alloc = np.asarray(jax.random.categorical(
            k_alloc, jnp.log(jnp.asarray(mass, jnp.float32)), shape=(s,)))
        u = np.asarray(jax.random.uniform(k_draw, (s,)), np.float64)
        out_idx = np.empty(s, np.int64)
        out_m = np.empty(s, np.float32)
        for sh, state in enumerate(states):
            take = np.nonzero(alloc == sh)[0]
            if take.size == 0:
                continue
            local = sampling.draw_from_cdf(state.cdf, u[take])
            out_idx[take] = self.offsets[sh] + local
            out_m[take] = (1.0 / self.n_total) / np.maximum(
                state.p_global[local], 1e-38)
        return out_idx, out_m

    def score_at(self, global_idx) -> np.ndarray:
        """Vectorized gather: one flat fancy gather when the concatenation
        cache is live, else searchsorted shard routing + per-shard fancy
        indexing (works unchanged on memmap shards)."""
        gi = np.asarray(global_idx, np.int64)
        if self._flat is not None:
            return self._flat[gi]
        sh = np.searchsorted(self.offsets, gi, side="right") - 1
        local = gi - self.offsets[sh]
        out = np.empty(gi.shape[0], np.float32)
        # Group draws by shard with one argsort, then gather each shard's
        # segment with a single fancy index (one touch per shard).
        order = np.argsort(sh, kind="stable")
        seg_bounds = np.searchsorted(sh[order],
                                     np.arange(len(self.shards) + 1))
        for shard_id in range(len(self.shards)):
            seg = order[seg_bounds[shard_id]:seg_bounds[shard_id + 1]]
            if seg.size:
                out[seg] = np.asarray(
                    self.shards[shard_id][local[seg]], np.float32)
        return out

    # -- query ----------------------------------------------------------

    def run(self, key, oracle_fn: Callable, query: SUPGQuery, *,
            sink: Optional[pipeline.SelectionSink] = None,
            chunk_records: Optional[int] = None) -> ShardedSelection:
        """Execute one RT/PT query, streaming the selection through `sink`.

        With no sink the selection lands in an in-memory `IndexSink`
        (O(selected) host memory); pass a `BitmaskStore` for out-of-core
        output or a `CallbackSink` to consume chunks as they are emitted.
        """
        key = jax.random.PRNGKey(0) if key is None else key
        oracle = BudgetedOracle(oracle_fn, query.budget)
        s = query.budget
        if query.target == "recall":
            scheme = {"is": query.weight_scheme, "uniform": "uniform",
                      "noci": "uniform"}[query.method]
            idx, m = self.draw_sample(key, s, scheme)
            o_s = oracle(idx)
            a_s = self.score_at(idx)
            if query.method == "noci":
                res = thresholds.tau_unoci_r(a_s, o_s, query.gamma)
            else:
                res = thresholds.tau_ci_r(a_s, o_s, m, query.gamma,
                                          query.delta)
            tau = float(res.tau)
        else:
            k0, k1 = jax.random.split(key)
            if query.method == "is" and query.two_stage:
                idx0, m0 = self.draw_sample(k0, s // 2, query.weight_scheme)
                o0 = oracle(idx0)
                _, rank = thresholds.pt_stage1_nmatch(
                    o0, m0, self.n_total, query.gamma, query.delta)
                tau_dp = float(binned.rank_to_threshold(self.sketch,
                                                        int(rank)))
                # stage 2: uniform on D' via per-shard masked draws
                idx1 = self._uniform_in_region(k1, s - s // 2, tau_dp)
                o1 = oracle(idx1)
                a1 = self.score_at(idx1)
                res = thresholds.tau_ci_p(a1, o1, query.gamma,
                                          query.delta / 2.0,
                                          min_step=query.min_step)
            else:
                scheme = ("uniform" if query.method in ("uniform", "noci")
                          else query.weight_scheme)
                idx, m = self.draw_sample(k0, s, scheme)
                o_s = oracle(idx)
                a_s = self.score_at(idx)
                if query.method == "noci":
                    res = thresholds.tau_unoci_p(a_s, o_s, query.gamma)
                else:
                    res = thresholds.tau_ci_p(
                        a_s, o_s, query.gamma, query.delta,
                        m_s=None if scheme == "uniform" else m,
                        min_step=query.min_step)
            tau = float(res.tau)

        pos = oracle.labeled_positives()
        return self._emit_selection(tau, pos, oracle.calls_used, sink,
                                    chunk_records)

    def run_joint(self, key, oracle_fn: Callable, query: JointSUPGQuery, *,
                  sink: Optional[pipeline.SelectionSink] = None,
                  chunk_records: Optional[int] = None) -> ShardedSelection:
        """Engine-level JT query (Appendix A): RT stage at gamma_recall,
        then exhaustive oracle filtering of the candidate set. The RT stage
        streams into an internal IndexSink; verification then re-walks the
        candidate indices in chunks, emitting only oracle-verified positives
        into `sink` (precision exactly 1.0; oracle usage beyond the RT
        stage is unbounded by design)."""
        rt = SUPGQuery(target="recall", gamma=query.gamma_recall,
                       delta=query.delta, budget=query.stage_budget,
                       method=query.method)
        cand = self.run(key, oracle_fn, rt, chunk_records=chunk_records)
        oracle = BudgetedOracle(oracle_fn, budget=self.n_total)
        out = pipeline.IndexSink() if sink is None else sink
        chunk = int(chunk_records or self.chunk_records)
        sizes = [int(s.shape[0]) for s in self.shards]
        out.open(sizes)
        for sh in range(len(self.shards)):
            local = cand.indices(sh)
            for start in range(0, local.size, chunk):
                seg = local[start:start + chunk]
                labels = oracle(self.offsets[sh] + seg)
                out.emit(sh, seg[labels > 0.5])
        counts = out.close()
        return ShardedSelection(
            tau=cand.tau,
            oracle_calls=cand.oracle_calls + oracle.calls_used,
            sampled_positive_global=cand.sampled_positive_global,
            sink=out, shard_sizes=sizes, counts=counts)

    def run_many(self, key, oracle_fn: Callable,
                 queries: Sequence[Union[SUPGQuery, JointSUPGQuery]], *,
                 sinks: Optional[Sequence[
                     Optional[pipeline.SelectionSink]]] = None,
                 chunk_records: Optional[int] = None) \
            -> List[ShardedSelection]:
        """Serve a batch of RT / PT / JT queries off one cached state.

        The sketch, shard masses, and per-scheme CDFs were built once at
        construction; each query only pays O(s) sampling + one streamed
        O(n) emission pass. Budgets are accounted per query (each gets its
        own BudgetedOracle), matching independent `run` calls semantically.
        `sinks`, when given, supplies one sink per query (None entries fall
        back to a fresh IndexSink) — the streaming fan-out point for a
        service.
        """
        keys = jax.random.split(
            jax.random.PRNGKey(0) if key is None else key, len(queries))
        if sinks is None:
            sinks = [None] * len(queries)
        if len(sinks) != len(queries):
            raise ValueError("need exactly one sink (or None) per query")
        out = []
        for k, q, snk in zip(keys, queries, sinks):
            if isinstance(q, JointSUPGQuery):
                out.append(self.run_joint(k, oracle_fn, q, sink=snk,
                                          chunk_records=chunk_records))
            else:
                out.append(self.run(k, oracle_fn, q, sink=snk,
                                    chunk_records=chunk_records))
        return out

    # -- streaming emission ---------------------------------------------

    def _emit_selection(self, tau: float, pos: np.ndarray,
                        oracle_calls: int,
                        sink: Optional[pipeline.SelectionSink],
                        chunk_records: Optional[int]) -> ShardedSelection:
        """Stream {A >= tau} ∪ labeled-positives through a sink.

        Shards are walked independently in fixed-size chunks through the
        fused threshold_select pass, so peak host memory is O(chunk) and
        per-shard counts accumulate in the sink — no full-corpus boolean
        mask is ever allocated. Labeled positives are folded as a sink-level
        merge of the positives *below* tau (those at/above tau stream out
        of their own chunks), keeping fold/emit disjoint and counts exact.
        Unscored records (the -1 sentinel) are never emitted by the
        threshold pass; an unscored labeled positive still folds in, exactly
        like the materialized path selected it.
        """
        sink = pipeline.IndexSink() if sink is None else sink
        chunk = int(chunk_records or self.chunk_records)
        sizes = [int(s.shape[0]) for s in self.shards]
        sink.open(sizes)
        if pos.size:
            below = pos[self.score_at(pos) < tau]
            if below.size:
                sh_ids = np.searchsorted(self.offsets, below,
                                         side="right") - 1
                for shard_id in np.unique(sh_ids):
                    loc = below[sh_ids == shard_id] - self.offsets[shard_id]
                    sink.fold(int(shard_id), np.unique(loc))
        for sh, scores in enumerate(self.shards):
            for start in range(0, int(scores.shape[0]), chunk):
                block = scores[start:start + chunk]
                local = select_ops.threshold_select(
                    block, tau, backend=self.select_backend)
                if local.size:
                    sink.emit(sh, start + local)
        counts = sink.close()
        return ShardedSelection(tau=float(tau), oracle_calls=oracle_calls,
                                sampled_positive_global=pos, sink=sink,
                                shard_sizes=sizes, counts=counts)

    def _uniform_in_region(self, key, s, tau):
        """Uniform draws from {A >= tau} across shards, chunk-streamed.

        Region sizes come from one chunked counting pass and draws are
        rank-routed back through per-chunk threshold_select, so the PT
        stage-2 restriction runs at O(chunk) peak memory like selection
        emission — no full-shard mask or nonzero is ever materialized
        (unscored sentinel records are excluded, like emission).

        Shards whose region is empty get exactly zero categorical mass (no
        floor), so draws can never be clamped onto records below tau. If the
        region is globally empty the draws fall back to uniform over all
        records — tau estimation then sees an unrestricted uniform sample,
        which keeps the estimator valid (D' restriction is an efficiency
        device, never a correctness requirement).
        """
        chunk = self.chunk_records
        per_shard = []           # per-shard arrays of per-chunk region sizes
        for scores in self.shards:
            n = int(scores.shape[0])
            cc = [0] if n == 0 else []
            for o in range(0, n, chunk):
                c = np.asarray(scores[o:o + chunk], np.float32)
                cc.append(int(np.count_nonzero((c >= tau) & (c >= 0.0))))
            per_shard.append(np.asarray(cc, np.int64))
        counts = np.asarray([cc.sum() for cc in per_shard], np.float64)
        total = counts.sum()
        if total == 0:
            idx = jax.random.randint(key, (s,), 0, self.n_total)
            return np.asarray(idx, np.int64)
        mass = counts / total
        k_alloc, k_draw = jax.random.split(key)
        # log(0) = -inf => empty shards are excluded from the categorical.
        alloc = np.asarray(jax.random.categorical(
            k_alloc, jnp.log(jnp.asarray(mass, jnp.float32)), shape=(s,)))
        out = np.empty(s, np.int64)
        dkeys = jax.random.split(k_draw, len(self.shards))
        for sh, scores in enumerate(self.shards):
            take = np.nonzero(alloc == sh)[0]
            if take.size == 0:
                continue
            cum = np.concatenate([[0], np.cumsum(per_shard[sh])])
            # uniform region ranks, then rank -> (chunk, offset-in-chunk)
            r = np.asarray(jax.random.randint(
                dkeys[sh], (take.size,), 0, int(cum[-1])), np.int64)
            ch = np.searchsorted(cum, r, side="right") - 1
            for c_id in np.unique(ch):
                in_chunk = ch == c_id
                region = select_ops.threshold_select(
                    scores[c_id * chunk:(c_id + 1) * chunk], tau,
                    backend=self.select_backend)
                out[take[in_chunk]] = (self.offsets[sh] + c_id * chunk
                                       + region[r[in_chunk] - cum[c_id]])
        return out
