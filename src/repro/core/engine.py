"""Distributed SUPG selection engine — the production query executor.

The engine is a *precomputation-cached, vectorized, sketch-driven* data
plane: all O(n) work happens once at construction, after which any number of
RT / PT / JT queries are served off cached per-shard state.

Construction (one pass over the shards):

  1. per-shard ScoreSketch via the fused Pallas score_hist kernel (compiled
     on TPU, interpret-mode on CPU; jnp fallback for non-tile-aligned bin
     counts), merged into the global sketch (one psum of 48 KiB on a fleet),
  2. cached sampling state per (scheme, kappa): the global defensive-mixture
     draw probabilities p(x) = (1-kappa)·raw(x)/Z + kappa/n and their
     normalized within-shard CDFs for inverse-CDF draws — the normalizers
     (Z_sqrt, Z_prop, n) come from `binned.weight_normalizers` on the merged
     sketch, never from re-reducing raw shards,
  3. shard-level sampling masses for the two-level (shard → record) draw,
     derived from the per-shard sketches.

Query execution (zero O(n) recomputation per query):

  * `draw_sample`   — multinomial over cached shard masses, then vectorized
                      inverse-CDF draws against the cached per-shard CDFs,
                      with globally-correct m(x) factors,
  * `score_at`      — `np.searchsorted` shard routing + per-shard fancy
                      gathers (no per-element Python loop),
  * tau estimation  — the exact sample-level estimators (Algorithms 2-5;
                      the sample is tiny, so estimation is never distributed),
  * D' restriction  — rank → conservative bin edge through the sketch
                      (superset property),
  * selection       — per-shard local masks, labeled positives folded in via
                      one vectorized searchsorted scatter.

`run_many` serves a *batch* of queries — SUPGQuery (RT/PT) and JointSUPGQuery
(JT, Appendix A) — amortizing the sketch and the cached sampling state across
the whole batch; this is the serving-plane entry point.

Shards are host-local float32 arrays: plain np.ndarray, np.memmap, or
`data.pipeline.ScoreStore` objects (consumed zero-copy through `.scores`, so
out-of-core corpora work end-to-end). On a real fleet each worker holds its
shard and the driver runs where the coordinator lives; the collective math
matches core/distributed.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binned, sampling, thresholds
from repro.core.oracle import BudgetedOracle
from repro.core.queries import JointSUPGQuery, SUPGQuery


@dataclasses.dataclass
class ShardedSelection:
    masks: List[np.ndarray]        # per-shard boolean selection masks
    tau: float
    oracle_calls: int
    sampled_positive_global: np.ndarray   # global ids of labeled positives

    @property
    def total_selected(self) -> int:
        # Labeled positives are already folded into the masks by run().
        return int(sum(int(m.sum()) for m in self.masks))


@dataclasses.dataclass
class _ShardSamplingState:
    """Cached per-shard draw state for one (scheme, kappa) pair."""
    p_global: np.ndarray   # (n_shard,) float32 global draw probability p(x)
    cdf: np.ndarray        # (n_shard,) float64 normalized within-shard CDF


class SelectionEngine:
    """Executes batches of SUPG queries over a list of score shards."""

    def __init__(self, shards: Sequence, num_bins: int = 4096,
                 use_kernel: Optional[bool] = None,
                 weight_schemes: Sequence[str] = ("sqrt",),
                 kappa: float = sampling.DEFENSIVE_KAPPA,
                 cache_flat: Optional[bool] = None):
        # ScoreStore (or anything exposing `.scores`) passes its memmap
        # through untouched; ndarray shards are viewed, not copied.
        raw_shards = [getattr(s, "scores", s) for s in shards]
        # Flat gather cache: for in-RAM shards a one-time concatenation
        # turns score_at into a single fancy gather. Defaults off for
        # memmap-backed (out-of-core) shards, which keep the routed path.
        # (Decide on the raw objects: np.asarray strips the memmap subclass.)
        if cache_flat is None:
            cache_flat = not any(isinstance(s, np.memmap)
                                 for s in raw_shards)
        self.shards = [np.asarray(s) for s in raw_shards]
        self.offsets = np.concatenate(
            [[0], np.cumsum([s.shape[0] for s in self.shards])]).astype(
                np.int64)
        self.n_total = int(self.offsets[-1])
        self.num_bins = num_bins
        self.kappa = float(kappa)
        self._flat = (np.concatenate(
            [np.asarray(s, np.float32) for s in self.shards])
            if cache_flat and self.shards else None)

        # 1. per-shard sketches (kernel path by default) + global merge.
        self.shard_sketches = [
            binned.build_sketch(jnp.asarray(s, jnp.float32), num_bins,
                                use_kernel=use_kernel)
            for s in self.shards]
        self.sketch = binned.merge_sketches(*self.shard_sketches)

        # 2. global weight normalizers from the merged sketch — the only
        #    cross-shard reductions sampling ever needs.
        z_sqrt, z_prop, n_sk = binned.weight_normalizers(self.sketch)
        self._z = {"sqrt": float(z_sqrt), "prop": float(z_prop)}
        # 3. shard-level raw masses from the per-shard sketches.
        self._shard_raw = {
            "sqrt": np.asarray([float(jnp.sum(sk.sum_w))
                                for sk in self.shard_sketches]),
            "prop": np.asarray([float(jnp.sum(sk.sum_a))
                                for sk in self.shard_sketches]),
        }
        self._shard_counts = np.asarray(
            [s.shape[0] for s in self.shards], np.float64)

        # 4. cached per-shard sampling state (CDFs) for the requested
        #    schemes; other schemes build lazily on first use.
        self._sampling_cache: Dict[Tuple[str, float], List[
            _ShardSamplingState]] = {}
        for scheme in weight_schemes:
            self._sampling_state(scheme, self.kappa)

    # -- cached state ---------------------------------------------------

    def _sampling_state(self, scheme: str,
                        kappa: float) -> List[_ShardSamplingState]:
        cache_key = (scheme, float(kappa))
        if cache_key not in self._sampling_cache:
            z = max(self._z[scheme], 1e-30)
            states = []
            for scores in self.shards:
                if scores.shape[0] == 0:
                    states.append(_ShardSamplingState(
                        p_global=np.empty(0, np.float32),
                        cdf=np.empty(0, np.float64)))
                    continue
                a = np.clip(np.asarray(scores, np.float32), 0.0, 1.0)
                raw = np.sqrt(a) if scheme == "sqrt" else a
                p_global = ((1.0 - kappa) * raw / z
                            + kappa / self.n_total).astype(np.float32)
                states.append(_ShardSamplingState(
                    p_global=p_global,
                    cdf=sampling.normalized_cdf(p_global)))
            self._sampling_cache[cache_key] = states
        return self._sampling_cache[cache_key]

    def _shard_masses(self, scheme: str, kappa: float) -> np.ndarray:
        raws = self._shard_raw[scheme]
        z = max(self._z[scheme], 1e-30)
        mass = (1.0 - kappa) * raws / z \
            + kappa * self._shard_counts / self.n_total
        return mass / mass.sum()

    # -- sampling -------------------------------------------------------

    def draw_sample(self, key, s: int, scheme: str = "sqrt",
                    kappa: Optional[float] = None):
        """Global with-replacement draws; returns (global_idx, m).

        Two-level: multinomial over cached shard masses, then vectorized
        inverse-CDF draws against the cached per-shard CDFs. The joint draw
        probability equals the global defensive-mixed p(x) exactly (shard
        mass is the shard's total p(x) by construction), so
        m(x) = (1/n) / p(x) is globally correct.
        """
        if scheme == "uniform":
            idx = jax.random.randint(key, (s,), 0, self.n_total)
            return np.asarray(idx, np.int64), np.ones(s, np.float32)
        kappa = self.kappa if kappa is None else kappa
        states = self._sampling_state(scheme, kappa)
        mass = self._shard_masses(scheme, kappa)
        k_alloc, k_draw = jax.random.split(key)
        alloc = np.asarray(jax.random.categorical(
            k_alloc, jnp.log(jnp.asarray(mass, jnp.float32)), shape=(s,)))
        u = np.asarray(jax.random.uniform(k_draw, (s,)), np.float64)
        out_idx = np.empty(s, np.int64)
        out_m = np.empty(s, np.float32)
        for sh, state in enumerate(states):
            take = np.nonzero(alloc == sh)[0]
            if take.size == 0:
                continue
            local = sampling.draw_from_cdf(state.cdf, u[take])
            out_idx[take] = self.offsets[sh] + local
            out_m[take] = (1.0 / self.n_total) / np.maximum(
                state.p_global[local], 1e-38)
        return out_idx, out_m

    def score_at(self, global_idx) -> np.ndarray:
        """Vectorized gather: one flat fancy gather when the concatenation
        cache is live, else searchsorted shard routing + per-shard fancy
        indexing (works unchanged on memmap shards)."""
        gi = np.asarray(global_idx, np.int64)
        if self._flat is not None:
            return self._flat[gi]
        sh = np.searchsorted(self.offsets, gi, side="right") - 1
        local = gi - self.offsets[sh]
        out = np.empty(gi.shape[0], np.float32)
        # Group draws by shard with one argsort, then gather each shard's
        # segment with a single fancy index (one touch per shard).
        order = np.argsort(sh, kind="stable")
        seg_bounds = np.searchsorted(sh[order],
                                     np.arange(len(self.shards) + 1))
        for shard_id in range(len(self.shards)):
            seg = order[seg_bounds[shard_id]:seg_bounds[shard_id + 1]]
            if seg.size:
                out[seg] = np.asarray(
                    self.shards[shard_id][local[seg]], np.float32)
        return out

    # -- query ----------------------------------------------------------

    def run(self, key, oracle_fn: Callable, query: SUPGQuery) \
            -> ShardedSelection:
        key = jax.random.PRNGKey(0) if key is None else key
        oracle = BudgetedOracle(oracle_fn, query.budget)
        s = query.budget
        if query.target == "recall":
            scheme = {"is": query.weight_scheme, "uniform": "uniform",
                      "noci": "uniform"}[query.method]
            idx, m = self.draw_sample(key, s, scheme)
            o_s = oracle(idx)
            a_s = self.score_at(idx)
            if query.method == "noci":
                res = thresholds.tau_unoci_r(a_s, o_s, query.gamma)
            else:
                res = thresholds.tau_ci_r(a_s, o_s, m, query.gamma,
                                          query.delta)
            tau = float(res.tau)
        else:
            k0, k1 = jax.random.split(key)
            if query.method == "is" and query.two_stage:
                idx0, m0 = self.draw_sample(k0, s // 2, query.weight_scheme)
                o0 = oracle(idx0)
                _, rank = thresholds.pt_stage1_nmatch(
                    o0, m0, self.n_total, query.gamma, query.delta)
                tau_dp = float(binned.rank_to_threshold(self.sketch,
                                                        int(rank)))
                # stage 2: uniform on D' via per-shard masked draws
                idx1 = self._uniform_in_region(k1, s - s // 2, tau_dp)
                o1 = oracle(idx1)
                a1 = self.score_at(idx1)
                res = thresholds.tau_ci_p(a1, o1, query.gamma,
                                          query.delta / 2.0,
                                          min_step=query.min_step)
            else:
                scheme = ("uniform" if query.method in ("uniform", "noci")
                          else query.weight_scheme)
                idx, m = self.draw_sample(k0, s, scheme)
                o_s = oracle(idx)
                a_s = self.score_at(idx)
                if query.method == "noci":
                    res = thresholds.tau_unoci_p(a_s, o_s, query.gamma)
                else:
                    res = thresholds.tau_ci_p(
                        a_s, o_s, query.gamma, query.delta,
                        m_s=None if scheme == "uniform" else m,
                        min_step=query.min_step)
            tau = float(res.tau)

        masks = [np.asarray(s_arr >= tau) for s_arr in self.shards]
        pos = oracle.labeled_positives()
        self._fold_positives(masks, pos)
        return ShardedSelection(masks=masks, tau=tau,
                                oracle_calls=oracle.calls_used,
                                sampled_positive_global=pos)

    def run_joint(self, key, oracle_fn: Callable,
                  query: JointSUPGQuery) -> ShardedSelection:
        """Engine-level JT query (Appendix A): RT stage at gamma_recall,
        then exhaustive oracle filtering of the candidate set. The returned
        masks hold only oracle-verified positives (precision exactly 1.0);
        oracle usage beyond the RT stage is unbounded by design."""
        rt = SUPGQuery(target="recall", gamma=query.gamma_recall,
                       delta=query.delta, budget=query.stage_budget,
                       method=query.method)
        sel = self.run(key, oracle_fn, rt)
        oracle = BudgetedOracle(oracle_fn, budget=self.n_total)
        masks = []
        for sh, m in enumerate(sel.masks):
            local = np.nonzero(m)[0]
            keep = np.zeros_like(m)
            if local.size:
                labels = oracle(self.offsets[sh] + local)
                keep[local] = labels > 0.5
            masks.append(keep)
        return ShardedSelection(
            masks=masks, tau=sel.tau,
            oracle_calls=sel.oracle_calls + oracle.calls_used,
            sampled_positive_global=sel.sampled_positive_global)

    def run_many(self, key, oracle_fn: Callable,
                 queries: Sequence[Union[SUPGQuery, JointSUPGQuery]]) \
            -> List[ShardedSelection]:
        """Serve a batch of RT / PT / JT queries off one cached state.

        The sketch, shard masses, and per-scheme CDFs were built once at
        construction; each query only pays O(s) sampling + O(n) mask
        emission. Budgets are accounted per query (each gets its own
        BudgetedOracle), matching independent `run` calls semantically.
        """
        keys = jax.random.split(
            jax.random.PRNGKey(0) if key is None else key, len(queries))
        out = []
        for k, q in zip(keys, queries):
            if isinstance(q, JointSUPGQuery):
                out.append(self.run_joint(k, oracle_fn, q))
            else:
                out.append(self.run(k, oracle_fn, q))
        return out

    # -- helpers --------------------------------------------------------

    def _fold_positives(self, masks: List[np.ndarray], pos: np.ndarray):
        """Fold labeled positives into their shard masks (Algorithm 1's R1)
        via one vectorized searchsorted route + per-shard scatter."""
        if pos.size == 0:
            return
        sh = np.searchsorted(self.offsets, pos, side="right") - 1
        local = pos - self.offsets[sh]
        for shard_id in np.unique(sh):
            masks[shard_id][local[sh == shard_id]] = True

    def _uniform_in_region(self, key, s, tau):
        """Uniform draws from {A >= tau} across shards.

        Shards whose region is empty get exactly zero categorical mass (no
        floor), so draws can never be clamped onto records below tau. If the
        region is globally empty the draws fall back to uniform over all
        records — tau estimation then sees an unrestricted uniform sample,
        which keeps the estimator valid (D' restriction is an efficiency
        device, never a correctness requirement).
        """
        counts = np.asarray([int((np.asarray(sh) >= tau).sum())
                             for sh in self.shards], np.float64)
        total = counts.sum()
        if total == 0:
            idx = jax.random.randint(key, (s,), 0, self.n_total)
            return np.asarray(idx, np.int64)
        mass = counts / total
        k_alloc, k_draw = jax.random.split(key)
        # log(0) = -inf => empty shards are excluded from the categorical.
        alloc = np.asarray(jax.random.categorical(
            k_alloc, jnp.log(jnp.asarray(mass, jnp.float32)), shape=(s,)))
        out = np.empty(s, np.int64)
        dkeys = jax.random.split(k_draw, len(self.shards))
        for sh, scores in enumerate(self.shards):
            take = np.nonzero(alloc == sh)[0]
            if take.size == 0:
                continue
            region = np.nonzero(np.asarray(scores) >= tau)[0]
            pick = np.asarray(jax.random.randint(
                dkeys[sh], (take.size,), 0, region.size))
            out[take] = self.offsets[sh] + region[pick]
        return out
