"""Distributed SUPG selection engine — the production query executor.

Ties the selection plane together over sharded score stores:

  1. build the global ScoreSketch (one psum of 48 KiB; Pallas score_hist
     kernel per shard on TPU),
  2. draw the oracle sample with exact global with-replacement semantics
     via two-level sampling (multinomial over shard masses -> within-shard
     inverse-CDF draws with globally-correct m(x) factors),
  3. estimate tau with the exact sample-level estimators (Algorithms 2-5 —
     the sample is tiny, so estimation is never distributed),
  4. resolve the two-stage D' restriction through the sketch
     (rank -> conservative bin edge, superset property), and
  5. emit per-shard selection masks (zero-communication local filters).

Shards here are host-local arrays (np / memmap via data.pipeline.ScoreStore);
on a real fleet each worker holds its shard and the driver runs where the
coordinator lives. Collective math matches core/distributed.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binned, sampling, thresholds
from repro.core.oracle import BudgetedOracle
from repro.core.queries import SUPGQuery


@dataclasses.dataclass
class ShardedSelection:
    masks: List[np.ndarray]        # per-shard boolean selection masks
    tau: float
    oracle_calls: int
    sampled_positive_global: np.ndarray   # global ids of labeled positives

    @property
    def total_selected(self) -> int:
        return int(sum(m.sum() for m in self.masks)) + \
            int(self.sampled_positive_global.size and
                sum(1 for _ in ()) or 0)


class SelectionEngine:
    """Executes SUPG queries over a list of score shards."""

    def __init__(self, shards: Sequence[np.ndarray], num_bins: int = 4096,
                 use_kernel: bool = False):
        self.shards = [np.asarray(s, np.float32) for s in shards]
        self.offsets = np.concatenate(
            [[0], np.cumsum([s.shape[0] for s in self.shards])])
        self.n_total = int(self.offsets[-1])
        self.num_bins = num_bins
        # 1. global sketch: per-shard pass + merge (psum on a fleet)
        self.sketch = binned.merge_sketches(*[
            binned.build_sketch(jnp.asarray(s), num_bins,
                                use_kernel=use_kernel)
            for s in self.shards])

    # -- sampling -------------------------------------------------------

    def _shard_masses(self, scheme: str, kappa: float = 0.1):
        raws = np.asarray([
            float(np.sum(np.sqrt(np.clip(s, 0, 1)) if scheme == "sqrt"
                         else np.clip(s, 0, 1))) for s in self.shards])
        counts = np.asarray([s.shape[0] for s in self.shards], np.float64)
        z = max(raws.sum(), 1e-30)
        mass = (1 - kappa) * raws / z + kappa * counts / counts.sum()
        return mass / mass.sum(), raws.sum(), counts.sum()

    def draw_sample(self, key, s: int, scheme: str = "sqrt",
                    kappa: float = 0.1):
        """Global with-replacement draws; returns (global_idx, m)."""
        if scheme == "uniform":
            idx = jax.random.randint(key, (s,), 0, self.n_total)
            return np.asarray(idx), np.ones(s, np.float32)
        mass, raw_total, n_total = self._shard_masses(scheme, kappa)
        k_alloc, k_draw = jax.random.split(key)
        alloc = np.asarray(jax.random.categorical(
            k_alloc, jnp.log(jnp.asarray(mass, jnp.float32)), shape=(s,)))
        out_idx = np.empty(s, np.int64)
        out_m = np.empty(s, np.float32)
        draw_keys = jax.random.split(k_draw, len(self.shards))
        for sh, scores in enumerate(self.shards):
            take = np.nonzero(alloc == sh)[0]
            if take.size == 0:
                continue
            a = np.clip(scores, 0, 1)
            raw = np.sqrt(a) if scheme == "sqrt" else a
            p_global = (1 - kappa) * raw / raw_total + kappa / n_total
            p_cond = p_global / p_global.sum()
            ws = sampling.sample_weighted(draw_keys[sh],
                                          jnp.asarray(p_cond), take.size)
            local = np.asarray(ws.indices)
            out_idx[take] = self.offsets[sh] + local
            # joint draw probability = mass[sh] * p_cond = p_global exactly
            # (mass[sh] is the shard's total p_global by construction)
            out_m[take] = (1.0 / n_total) / np.maximum(p_global[local],
                                                       1e-38)
        return out_idx, out_m

    def score_at(self, global_idx) -> np.ndarray:
        gi = np.asarray(global_idx, np.int64)
        sh = np.searchsorted(self.offsets, gi, side="right") - 1
        out = np.empty(gi.shape[0], np.float32)
        for i, (s, g) in enumerate(zip(sh, gi)):
            out[i] = self.shards[s][g - self.offsets[s]]
        return out

    # -- query ----------------------------------------------------------

    def run(self, key, oracle_fn: Callable, query: SUPGQuery) \
            -> ShardedSelection:
        oracle = BudgetedOracle(oracle_fn, query.budget)
        s = query.budget
        if query.target == "recall":
            scheme = {"is": query.weight_scheme, "uniform": "uniform",
                      "noci": "uniform"}[query.method]
            idx, m = self.draw_sample(key, s, scheme)
            o_s = oracle(idx)
            a_s = self.score_at(idx)
            if query.method == "noci":
                res = thresholds.tau_unoci_r(a_s, o_s, query.gamma)
            else:
                res = thresholds.tau_ci_r(a_s, o_s, m, query.gamma,
                                          query.delta)
            tau = float(res.tau)
        else:
            k0, k1 = jax.random.split(key)
            if query.method == "is" and query.two_stage:
                idx0, m0 = self.draw_sample(k0, s // 2, query.weight_scheme)
                o0 = oracle(idx0)
                _, rank = thresholds.pt_stage1_nmatch(
                    o0, m0, self.n_total, query.gamma, query.delta)
                tau_dp = float(binned.rank_to_threshold(self.sketch,
                                                        int(rank)))
                # stage 2: uniform on D' via per-shard masked draws
                idx1 = self._uniform_in_region(k1, s - s // 2, tau_dp)
                o1 = oracle(idx1)
                a1 = self.score_at(idx1)
                res = thresholds.tau_ci_p(a1, o1, query.gamma,
                                          query.delta / 2.0,
                                          min_step=query.min_step)
            else:
                scheme = ("uniform" if query.method in ("uniform", "noci")
                          else query.weight_scheme)
                idx, m = self.draw_sample(k0, s, scheme)
                o_s = oracle(idx)
                a_s = self.score_at(idx)
                if query.method == "noci":
                    res = thresholds.tau_unoci_p(a_s, o_s, query.gamma)
                else:
                    res = thresholds.tau_ci_p(
                        a_s, o_s, query.gamma, query.delta,
                        m_s=None if scheme == "uniform" else m,
                        min_step=query.min_step)
            tau = float(res.tau)

        masks = [s_arr >= tau for s_arr in self.shards]
        pos = oracle.labeled_positives()
        # fold labeled positives into their shard masks
        for g in pos:
            sh = int(np.searchsorted(self.offsets, g, side="right") - 1)
            masks[sh][g - self.offsets[sh]] = True
        return ShardedSelection(masks=masks, tau=tau,
                                oracle_calls=oracle.calls_used,
                                sampled_positive_global=pos)

    def _uniform_in_region(self, key, s, tau):
        """Uniform draws from {A >= tau} across shards."""
        counts = np.asarray([(sh >= tau).sum() for sh in self.shards],
                            np.float64)
        mass = counts / max(counts.sum(), 1)
        k_alloc, k_draw = jax.random.split(key)
        alloc = np.asarray(jax.random.categorical(
            k_alloc, jnp.log(jnp.asarray(np.maximum(mass, 1e-30),
                                         jnp.float32)), shape=(s,)))
        out = np.empty(s, np.int64)
        dkeys = jax.random.split(k_draw, len(self.shards))
        for sh, scores in enumerate(self.shards):
            take = np.nonzero(alloc == sh)[0]
            if take.size == 0:
                continue
            region = np.nonzero(scores >= tau)[0]
            pick = np.asarray(jax.random.randint(
                dkeys[sh], (take.size,), 0, max(region.size, 1)))
            out[take] = self.offsets[sh] + region[np.minimum(
                pick, max(region.size - 1, 0))]
        return out
