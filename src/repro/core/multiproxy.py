"""Multiple-proxy fusion (the paper's Section 8 future-work direction).

Given M proxy score vectors (e.g. a motion detector, a cheap CNN, and a
BERT-sized scorer in the legal-discovery case), SUPG's algorithms consume a
single A(x). We fuse with a *stacked logistic* model fit on a small labeled
pilot sample (part of the oracle budget):

    A_fused(x) = sigma( b0 + sum_m b_m * logit(A_m(x)) )

Fitting uses the importance-reweighted pilot labels, so the pilot can come
from any defensive-mixed proposal. Because the SUPG guarantees never assume
anything about A (Section 5.3), running the standard estimators on A_fused
preserves validity; fusion only improves the quality/variance side. A
pilot/holdout split guards against the fused proxy overfitting M >> pilot.
"""
from __future__ import annotations

import numpy as np

from repro.core.calibration import _logit


def fit_fusion(pilot_scores, pilot_labels, weights=None, iters=80,
               l2=1e-3):
    """pilot_scores: (s, M); labels: (s,). Returns beta (M+1,)."""
    x = _logit(np.asarray(pilot_scores, np.float64))
    y = np.asarray(pilot_labels, np.float64)
    s, m = x.shape
    w = np.ones(s) if weights is None else np.asarray(weights, np.float64)
    xb = np.concatenate([np.ones((s, 1)), x], axis=1)
    beta = np.zeros(m + 1)
    for _ in range(iters):
        p = 1.0 / (1.0 + np.exp(-xb @ beta))
        g = xb.T @ (w * (p - y)) + l2 * beta
        h = (xb * (w * p * (1 - p))[:, None]).T @ xb + l2 * np.eye(m + 1)
        try:
            step = np.linalg.solve(h, g)
        except np.linalg.LinAlgError:
            break
        beta = beta - step
        if np.max(np.abs(step)) < 1e-10:
            break
    return beta


def apply_fusion(scores, beta):
    """scores: (n, M) -> fused (n,) in [0,1]."""
    x = _logit(np.asarray(scores, np.float64))
    xb = np.concatenate([np.ones((x.shape[0], 1)), x], axis=1)
    return (1.0 / (1.0 + np.exp(-xb @ beta))).astype(np.float32)


def fuse_proxies(key_seed, all_scores, oracle_fn, pilot_budget=500):
    """Spend `pilot_budget` oracle calls on a uniform pilot, fit the fusion,
    return (fused_scores, pilot_calls_used). all_scores: (n, M)."""
    n = all_scores.shape[0]
    rng = np.random.default_rng(key_seed)
    pilot_idx = rng.choice(n, size=min(pilot_budget, n), replace=False)
    pilot_labels = np.asarray(oracle_fn(pilot_idx), np.float32)
    beta = fit_fusion(all_scores[pilot_idx], pilot_labels)
    return apply_fusion(all_scores, beta), len(pilot_idx)
