"""Fault tolerance for the oracle channel: taxonomy, retries, breaker.

The paper's operational model treats the oracle as "often a human or an
expensive machine learning model" (§1) — in production that is a flaky
remote service: calls time out, rate-limit, and return malformed
batches. This module gives `core.oracle.BatchingOracle` the pieces it
needs to survive that without weakening any statistical guarantee:

Error taxonomy
    `OracleTransientError` (and its subclasses `OracleTimeoutError`,
    `OracleMalformedError`) marks failures worth retrying;
    `OracleFatalError` marks ones that are not. Any exception may carry
    a boolean ``retryable`` attribute to classify itself (the serving
    plane's `RateLimitError` sets ``retryable = False`` — a request
    that exceeds bucket capacity can never succeed); unknown exceptions
    fall back to `is_retryable`'s built-in transport heuristics.

`RetryPolicy`
    Exponential backoff with *deterministic* jitter: the jitter is a
    pure hash of (seed, attempt, salt), never global randomness, and
    the sleep function is injectable — exactly like `serve.TokenBucket`
    — so tests drive retries without wall-clock time. Retries re-ask
    the oracle for the *same* records; for a pure oracle the labels are
    identical whenever they arrive, so retries can never change a
    committed result (see `docs/guarantees.md`, "Failure semantics").

`CircuitBreaker`
    closed → open after N consecutive exhausted micro-batches →
    half-open probe after a cooldown. The channel consults it once per
    micro-batch, before the retry loop — a granted half-open probe
    covers every attempt of that chunk, and the chunk's final outcome
    settles the probe; the serving plane consults it at admission so a
    down oracle sheds load with a retry-after hint instead of queueing
    work that will die.

`call_with_timeout`
    The per-call watchdog: runs the oracle callable on a sacrificial
    thread and raises `OracleTimeoutError` if it overruns the deadline
    (the runaway call's eventual result is discarded, never cached).

>>> sleeps = []
>>> policy = RetryPolicy(max_attempts=3, base_delay_s=0.1, jitter=0.0,
...                      sleep=sleeps.append)
>>> [round(policy.backoff_s(a), 3) for a in (1, 2, 3)]
[0.1, 0.2, 0.4]
>>> policy.backoff_s(2, salt=7) == policy.backoff_s(2, salt=7)  # pure
True
>>> is_retryable(OracleTimeoutError("slow")), is_retryable(ValueError())
(True, False)
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional, Tuple

__all__ = [
    "OracleError", "OracleTransientError", "OracleTimeoutError",
    "OracleMalformedError", "OracleFatalError", "CircuitOpenError",
    "is_retryable", "RetryPolicy", "CircuitBreaker", "call_with_timeout",
]


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------

class OracleError(RuntimeError):
    """Base class for typed oracle-channel failures."""


class OracleFatalError(OracleError):
    """A failure that retrying cannot fix (never retried)."""

    retryable = False


class OracleTransientError(OracleError):
    """A failure expected to clear on retry (network blip, 5xx, ...)."""

    retryable = True


class OracleTimeoutError(OracleTransientError):
    """An oracle call overran its per-call deadline (watchdog fired)."""


class OracleMalformedError(OracleTransientError, ValueError):
    """The oracle returned a malformed batch (wrong length, non-finite
    labels). Rejected before caching and retried — a torn response must
    never poison the shared label cache."""


class CircuitOpenError(OracleError):
    """The circuit breaker is open: the channel (or server) is shedding
    work instead of hammering a down oracle. `retry_after_s` hints when
    the next probe will be allowed."""

    retryable = False

    def __init__(self, message: str, retry_after_s: float = 0.0):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)


#: Built-in exception types treated as transient when the exception does
#: not classify itself via a ``retryable`` attribute. These are the
#: shapes real transports raise: socket resets, OS-level I/O errors,
#: stdlib timeouts.
_TRANSIENT_BUILTINS = (ConnectionError, TimeoutError, InterruptedError,
                       OSError)

#: `OSError` subclasses that are deterministic, not transport blips: a
#: missing file, a permission wall, or a path-shape error will not heal
#: on retry — retrying one just burns the whole backoff budget on the
#: drain thread (under the channel lock) before failing anyway.
_DETERMINISTIC_OSERRORS = (FileNotFoundError, FileExistsError,
                           IsADirectoryError, NotADirectoryError,
                           PermissionError)


def is_retryable(err: BaseException) -> bool:
    """Classify an exception as retryable (transient) or fatal.

    An explicit boolean ``retryable`` attribute on the exception wins
    (the taxonomy classes above carry one; `serve.RateLimitError`
    declares itself fatal); otherwise common transport exception types
    are transient — except the deterministic `OSError` subclasses like
    `FileNotFoundError` and `PermissionError`, which no retry can fix —
    and everything else — `ValueError`, assertion failures, arbitrary
    logic errors — is fatal, because retrying a deterministic bug just
    burns the rate budget.
    """
    flag = getattr(err, "retryable", None)
    if flag is not None:
        return bool(flag)
    if isinstance(err, _DETERMINISTIC_OSERRORS):
        return False
    return isinstance(err, _TRANSIENT_BUILTINS)


# ---------------------------------------------------------------------------
# Retry policy — exponential backoff, deterministic jitter
# ---------------------------------------------------------------------------

def _hash01(*parts: int) -> float:
    """Pure integer hash of `parts` into [0, 1) — splitmix64-flavored.

    This is the jitter source: no global RNG, no wall clock, so a retry
    schedule is a deterministic function of (seed, attempt, salt) and a
    faulty run replays bit-for-bit.
    """
    x = 0x9E3779B97F4A7C15
    for p in parts:
        x = (x ^ (int(p) & 0xFFFFFFFFFFFFFFFF)) & 0xFFFFFFFFFFFFFFFF
        x = (x * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        x ^= x >> 31
    return (x >> 11) / float(1 << 53)


@dataclasses.dataclass
class RetryPolicy:
    """How the channel retries a failed oracle micro-batch.

    `max_attempts` bounds total invocations (1 = no retries). Backoff
    before retry ``attempt`` (1-based: the wait after the attempt-th
    failure) is ``base_delay_s * multiplier**(attempt-1)``, capped at
    `max_delay_s`, then shrunk by up to ``jitter`` fraction using the
    deterministic `_hash01` of (seed, attempt, salt) — `salt` lets the
    channel decorrelate concurrent micro-batches without randomness.
    `sleep` and `classify` are injectable for tests (`classify` defaults
    to `is_retryable`).
    """

    max_attempts: int = 4
    base_delay_s: float = 0.05
    multiplier: float = 2.0
    max_delay_s: float = 2.0
    jitter: float = 0.25
    seed: int = 0
    sleep: Callable[[float], None] = time.sleep
    classify: Optional[Callable[[BaseException], bool]] = None

    def __post_init__(self):
        """Validate the knobs once, loudly."""
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_delay_s < 0 or self.max_delay_s < 0:
            raise ValueError("delays must be >= 0")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1]")

    def retryable(self, err: BaseException) -> bool:
        """True when `err` is worth another attempt under this policy."""
        return (self.classify or is_retryable)(err)

    def backoff_s(self, attempt: int, salt: int = 0) -> float:
        """Deterministic backoff before retry `attempt` (1-based)."""
        raw = min(self.max_delay_s,
                  self.base_delay_s * self.multiplier ** (attempt - 1))
        if self.jitter <= 0.0:
            return raw
        return raw * (1.0 - self.jitter * _hash01(self.seed, attempt, salt))


# ---------------------------------------------------------------------------
# Circuit breaker — closed -> open -> half-open probe
# ---------------------------------------------------------------------------

class CircuitBreaker:
    """Sheds oracle traffic after `failure_threshold` consecutive
    exhausted micro-batches.

    closed: everything flows; each exhausted micro-batch counts, each
    success resets the count. open: `allow()` rejects until
    `reset_timeout_s` has elapsed on the injectable clock, then flips
    to half-open and grants exactly one probe. half-open: the probe's
    outcome decides — success closes the circuit, failure re-opens it
    (and restarts the cooldown). Thread-safe; transition counters
    (`opens`, `closes`, `probes`, `rejections`) feed `ServerStats`.

    >>> t = [0.0]
    >>> br = CircuitBreaker(failure_threshold=2, reset_timeout_s=10.0,
    ...                     clock=lambda: t[0])
    >>> br.record_failure(); br.state
    'closed'
    >>> br.record_failure(); br.state          # threshold hit
    'open'
    >>> br.allow()                             # cooling down
    False
    >>> t[0] = 11.0
    >>> br.allow(), br.state                   # cooldown over: one probe
    (True, 'half-open')
    >>> br.allow()                             # probe already in flight
    False
    >>> br.record_success(); br.state
    'closed'
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"

    def __init__(self, failure_threshold: int = 5,
                 reset_timeout_s: float = 30.0, *,
                 clock: Callable[[], float] = time.monotonic):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if reset_timeout_s < 0:
            raise ValueError("reset_timeout_s must be >= 0")
        self.failure_threshold = int(failure_threshold)
        self.reset_timeout_s = float(reset_timeout_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._failures = 0
        self._opened_at = 0.0
        self.opens = 0          # closed/half-open -> open transitions
        self.closes = 0         # open/half-open -> closed transitions
        self.probes = 0         # half-open probes granted
        self.rejections = 0     # allow() == False occurrences

    @property
    def state(self) -> str:
        """Current state name (no transition side effects)."""
        with self._lock:
            return self._state

    def allow(self) -> bool:
        """May the caller invoke the oracle now?

        closed: yes. open: no until the cooldown elapses, at which point
        the circuit flips to half-open and this call is the one granted
        probe. half-open: no (a probe is already in flight).
        """
        with self._lock:
            if self._state == self.CLOSED:
                return True
            if self._state == self.OPEN and \
                    self._clock() - self._opened_at >= self.reset_timeout_s:
                self._state = self.HALF_OPEN
                self.probes += 1
                return True
            self.rejections += 1
            return False

    def retry_after_s(self) -> float:
        """Seconds until the open circuit will grant a probe (0 when the
        circuit is not open or the cooldown already elapsed) — the
        retry-after hint `CircuitOpenError` carries."""
        with self._lock:
            if self._state != self.OPEN:
                return 0.0
            return max(0.0, self.reset_timeout_s
                       - (self._clock() - self._opened_at))

    def record_success(self) -> None:
        """A micro-batch labeled cleanly: reset the failure streak and
        close the circuit (a successful half-open probe heals it)."""
        with self._lock:
            self._failures = 0
            if self._state != self.CLOSED:
                self._state = self.CLOSED
                self.closes += 1

    def record_failure(self) -> None:
        """A micro-batch exhausted its retries (or failed fatally):
        extend the streak; trip open at the threshold, and re-open
        immediately on a failed half-open probe."""
        with self._lock:
            self._failures += 1
            if self._state == self.HALF_OPEN or (
                    self._state == self.CLOSED
                    and self._failures >= self.failure_threshold):
                self._state = self.OPEN
                self._opened_at = self._clock()
                self._failures = 0
                self.opens += 1


# ---------------------------------------------------------------------------
# Per-call watchdog
# ---------------------------------------------------------------------------

def call_with_timeout(fn: Callable, arg, timeout_s: float):
    """Invoke ``fn(arg)`` with a hard deadline.

    The call runs on a fresh sacrificial daemon thread; if it does not
    finish within `timeout_s` seconds an `OracleTimeoutError` is raised
    and the runaway call is abandoned — whatever it eventually returns
    is discarded, so a late answer can never reach the label cache. A
    thread per call is cheap next to an oracle invocation (the whole
    point of the channel is that ``fn`` is expensive).

    Abandoned means exactly that: Python offers no safe way to kill the
    runaway thread, so it keeps executing ``fn`` until it returns on
    its own. A caller that retries after the timeout (the channel's
    `RetryPolicy` does) therefore re-invokes ``fn`` while the abandoned
    call may still be running — ``fn`` must tolerate concurrent
    invocation. Pure functions and `testing.FaultInjector` (which locks
    internally) qualify; an oracle with shared mutable state needs its
    own synchronization.
    """
    box: List[Tuple[str, object]] = []
    done = threading.Event()

    def runner():
        try:
            box.append(("ok", fn(arg)))
        except BaseException as e:  # noqa: BLE001 — rethrown below
            box.append(("err", e))
        finally:
            done.set()

    t = threading.Thread(target=runner, daemon=True,
                         name="repro-oracle-call")
    t.start()
    if not done.wait(timeout_s):
        raise OracleTimeoutError(
            f"oracle call overran its {timeout_s:g}s deadline "
            f"(batch of {getattr(arg, 'size', len(arg))} records); "
            f"the call was abandoned")
    kind, val = box[0]
    if kind == "err":
        raise val
    return val
