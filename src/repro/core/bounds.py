"""Confidence bounds (Lemma 1 of the paper) and union-bound helpers.

The paper's Lemma 1 (asymptotic, via Berry-Esseen-controlled t-statistics):

    Pr[ mu_hat >= mu + sigma/sqrt(s) * sqrt(2 log 1/delta) ] <= delta
    Pr[ mu_hat <= mu - sigma/sqrt(s) * sqrt(2 log 1/delta) ] <= delta

yielding the helper functions (Eqs. 7-8):

    UB(mu, sigma, s, delta) = mu + sigma/sqrt(s) * sqrt(2 log 1/delta)
    LB(mu, sigma, s, delta) = mu - sigma/sqrt(s) * sqrt(2 log 1/delta)

All functions here are pure jnp and safe under jit/vmap/shard_map. ``sigma``
is the *sample* standard deviation (plug-in estimate), per Section 5.2.
"""
from __future__ import annotations

import jax.numpy as jnp


def gaussian_width(sigma, s, delta):
    """Half-width sigma/sqrt(s) * sqrt(2 log(1/delta)) from Lemma 1."""
    sigma = jnp.asarray(sigma, jnp.float32)
    s = jnp.asarray(s, jnp.float32)
    delta = jnp.asarray(delta, jnp.float32)
    # Guard s == 0 (empty prefix in vectorized candidate scans): width -> +inf
    safe_s = jnp.maximum(s, 1.0)
    w = sigma / jnp.sqrt(safe_s) * jnp.sqrt(2.0 * jnp.log(1.0 / delta))
    return jnp.where(s > 0, w, jnp.inf)


def ub(mu, sigma, s, delta):
    """Upper confidence bound UB(mu, sigma, s, delta) — Eq. (7)."""
    return jnp.asarray(mu, jnp.float32) + gaussian_width(sigma, s, delta)


def lb(mu, sigma, s, delta):
    """Lower confidence bound LB(mu, sigma, s, delta) — Eq. (8)."""
    return jnp.asarray(mu, jnp.float32) - gaussian_width(sigma, s, delta)


def sample_mean_std(z, axis=-1):
    """Plug-in estimates (mu_hat, sigma_hat) used throughout Section 5.

    Uses the biased (1/n) variance as in the asymptotic t-statistic; at the
    paper's regime (s > 100) the 1/n vs 1/(n-1) distinction is immaterial.
    """
    z = jnp.asarray(z, jnp.float32)
    mu = jnp.mean(z, axis=axis)
    sigma = jnp.std(z, axis=axis)
    return mu, sigma


def weighted_mean_std(z, weights, axis=-1):
    """Mean/std of importance-reweighted samples ``z*m`` given multiplicities.

    For importance sampling we form the set {f(x) m(x)} and treat it as an
    i.i.d. sample of the reweighted estimator; weights here are sample
    *inclusion counts* (with-replacement draws can repeat records).
    """
    z = jnp.asarray(z, jnp.float32)
    w = jnp.asarray(weights, jnp.float32)
    tot = jnp.maximum(jnp.sum(w, axis=axis), 1e-30)
    mu = jnp.sum(w * z, axis=axis) / tot
    var = jnp.sum(w * (z - jnp.expand_dims(mu, axis)) ** 2, axis=axis) / tot
    return mu, jnp.sqrt(var)


def union_bound_split(delta, k):
    """delta/k failure-probability split for k simultaneous uses of Lemma 1."""
    return jnp.asarray(delta, jnp.float32) / jnp.asarray(k, jnp.float32)


def prefix_mean_std(z):
    """Vectorized (mu, sigma, n) of every prefix z[:i+1] of a 1-D array.

    Enables evaluating Lemma-1 bounds for *all* candidate thresholds in one
    pass (Algorithm 3 / 5 evaluate prefixes of the score-sorted sample).
    Returns arrays of shape z.shape with entry i describing prefix length i+1.
    """
    z = jnp.asarray(z, jnp.float32)
    n = jnp.arange(1, z.shape[-1] + 1, dtype=jnp.float32)
    csum = jnp.cumsum(z, axis=-1)
    csq = jnp.cumsum(z * z, axis=-1)
    mu = csum / n
    var = jnp.maximum(csq / n - mu * mu, 0.0)
    return mu, jnp.sqrt(var), n


def weighted_prefix_mean_std(z, w):
    """Weighted prefix statistics (mu, sigma, ess) of every prefix z[:i+1].

    Weights are sample multiplicities / importance masses. The effective
    sample size (Kish: (Σw)²/Σw²) is returned for use as ``s`` in Lemma 1 —
    it equals the prefix length exactly for unit weights.
    """
    z = jnp.asarray(z, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    n = jnp.cumsum(w, axis=-1)
    csum = jnp.cumsum(z * w, axis=-1)
    csq = jnp.cumsum(z * z * w, axis=-1)
    safe_n = jnp.maximum(n, 1e-30)
    mu = csum / safe_n
    var = jnp.maximum(csq / safe_n - mu * mu, 0.0)
    ess = (n * n) / jnp.maximum(jnp.cumsum(w * w, axis=-1), 1e-30)
    return mu, jnp.sqrt(var), ess


def masked_prefix_mean_std(z, mask):
    """Prefix statistics counting only entries where ``mask`` is True.

    Entry i gives (mu, sigma, n) over {z[j] : j <= i, mask[j]}. Used by the
    PT estimators where Z(tau) = {O(x) : A(x) >= tau} is a *subset* of the
    sample prefix (stage-2 samples may sit below the candidate threshold).
    """
    z = jnp.asarray(z, jnp.float32)
    m = jnp.asarray(mask, jnp.float32)
    n = jnp.cumsum(m, axis=-1)
    csum = jnp.cumsum(z * m, axis=-1)
    csq = jnp.cumsum(z * z * m, axis=-1)
    safe_n = jnp.maximum(n, 1.0)
    mu = csum / safe_n
    var = jnp.maximum(csq / safe_n - mu * mu, 0.0)
    return mu, jnp.sqrt(var), n
