"""Proxy-score calibration from sampled oracle labels.

Theorem 1's optimal √A weights assume the proxy is *approximately
calibrated* (A(x) ≈ Pr[O(x)=1 | A(x)]). Production proxies rarely are —
DNN confidences are systematically over-sharp. The guarantees never depend
on calibration (Section 5.3), but sample efficiency does, so recalibrating
the proxy with a few hundred of the already-budgeted labels is free quality.

Two standard monotone calibrators (monotonicity preserves the threshold
semantics of Section 4.2 — a monotone remap of A never changes D(tau) sets,
only the *weights* improve):

  * Platt scaling: logistic fit sigma(a*logit(s)+b) by Newton steps on the
    binomial likelihood — 2 parameters, robust at tiny positive counts;
  * isotonic binning: PAV (pool-adjacent-violators) over score-sorted
    labels with importance reweighting.

`calibrated_weights` composes either with the Theorem-1 √· rule.
"""
from __future__ import annotations

import numpy as np


def _logit(p, eps=1e-6):
    p = np.clip(p, eps, 1 - eps)
    return np.log(p / (1 - p))


def platt_fit(scores, labels, weights=None, iters=50):
    """Weighted logistic regression on logit(score) -> (a, b)."""
    x = _logit(np.asarray(scores, np.float64))
    y = np.asarray(labels, np.float64)
    w = np.ones_like(y) if weights is None else np.asarray(weights,
                                                           np.float64)
    a, b = 1.0, 0.0
    for _ in range(iters):
        z = a * x + b
        p = 1.0 / (1.0 + np.exp(-z))
        g_a = np.sum(w * (p - y) * x)
        g_b = np.sum(w * (p - y))
        s = np.maximum(w * p * (1 - p), 1e-12)
        h_aa = np.sum(s * x * x) + 1e-9
        h_ab = np.sum(s * x)
        h_bb = np.sum(s) + 1e-9
        det = h_aa * h_bb - h_ab * h_ab
        if det <= 1e-12:
            break
        da = (h_bb * g_a - h_ab * g_b) / det
        db = (h_aa * g_b - h_ab * g_a) / det
        a, b = a - da, b - db
        if abs(da) + abs(db) < 1e-10:
            break
    return float(a), float(b)


def platt_apply(scores, a, b):
    z = a * _logit(np.asarray(scores, np.float64)) + b
    return (1.0 / (1.0 + np.exp(-z))).astype(np.float32)


def isotonic_fit(scores, labels, weights=None):
    """PAV isotonic regression; returns (knot_scores, knot_values)."""
    order = np.argsort(scores)
    s = np.asarray(scores, np.float64)[order]
    y = np.asarray(labels, np.float64)[order]
    w = (np.ones_like(y) if weights is None
         else np.asarray(weights, np.float64)[order])
    # pool adjacent violators
    vals, wts, lo = [], [], []
    for i in range(len(y)):
        vals.append(y[i])
        wts.append(w[i])
        lo.append(s[i])
        while len(vals) > 1 and vals[-2] >= vals[-1]:
            v = (vals[-2] * wts[-2] + vals[-1] * wts[-1]) / \
                (wts[-2] + wts[-1])
            wts[-2] += wts[-1]
            vals[-2] = v
            vals.pop()
            wts.pop()
            lo.pop()
    return np.asarray(lo, np.float32), np.asarray(vals, np.float32)


def isotonic_apply(scores, knots, values):
    idx = np.searchsorted(knots, np.asarray(scores, np.float32),
                          side="right") - 1
    idx = np.clip(idx, 0, len(values) - 1)
    return values[idx]


def calibrated_weights(scores, sample_scores, sample_labels,
                       sample_m=None, method="platt"):
    """Recalibrate the full score array from a labeled sample, then return
    Theorem-1 optimal weights sqrt(calibrated). Monotone by construction."""
    if method == "platt":
        a, b = platt_fit(sample_scores, sample_labels, sample_m)
        cal = platt_apply(scores, a, b)
    elif method == "isotonic":
        knots, vals = isotonic_fit(sample_scores, sample_labels, sample_m)
        cal = isotonic_apply(scores, knots, vals)
    else:
        raise ValueError(method)
    return np.sqrt(np.clip(cal, 0.0, 1.0))
