"""Fault tolerance: retry policy, circuit breaker, watchdog, fail-alone
transport poisoning, pacer-error taxonomy, close/drain races, and the
acceptance criterion — faulty runs are bit-for-bit the fault-free runs."""
import threading
import time

import numpy as np
import pytest

import jax

from repro.core.engine import SelectionEngine
from repro.core.oracle import BatchingOracle, BudgetLedger, array_oracle
from repro.core.queries import JointSUPGQuery, SUPGQuery
from repro.core.resilience import (CircuitBreaker, CircuitOpenError,
                                   OracleFatalError, OracleMalformedError,
                                   OracleTimeoutError, OracleTransientError,
                                   RetryPolicy, call_with_timeout,
                                   is_retryable)
from repro.data.synthetic import make_beta
from repro.serve import SelectionServer, ServerClosedError, TokenBucket
from repro.serve.limiter import RateLimitError
from repro.testing import FaultInjector, fault_schedule


def _nosleep_policy(**kw):
    kw.setdefault("max_attempts", 5)
    kw.setdefault("base_delay_s", 0.0)
    kw.setdefault("sleep", lambda s: None)
    return RetryPolicy(**kw)


def _dataset(n=50_000, seed=12):
    ds = make_beta(n, 0.02, 1.0, seed=seed)
    return ds, array_oracle(ds.labels)


def _engine(ds, shards=4):
    return SelectionEngine(np.array_split(ds.scores, shards),
                           num_bins=1024, use_kernel=False)


def _batch():
    return [
        SUPGQuery(target="recall", gamma=0.9, budget=2000, method="is"),
        SUPGQuery(target="precision", gamma=0.8, budget=2000, method="is"),
        JointSUPGQuery(gamma_recall=0.8, stage_budget=2000),
        SUPGQuery(target="recall", gamma=0.85, budget=1500,
                  method="uniform"),
    ]


# -- RetryPolicy --------------------------------------------------------------

def test_retry_policy_backoff_deterministic_and_bounded():
    p = RetryPolicy(max_attempts=6, base_delay_s=0.1, multiplier=2.0,
                    max_delay_s=0.5, jitter=0.25, seed=3)
    seq = [p.backoff_s(a, salt=42) for a in range(1, 6)]
    assert seq == [p.backoff_s(a, salt=42) for a in range(1, 6)]  # pure
    for a, d in enumerate(seq, start=1):
        raw = min(0.5, 0.1 * 2.0 ** (a - 1))
        assert raw * 0.75 <= d <= raw         # jitter only shrinks
    # different salts decorrelate concurrent micro-batches
    assert p.backoff_s(2, salt=1) != p.backoff_s(2, salt=2)
    # zero jitter is exactly exponential, capped
    q = RetryPolicy(base_delay_s=0.1, jitter=0.0, max_delay_s=0.25)
    assert [q.backoff_s(a) for a in (1, 2, 3, 4)] == [0.1, 0.2, 0.25, 0.25]


def test_retry_policy_validates_knobs():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=1.5)
    with pytest.raises(ValueError, match="delays"):
        RetryPolicy(base_delay_s=-1.0)


def test_taxonomy_classification():
    assert is_retryable(OracleTransientError("5xx"))
    assert is_retryable(OracleTimeoutError("slow"))
    assert is_retryable(OracleMalformedError("torn"))
    assert not is_retryable(OracleFatalError("rejected"))
    assert not is_retryable(CircuitOpenError("open"))
    assert not is_retryable(RateLimitError("over capacity"))
    # builtin transport errors are transient; logic errors are not
    assert is_retryable(ConnectionResetError())
    assert is_retryable(TimeoutError())
    assert not is_retryable(ValueError("bug"))
    # generic OS-level I/O errors are transient, but the deterministic
    # OSError subclasses are not — a missing file won't heal on retry
    assert is_retryable(OSError("EIO"))
    assert is_retryable(BrokenPipeError())
    assert not is_retryable(FileNotFoundError("model.ckpt"))
    assert not is_retryable(PermissionError("denied"))
    assert not is_retryable(IsADirectoryError("/tmp"))
    # an explicit retryable attribute wins over the heuristics
    err = ValueError("flaky wire format")
    err.retryable = True
    assert is_retryable(err)
    assert isinstance(OracleMalformedError("x"), ValueError)  # back-compat


# -- CircuitBreaker -----------------------------------------------------------

def test_breaker_full_state_machine_with_fake_clock():
    t = [0.0]
    br = CircuitBreaker(failure_threshold=3, reset_timeout_s=10.0,
                        clock=lambda: t[0])
    assert br.state == "closed" and br.allow()
    br.record_failure()
    br.record_failure()
    br.record_success()                    # success resets the streak
    br.record_failure()
    br.record_failure()
    assert br.state == "closed"
    br.record_failure()                    # third consecutive: trips
    assert br.state == "open" and br.opens == 1
    assert not br.allow()
    assert br.retry_after_s() == pytest.approx(10.0)
    t[0] = 6.0
    assert br.retry_after_s() == pytest.approx(4.0)
    t[0] = 10.0
    assert br.allow() and br.state == "half-open"   # the one probe
    assert not br.allow()                  # probe already granted
    br.record_failure()                    # failed probe: re-open
    assert br.state == "open" and br.opens == 2
    t[0] = 20.0
    assert br.allow()
    br.record_success()                    # healed
    assert br.state == "closed" and br.closes == 1
    assert br.probes == 2 and br.rejections >= 2


# -- watchdog -----------------------------------------------------------------

def test_call_with_timeout_passes_and_kills():
    assert call_with_timeout(lambda x: x * 2, 21, timeout_s=5.0) == 42
    release = threading.Event()

    def stuck(_):
        release.wait(30)
        return "late"

    with pytest.raises(OracleTimeoutError, match="deadline"):
        call_with_timeout(stuck, [1, 2], timeout_s=0.05)
    release.set()
    # errors inside fn propagate as themselves, not as timeouts
    with pytest.raises(KeyError):
        call_with_timeout(lambda _: {}["missing"], None, timeout_s=5.0)


def test_channel_watchdog_times_out_then_retry_succeeds():
    """A latency spike beyond call_timeout_s raises OracleTimeoutError,
    which is transient: the retry answers and the late result of the
    abandoned call never corrupts anything."""
    ds = np.arange(32.0)
    inj = FaultInjector(array_oracle(ds), {0: "latency"}, spike_s=0.5)
    client = BatchingOracle(inj, retry=_nosleep_policy(),
                            call_timeout_s=0.1)
    t = client.submit([3, 4], ledger=BudgetLedger(10))
    np.testing.assert_array_equal(t.result(), [3.0, 4.0])
    assert client.timeouts == 1 and client.retries == 1
    assert inj.calls == 2


# -- retries inside the drain -------------------------------------------------

def test_transient_faults_retried_labels_cached_once():
    inj = FaultInjector(array_oracle(np.arange(64.0)),
                        {0: "transient", 1: "transient"})
    client = BatchingOracle(inj, retry=_nosleep_policy())
    led = BudgetLedger(32)
    t = client.submit([5, 6, 7], ledger=led)
    np.testing.assert_array_equal(t.result(), [5.0, 6.0, 7.0])
    assert client.retries == 2 and client.fn_calls == 1
    assert led.charged == 3                # charged once, not per attempt


@pytest.mark.parametrize("kind", ["torn", "dup", "nan"])
def test_malformed_batches_rejected_retried_never_cached(kind):
    """Wrong-length and non-finite responses are validation failures:
    retried like transients, and the bad labels must never reach the
    shared cache (a later cache hit would silently corrupt a query)."""
    inj = FaultInjector(array_oracle(np.arange(64.0)), {0: kind})
    client = BatchingOracle(inj, retry=_nosleep_policy())
    t = client.submit([8, 9], ledger=BudgetLedger(10))
    np.testing.assert_array_equal(t.result(), [8.0, 9.0])
    assert client.retries == 1
    assert client.cache_size == 2          # only the clean labels landed
    labels, known = client._cache.lookup(np.asarray([8, 9]))
    assert known.all() and np.isfinite(labels).all()


def test_exhausted_retries_fail_only_owning_tickets():
    """The chaos acceptance test: with max_batch=2, tickets A=[1,2] and
    B=[3,4] coalesce into two micro-batches. The schedule faults B's
    chunk through every attempt; A completes with its labels and its
    charge, B fails alone with the typed error, and the failed chunk is
    neither charged nor cached."""
    schedule = {1: "transient", 2: "transient"}   # calls 1,2 = chunk {3,4}
    inj = FaultInjector(array_oracle(np.arange(64.0)), schedule)
    client = BatchingOracle(inj, max_batch=2,
                            retry=_nosleep_policy(max_attempts=2))
    la, lb = BudgetLedger(10), BudgetLedger(10)
    ta = client.submit([1, 2], ledger=la)
    tb = client.submit([3, 4], ledger=lb)
    client.drain()
    np.testing.assert_array_equal(ta.result(), [1.0, 2.0])
    with pytest.raises(OracleTransientError, match="injected"):
        tb.result()
    assert la.charged == 2 and lb.charged == 0
    assert client.cache_size == 2          # {1,2} only
    assert client.retries == 1             # one re-attempt before exhaustion
    assert client.batch_failures == 1
    # the channel is not wedged: B's records label fine on resubmit
    tb2 = client.submit([3, 4], ledger=lb)
    np.testing.assert_array_equal(tb2.result(), [3.0, 4.0])
    assert lb.charged == 2


def test_shared_record_failure_poisons_both_owners():
    """Two tickets sharing a record in the failed micro-batch both fail
    (the record's labels never arrived for either); a later ticket with
    disjoint records labels cleanly — the channel is not wedged."""
    inj = FaultInjector(array_oracle(np.arange(64.0)),
                        {0: "fatal"})                # chunk {2} fails
    client = BatchingOracle(inj, max_batch=2, retry=_nosleep_policy())
    la, lb, lc = BudgetLedger(10), BudgetLedger(10), BudgetLedger(10)
    ta = client.submit([2], ledger=la)
    tb = client.submit([2], ledger=lb)     # shares record 2; auto-drains
    with pytest.raises(OracleFatalError):
        ta.result()
    with pytest.raises(OracleFatalError):
        tb.result()
    tc = client.submit([5, 6], ledger=lc)  # disjoint, clean call
    np.testing.assert_array_equal(tc.result(), [5.0, 6.0])
    assert la.charged == lb.charged == 0 and lc.charged == 2
    assert client.retries == 0             # fatal = never retried
    assert client.cache_size == 2          # the failed record never cached


def test_breaker_trips_channel_and_sheds_then_heals():
    """Consecutive exhausted micro-batches trip the breaker; while open,
    drains shed with CircuitOpenError without invoking the oracle; after
    the cooldown the half-open probe heals it."""
    t = [0.0]
    br = CircuitBreaker(failure_threshold=2, reset_timeout_s=5.0,
                        clock=lambda: t[0])
    inj = FaultInjector(array_oracle(np.arange(64.0)),
                        {0: "fatal", 1: "fatal"})
    client = BatchingOracle(inj, max_batch=2, breaker=br)
    led = BudgetLedger(32)
    t1 = client.submit([1, 2], ledger=led)
    t2 = client.submit([3, 4], ledger=led)
    client.drain()
    for tick in (t1, t2):
        with pytest.raises(OracleFatalError):
            tick.result()
    assert br.state == "open"
    calls_before = inj.calls
    t3 = client.submit([5, 6], ledger=led)
    client.drain()
    with pytest.raises(CircuitOpenError) as ei:
        t3.result()
    assert ei.value.retry_after_s > 0.0
    assert inj.calls == calls_before       # shed without touching the oracle
    # sheds are refused load, not channel failures: counted apart
    assert client.batch_sheds == 1 and client.batch_failures == 2
    t[0] = 6.0                             # cooldown elapsed: probe allowed
    t4 = client.submit([7, 8], ledger=led)
    np.testing.assert_array_equal(t4.result(), [7.0, 8.0])
    assert br.state == "closed" and br.closes == 1


def test_half_open_probe_keeps_slot_across_retries_and_heals():
    """Regression: the breaker is consulted once per micro-batch, so a
    half-open probe whose first attempt fails transiently retries under
    its own grant — it must not be rejected with CircuitOpenError by
    the probe slot it is holding (which used to wedge the breaker
    half-open forever)."""
    t = [0.0]
    br = CircuitBreaker(failure_threshold=1, reset_timeout_s=5.0,
                        clock=lambda: t[0])
    inj = FaultInjector(array_oracle(np.arange(64.0)),
                        {0: "fatal", 1: "transient"})
    client = BatchingOracle(inj, retry=_nosleep_policy(), breaker=br)
    led = BudgetLedger(32)
    with pytest.raises(OracleFatalError):
        client.submit([1, 2], ledger=led).result()
    assert br.state == "open"
    t[0] = 6.0                             # cooldown over: next chunk probes
    tk = client.submit([3, 4], ledger=led) # probe blips, retry answers
    np.testing.assert_array_equal(tk.result(), [3.0, 4.0])
    assert br.state == "closed" and client.retries == 1
    assert client.batch_sheds == 0         # the probe was never self-shed


def test_half_open_probe_exhaustion_reopens_not_wedges():
    """Regression companion: a probe whose every attempt fails must
    re-open the circuit (record_failure restarts the cooldown) — not
    strand it half-open with retry_after_s() == 0 shedding forever."""
    t = [0.0]
    br = CircuitBreaker(failure_threshold=1, reset_timeout_s=5.0,
                        clock=lambda: t[0])
    inj = FaultInjector(array_oracle(np.arange(64.0)),
                        {0: "fatal", 1: "transient", 2: "transient"})
    client = BatchingOracle(inj, retry=_nosleep_policy(max_attempts=2),
                            breaker=br)
    led = BudgetLedger(32)
    with pytest.raises(OracleFatalError):
        client.submit([1, 2], ledger=led).result()
    t[0] = 6.0                             # cooldown over
    with pytest.raises(OracleTransientError, match="injected"):
        client.submit([3, 4], ledger=led).result()   # probe exhausts
    assert br.state == "open" and br.opens == 2
    assert br.retry_after_s() == pytest.approx(5.0)  # cooldown restarted
    t[0] = 12.0                            # next probe: schedule is clean
    tk = client.submit([5, 6], ledger=led)
    np.testing.assert_array_equal(tk.result(), [5.0, 6.0])
    assert br.state == "closed"


# -- pacer taxonomy (satellite) -----------------------------------------------

def test_pacer_rate_limit_error_fails_tickets_not_drain_worker():
    """A zero-capacity bucket rejects every nonzero acquire; the typed
    RateLimitError is fatal (retryable=False), so the micro-batch fails
    alone instead of spinning retries, and the async drain worker
    survives to serve later drains."""
    bucket = TokenBucket(rate=5.0, burst=0)
    client = BatchingOracle(array_oracle(np.arange(16.0)), pacer=bucket,
                            retry=_nosleep_policy())
    t = client.submit([1, 2], ledger=BudgetLedger(10))
    handle = client.drain_async()
    handle.wait()
    assert handle.exception() is None      # worker survived
    with pytest.raises(RateLimitError):
        t.result()
    assert client.retries == 0 and client.batch_failures == 1
    # the worker still drains cleanly after the failure
    client._pacer = None
    t2 = client.submit([3], ledger=BudgetLedger(10))
    client.drain_async().wait()
    np.testing.assert_array_equal(t2.result(), [3.0])
    client.close()


def test_pacer_transient_error_is_retried():
    """A pacer that blips (transient) is re-run on the next attempt —
    pacing errors go through the same taxonomy as oracle errors."""
    calls = [0]

    def flaky_pacer(n):
        calls[0] += 1
        if calls[0] == 1:
            raise ConnectionResetError("limiter hiccup")

    client = BatchingOracle(array_oracle(np.arange(16.0)),
                            pacer=flaky_pacer, retry=_nosleep_policy())
    t = client.submit([4, 5], ledger=BudgetLedger(10))
    np.testing.assert_array_equal(t.result(), [4.0, 5.0])
    assert calls[0] == 2 and client.retries == 1


# -- close / drain_async race (satellite) -------------------------------------

def test_close_waits_for_inflight_drain_async():
    """close() must not reap the drain worker under an in-flight
    drain_async: the handle settles (tickets resolved), no thread leaks,
    even when a concurrent drain_async installs a fresh worker."""
    gate = threading.Event()
    labels = np.arange(32.0)

    def slow_fn(idx):
        gate.wait(30)
        return labels[np.asarray(idx)]

    before = set(threading.enumerate())
    client = BatchingOracle(slow_fn)
    led = BudgetLedger(32)
    t1 = client.submit([1, 2], ledger=led)
    handle = client.drain_async()
    closer = threading.Thread(target=client.close)
    closer.start()
    time.sleep(0.05)                       # let close() reach the join
    gate.set()
    closer.join(timeout=30)
    assert not closer.is_alive()
    assert handle.done and handle.exception() is None
    np.testing.assert_array_equal(t1.result(), [1.0, 2.0])
    deadline = time.monotonic() + 10
    while set(threading.enumerate()) - before:
        assert time.monotonic() < deadline, (
            f"leaked threads: {set(threading.enumerate()) - before}")
        time.sleep(0.01)


# -- session + stats surfacing ------------------------------------------------

def test_session_surfaces_retry_stats():
    ds, oracle = _dataset(20_000)
    inj = FaultInjector(oracle, {0: "transient", 3: "transient"})
    q = SUPGQuery(target="recall", gamma=0.9, budget=1000, method="is")
    with _engine(ds, shards=2) as engine:
        with engine.session(inj, retry=_nosleep_policy()) as sess:
            h = sess.submit(q, key=jax.random.PRNGKey(0))
            assert h.result().total_selected >= 0
            assert sess.stats.retries == sess.client.retries >= 1
            assert sess.stats.batch_failures == 0


# -- acceptance: faulty == fault-free, bit for bit ----------------------------

@pytest.mark.parametrize("workers", [1, 4, 8])
def test_faulty_run_many_bit_for_bit_fault_free(workers):
    """Under a seeded transient-only schedule with retries, run_many
    results are exactly the fault-free results at any worker count:
    retries re-ask for the same records and a pure oracle answers the
    same labels, so no committed result can change."""
    ds, oracle = _dataset(30_000)
    qs = _batch()
    key = jax.random.PRNGKey(7)

    with SelectionEngine(np.array_split(ds.scores, 4), num_bins=1024,
                         use_kernel=False, workers=workers,
                         clamp_workers=False) as engine:
        ref = engine.run_many(key, oracle, qs)

    schedule = fault_schedule(seed=17, n_calls=400, rate=0.3)
    assert schedule                        # the chaos must actually engage
    inj = FaultInjector(oracle, schedule)
    with SelectionEngine(np.array_split(ds.scores, 4), num_bins=1024,
                         use_kernel=False, workers=workers,
                         clamp_workers=False) as engine:
        client = BatchingOracle(inj, retry=_nosleep_policy(max_attempts=8))
        out = engine.run_many(key, client, qs)
    assert inj.injected["transient"] > 0
    assert client.retries > 0

    for r, o in zip(ref, out):
        assert r.tau == o.tau
        assert r.total_selected == o.total_selected
        np.testing.assert_array_equal(np.concatenate(r.masks),
                                      np.concatenate(o.masks))


def test_faulty_server_bit_for_bit_fault_free():
    """Same acceptance through the serving plane: SelectionServer with a
    retrying channel over an injected-fault oracle returns exactly the
    fault-free served results, and the stats surface the retries."""
    ds, oracle = _dataset(30_000)
    qs = _batch()
    keys = list(jax.random.split(jax.random.PRNGKey(7), len(qs)))

    with SelectionServer(_engine(ds), oracle, max_inflight=2,
                         sessions=2) as server:
        ref = [server.submit(q, key=k).result(timeout=120)
               for q, k in zip(qs, keys)]

    inj = FaultInjector(oracle, fault_schedule(seed=23, n_calls=400,
                                               rate=0.3))
    with SelectionServer(_engine(ds), inj, max_inflight=2, sessions=2,
                         retry=_nosleep_policy(max_attempts=8)) as server:
        out = [server.submit(q, key=k).result(timeout=120)
               for q, k in zip(qs, keys)]
        stats = server.stats()
    assert inj.injected["transient"] > 0
    assert stats.retries > 0 and stats.batch_failures == 0
    assert "resilience:" in stats.format()

    for r, o in zip(ref, out):
        assert r.tau == o.tau
        np.testing.assert_array_equal(np.concatenate(r.masks),
                                      np.concatenate(o.masks))


# -- server circuit shedding --------------------------------------------------

def test_server_sheds_admissions_while_circuit_open():
    """Once the breaker trips, submit() rejects with CircuitOpenError
    (retry-after hint, counted as shed); after the cooldown the drain
    path's half-open probe heals the circuit and the server admits
    again. Admission checks never consume the probe slot."""
    ds, oracle = _dataset(20_000)
    t = [0.0]
    br = CircuitBreaker(failure_threshold=1, reset_timeout_s=30.0,
                        clock=lambda: t[0])
    inj = FaultInjector(oracle, {0: "fatal"})
    q = SUPGQuery(target="recall", gamma=0.9, budget=500, method="is")
    with SelectionServer(_engine(ds, shards=2), inj,
                         retry=_nosleep_policy(max_attempts=1),
                         breaker=br) as server:
        h = server.submit(q, key=jax.random.PRNGKey(0))
        with pytest.raises(OracleFatalError):
            h.result(timeout=120)
        assert br.state == "open"
        with pytest.raises(CircuitOpenError) as ei:
            server.submit(q, key=jax.random.PRNGKey(1))
        assert ei.value.retry_after_s > 0.0
        stats = server.stats()
        assert stats.circuit_state == "open" and stats.circuit_opens == 1
        assert stats.circuit_shed == 1
        assert stats.tenants["default"].shed == 1
        assert stats.tenants["default"].in_flight == 0
        assert "circuit open" in stats.format()
        t[0] = 31.0                        # cooldown over: admit + probe
        h2 = server.submit(q, key=jax.random.PRNGKey(2))
        assert h2.result(timeout=120).total_selected >= 0
        assert br.state == "closed"
        assert server.stats().circuit_state == "closed"


def test_server_rejects_resilience_kwargs_with_external_client():
    ds, oracle = _dataset(20_000)
    client = BatchingOracle(oracle)
    with pytest.raises(ValueError, match="configure"):
        SelectionServer(_engine(ds, shards=2), client,
                        retry=RetryPolicy())
    client.close()


def test_server_inherits_breaker_from_external_client():
    """An externally-owned channel's breaker still drives admission
    shedding: the server reads it off the client."""
    ds, oracle = _dataset(20_000)
    t = [0.0]
    br = CircuitBreaker(failure_threshold=1, reset_timeout_s=30.0,
                        clock=lambda: t[0])
    client = BatchingOracle(oracle, breaker=br)
    br.record_failure()                    # trip it by hand
    with SelectionServer(_engine(ds, shards=2), client) as server:
        assert server.breaker is br
        with pytest.raises(CircuitOpenError):
            server.submit(SUPGQuery(target="recall", gamma=0.9,
                                    budget=500, method="is"))
    client.close()


# -- server close(abandon=True) mid-drain (satellite) -------------------------

def test_server_close_abandon_mid_drain_no_leaked_threads():
    """close(abandon=True) while queries are mid-drain: the scheduler
    thread exits, every outstanding ServerHandle resolves with
    ServerClosedError, and no server/session/channel thread leaks."""
    ds, _ = _dataset(20_000)
    gate = threading.Event()
    labels = ds.labels
    calls = [0]

    def gated_fn(idx):
        calls[0] += 1
        assert gate.wait(timeout=60), "gated oracle never released"
        return labels[np.asarray(idx)]

    before = set(threading.enumerate())
    # JT needs >= 2 oracle rounds, so after _abandon the final scheduler
    # pass cannot complete it — the handle must resolve ServerClosedError
    q = JointSUPGQuery(gamma_recall=0.8, stage_budget=800)
    server = SelectionServer(_engine(ds, shards=2), gated_fn,
                             max_inflight=2)
    handles = [server.submit(q, key=k)
               for k in jax.random.split(jax.random.PRNGKey(1), 3)]
    deadline = time.monotonic() + 30
    while calls[0] == 0:                   # a drain is truly in flight
        assert time.monotonic() < deadline, "drain never started"
        time.sleep(0.005)
    closer = threading.Thread(target=server.close, kwargs={"abandon": True})
    closer.start()
    time.sleep(0.05)
    gate.set()                             # release the stuck oracle call
    closer.join(timeout=60)
    assert not closer.is_alive()
    for h in handles:
        with pytest.raises(ServerClosedError):
            h.result(timeout=60)
    deadline = time.monotonic() + 10
    while set(threading.enumerate()) - before:
        assert time.monotonic() < deadline, (
            f"leaked threads: {set(threading.enumerate()) - before}")
        time.sleep(0.01)
