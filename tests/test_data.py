"""Data pipeline: determinism, sharding, resume, marker oracle."""
import numpy as np
import pytest

from repro.data import synthetic
from repro.data.pipeline import DeterministicSource, Prefetcher, ScoreStore


def test_beta_dataset_properties():
    ds = synthetic.make_beta(50_000, 0.01, 1.0, seed=0)
    assert 0.001 < ds.tpr < 0.05
    assert ds.scores.min() >= 0 and ds.scores.max() <= 1


def test_beta_noise_clipped():
    ds = synthetic.make_beta(10_000, 0.01, 2.0, seed=1, noise_std=0.05)
    assert ds.scores.min() >= 0 and ds.scores.max() <= 1


def test_marker_oracle_exact():
    toks, labels = synthetic.make_token_corpus(512, 64, 128,
                                               positive_rate=0.1, seed=0)
    assert labels.sum() >= 0.1 * 512 * 0.9
    hits = synthetic.contains_marker(toks)
    np.testing.assert_array_equal(hits.astype(np.float32), labels)


def test_deterministic_source_resume():
    def make(rng, step):
        return {"x": rng.integers(0, 100, (8, 4))}

    src = DeterministicSource(make, seed=5)
    run1 = [src.batch_at(s)["x"] for s in range(5)]
    run2 = [src.batch_at(s)["x"] for s in range(5)]
    for a, b in zip(run1, run2):
        np.testing.assert_array_equal(a, b)
    # resume from step 3 sees exactly batch 3
    it = src.iter_from(3)
    np.testing.assert_array_equal(next(it)["x"], run1[3])


def test_source_sharding_partitions_batch():
    def make(rng, step):
        return {"x": np.arange(8)}

    a = DeterministicSource(make, 0, shard_index=0, num_shards=2)
    b = DeterministicSource(make, 0, shard_index=1, num_shards=2)
    xa, xb = a.batch_at(0)["x"], b.batch_at(0)["x"]
    assert sorted(np.concatenate([xa, xb]).tolist()) == list(range(8))


def test_prefetcher_order_and_exhaustion():
    out = list(Prefetcher(iter(range(10)), depth=3))
    assert out == list(range(10))


def test_prefetcher_propagates_errors():
    def gen():
        yield 1
        raise RuntimeError("boom")

    it = Prefetcher(gen(), depth=2)
    assert next(it) == 1
    with pytest.raises(RuntimeError):
        list(it)


def test_score_store_roundtrip(tmp_path):
    store = ScoreStore(tmp_path / "scores.f32", 100, create=True)
    assert store.num_scored == 0
    store.write(10, np.linspace(0, 1, 20).astype(np.float32))
    assert store.num_scored == 20
    got = store.read(10, 20)
    np.testing.assert_allclose(got, np.linspace(0, 1, 20), atol=1e-6)


def test_lm_batches_resumable():
    a = list(synthetic.lm_batches(0, 3, 4, 16, 100))
    b = list(synthetic.lm_batches(0, 3, 4, 16, 100, start_step=1))
    np.testing.assert_array_equal(a[1]["tokens"], b[0]["tokens"])
