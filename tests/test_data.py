"""Data pipeline: determinism, sharding, resume, marker oracle, and the
streaming selection sinks."""
import numpy as np
import pytest

from repro.data import synthetic
from repro.data.pipeline import (BitmaskStore, CallbackSink, ChunkPlan,
                                 ChunkWalk, DeterministicSource, IndexSink,
                                 Prefetcher, ScoreStore, SelectionStream,
                                 WorkerPool, parallel_map, run_fused)


def test_beta_dataset_properties():
    ds = synthetic.make_beta(50_000, 0.01, 1.0, seed=0)
    assert 0.001 < ds.tpr < 0.05
    assert ds.scores.min() >= 0 and ds.scores.max() <= 1


def test_beta_noise_clipped():
    ds = synthetic.make_beta(10_000, 0.01, 2.0, seed=1, noise_std=0.05)
    assert ds.scores.min() >= 0 and ds.scores.max() <= 1


def test_marker_oracle_exact():
    toks, labels = synthetic.make_token_corpus(512, 64, 128,
                                               positive_rate=0.1, seed=0)
    assert labels.sum() >= 0.1 * 512 * 0.9
    hits = synthetic.contains_marker(toks)
    np.testing.assert_array_equal(hits.astype(np.float32), labels)


def test_deterministic_source_resume():
    def make(rng, step):
        return {"x": rng.integers(0, 100, (8, 4))}

    src = DeterministicSource(make, seed=5)
    run1 = [src.batch_at(s)["x"] for s in range(5)]
    run2 = [src.batch_at(s)["x"] for s in range(5)]
    for a, b in zip(run1, run2):
        np.testing.assert_array_equal(a, b)
    # resume from step 3 sees exactly batch 3
    it = src.iter_from(3)
    np.testing.assert_array_equal(next(it)["x"], run1[3])


def test_source_sharding_partitions_batch():
    def make(rng, step):
        return {"x": np.arange(8)}

    a = DeterministicSource(make, 0, shard_index=0, num_shards=2)
    b = DeterministicSource(make, 0, shard_index=1, num_shards=2)
    xa, xb = a.batch_at(0)["x"], b.batch_at(0)["x"]
    assert sorted(np.concatenate([xa, xb]).tolist()) == list(range(8))


def test_prefetcher_order_and_exhaustion():
    out = list(Prefetcher(iter(range(10)), depth=3))
    assert out == list(range(10))


def test_prefetcher_propagates_errors():
    def gen():
        yield 1
        raise RuntimeError("boom")

    it = Prefetcher(gen(), depth=2)
    assert next(it) == 1
    with pytest.raises(RuntimeError):
        list(it)


def test_score_store_roundtrip(tmp_path):
    store = ScoreStore(tmp_path / "scores.f32", 100, create=True)
    assert store.num_scored == 0
    store.write(10, np.linspace(0, 1, 20).astype(np.float32))
    assert store.num_scored == 20
    got = store.read(10, 20)
    np.testing.assert_allclose(got, np.linspace(0, 1, 20), atol=1e-6)


def test_score_store_num_scored_cached(tmp_path):
    """Regression: num_scored used to rescan the whole store on every
    access; it must be cached and invalidated by write()."""
    store = ScoreStore(tmp_path / "s.f32", 64, create=True)
    assert store.num_scored == 0
    assert store._num_scored == 0              # cache populated
    store.write(0, np.full(8, 0.5, np.float32))
    assert store._num_scored is None           # invalidated
    assert store.num_scored == 8
    # cached value survives repeated reads without a rescan
    store._arr[16] = 0.9                       # out-of-band mutation
    assert store.num_scored == 8               # stale by design until write
    store.write(32, np.full(1, 0.1, np.float32))
    assert store.num_scored == 10


def test_score_store_append_grows_and_delta_updates_count(tmp_path):
    """append() extends the backing file in place, keeps pre-append views
    readable, and delta-updates the num_scored cache (no rescan)."""
    store = ScoreStore(tmp_path / "s.f32", 8, create=True)
    store.write(0, np.full(8, 0.5, np.float32))
    assert store.num_scored == 8               # populate the cache
    old_view = store._arr
    assert store.append(np.array([0.1, -1.0, 0.9], np.float32)) == 11
    assert store._num_scored == 10             # delta-updated, not rescanned
    assert store.num_scored == 10              # -1 stays the unscored sentinel
    np.testing.assert_allclose(store.read(8, 3), [0.1, -1.0, 0.9])
    # a reader holding the pre-append memmap still sees its records
    np.testing.assert_allclose(np.asarray(old_view[:8]), np.full(8, 0.5))
    # empty append is a no-op epoch: length unchanged, cache intact
    assert store.append(np.empty(0, np.float32)) == 11
    assert store.num_scored == 10


def test_score_store_num_scored_not_stale_under_racing_write(tmp_path):
    """Regression: a write() landing while num_scored scans must not let
    a pre-write count be committed to the cache. The scan runs outside
    the store lock (so writers are never blocked on O(n) counting); the
    version check must detect the interleaved write and rescan."""
    class RacingStore(ScoreStore):
        raced = False

        def _count_span(self, arr, start, stop):
            out = super()._count_span(arr, start, stop)
            if not self.raced:
                # Interleave a write after the span was counted but
                # before the scan commits — the classic stale-cache race.
                self.raced = True
                self.write(0, np.full(4, 0.5, np.float32))
            return out

    store = RacingStore(tmp_path / "s.f32", 32, create=True)
    assert store.num_scored == 4               # rescan saw the write
    assert store._num_scored == 4              # and the cache is not stale
    assert store.num_scored == 4


def test_score_store_write_rejects_out_of_range(tmp_path):
    """Regression: memmap slicing used to silently truncate out-of-range
    writes; they must be rejected outright."""
    store = ScoreStore(tmp_path / "s.f32", 10, create=True)
    with pytest.raises(ValueError):
        store.write(8, np.ones(5, np.float32))
    with pytest.raises(ValueError):
        store.write(-1, np.ones(2, np.float32))
    assert store.num_scored == 0               # nothing landed
    store.write(5, np.ones(5, np.float32))     # exact-fit tail is fine
    assert store.num_scored == 5


# -- ChunkPlan + worker pool -------------------------------------------------


def test_chunk_plan_spans_cover_shards():
    """Spans tile every shard exactly, shard-major, with dense chunk ids;
    empty shards contribute no spans."""
    plan = ChunkPlan([10, 0, 7], 4)
    spans = [(s.shard_id, s.chunk_id, s.start, s.stop) for s in plan]
    assert spans == [(0, 0, 0, 4), (0, 1, 4, 8), (0, 2, 8, 10),
                     (2, 0, 0, 4), (2, 1, 4, 7)]
    assert [plan.num_chunks(sh) for sh in range(3)] == [3, 0, 2]
    assert plan.total_chunks == 5
    assert [sp.size for sp in plan.shard_spans(0)] == [4, 4, 2]
    # whole-shard plan: one span per shard
    assert ChunkPlan([10, 0, 7], 64).total_chunks == 2


def test_chunk_plan_rejects_nonpositive_chunk():
    with pytest.raises(ValueError):
        ChunkPlan([10], 0)


def test_parallel_map_preserves_order_and_results():
    items = list(range(97))
    expect = [x * x for x in items]
    assert parallel_map(lambda x: x * x, items, workers=1) == expect
    assert parallel_map(lambda x: x * x, items, workers=4) == expect
    assert parallel_map(lambda x: x, [], workers=4) == []


def test_parallel_map_propagates_exceptions():
    def boom(x):
        if x == 13:
            raise RuntimeError("boom")
        return x

    with pytest.raises(RuntimeError):
        parallel_map(boom, range(20), workers=4)
    with pytest.raises(RuntimeError):
        parallel_map(boom, range(20), workers=1)


def test_sink_concurrent_emit_same_shard():
    """The sink thread-safety contract: concurrent emit() calls — including
    for chunks of the same shard, in any order — must produce exact counts
    and canonically sorted per-shard indices after close()."""
    sink = IndexSink()
    sink.open([10_000])
    chunks = [np.arange(o, o + 100, dtype=np.int64) for o in
              range(0, 10_000, 100)]
    rng = np.random.default_rng(0)
    order = rng.permutation(len(chunks))
    parallel_map(lambda i: sink.emit(0, chunks[i]), order, workers=8)
    counts = sink.close()
    np.testing.assert_array_equal(counts, [10_000])
    np.testing.assert_array_equal(sink.indices(0), np.arange(10_000))


def test_worker_pool_survives_poisoned_task_and_stays_reusable():
    """A task exception propagates to the caller, but the persistent pool
    must keep serving later maps — an engine-owned pool lives across many
    queries and one bad CallbackSink consumer cannot kill it."""
    pool = WorkerPool(4)

    def boom(x):
        if x == 7:
            raise RuntimeError("poisoned task")
        return x * x

    with pytest.raises(RuntimeError, match="poisoned task"):
        pool.map(boom, range(20))
    # same pool, same threads: still fully functional afterwards
    assert pool.map(lambda x: x + 1, range(50)) == list(range(1, 51))
    assert pool.map(lambda x: x * x, range(10)) == [x * x
                                                    for x in range(10)]
    pool.close()


def test_worker_pool_lifecycle_and_inline_paths():
    """close() is idempotent; a closed pool still serves the inline fast
    paths (they own no threads) but refuses threaded work; workers<=1 and
    single-item maps never touch an executor at all."""
    pool = WorkerPool(4)
    assert pool.map(lambda x: -x, [3]) == [-3]        # single item: inline
    assert pool.map(lambda x: -x, []) == []
    assert pool.map(lambda x: -x, [1, 2, 3]) == [-1, -2, -3]
    pool.close()
    pool.close()                                      # idempotent
    assert pool.closed
    assert pool.map(lambda x: -x, [5]) == [-5]        # inline still works
    with pytest.raises(RuntimeError, match="closed"):
        pool.map(lambda x: -x, [1, 2, 3])             # threaded refused
    with WorkerPool(1) as serial:
        # workers=1 is a plain loop — order is the iteration order
        log = []
        serial.map(log.append, range(5))
        assert log == [0, 1, 2, 3, 4]


def test_worker_pool_nested_map_runs_inline():
    """A map issued *from a pool worker thread* must run inline on that
    thread: plan steps scheduled on the pool call pool.map for their own
    chunk walks, and a fixed-size pool blocking on its own slots would
    deadlock."""
    import threading

    pool = WorkerPool(2)
    inner_threads = []

    def outer(i):
        def inner(j):
            inner_threads.append(threading.current_thread().name)
            return i * 10 + j
        return pool.map(inner, range(3))

    got = pool.map(outer, range(8))     # 8 tasks on 2 workers
    assert got == [[i * 10 + j for j in range(3)] for i in range(8)]
    # every inner call ran on a pool worker thread (i.e. inline in its
    # outer task), never by re-entering the executor from outside
    assert all(name.startswith("repro-pool") for name in inner_threads)
    pool.close()


# -- ChunkPlan fusion --------------------------------------------------------


def test_chunk_plan_fuse_span_accounting():
    """Same-geometry plans share one span list (tagged with every owner);
    distinct geometries keep their own spans — the per-round fusion that
    makes k queries touch each data chunk once."""
    a = ChunkPlan([10, 0, 7], 4)
    b = ChunkPlan([10, 0, 7], 4)        # same geometry as a
    c = ChunkPlan([10, 0, 7], 64)       # same shards, coarser chunks
    assert a.geometry == b.geometry != c.geometry
    fused = ChunkPlan.fuse([a, b, c])
    # one span set for {a, b} plus c's own: 5 + 2, not 5 + 5 + 2
    assert len(fused) == a.total_chunks + c.total_chunks == 7
    owners = {(sp.shard_id, sp.chunk_id, sp.stop - sp.start): idxs
              for sp, idxs in fused}
    assert all(idxs == [0, 1] for (_, _, sz), idxs in owners.items()
               if sz <= 4)
    # degenerate fuse of one plan is just its span list
    solo = ChunkPlan.fuse([a])
    assert [sp for sp, _ in solo] == list(a)
    assert all(idxs == [0] for _, idxs in solo)


def test_run_fused_matches_per_plan_walks():
    """Fused execution visits, per walk, exactly the spans a solo walk of
    its plan would — accounting must match the unfused baseline."""
    plans = [ChunkPlan([10, 0, 7], 4), ChunkPlan([10, 0, 7], 4),
             ChunkPlan([12], 5)]
    solo = [[(sp.shard_id, sp.chunk_id) for sp in p] for p in plans]
    seen = [[] for _ in plans]
    walks = [ChunkWalk(p, lambda sp, i=i: seen[i].append(
        (sp.shard_id, sp.chunk_id))) for i, p in enumerate(plans)]
    with WorkerPool(1) as pool:
        errs = run_fused(walks, pool)
    assert errs == [None, None, None]
    assert seen == solo
    # and the fused pass cost: shared spans ran once for both owners
    assert (len(ChunkPlan.fuse(plans))
            == plans[0].total_chunks + plans[2].total_chunks)


def test_run_fused_isolates_walk_errors():
    """One walk's failure must not stop the others: its first error comes
    back in its slot (and its remaining spans are skipped), while every
    co-fused walk still completes all spans."""
    plan = ChunkPlan([20], 4)           # 5 spans
    good = []

    def bad_fn(sp):
        if sp.chunk_id == 1:
            raise ValueError("walk died")
        good.append(("bad", sp.chunk_id))

    ok = []
    walks = [ChunkWalk(plan, bad_fn),
             ChunkWalk(plan, lambda sp: ok.append(sp.chunk_id))]
    errs = run_fused(walks)             # serial path: no pool given
    assert isinstance(errs[0], ValueError) and errs[1] is None
    assert ok == [0, 1, 2, 3, 4]        # co-fused walk saw every span
    # the failing walk stopped at its error
    assert ("bad", 0) in good and all(c < 1 for _, c in good)


# -- selection sinks ---------------------------------------------------------

_SIZES = [100, 0, 37]


def _fill(sink):
    sink.open(_SIZES)
    sink.fold(0, np.asarray([5, 99]))
    sink.emit(0, np.arange(10, 20))
    sink.emit(2, np.asarray([0, 36]))
    return sink.close()


def test_index_sink_counts_and_views():
    sink = IndexSink()
    counts = _fill(sink)
    np.testing.assert_array_equal(counts, [12, 0, 2])
    assert sink.total_selected == 14
    np.testing.assert_array_equal(
        sink.indices(0), np.sort(np.r_[5, 99, np.arange(10, 20)]))
    assert sink.indices(1).size == 0
    mask = sink.mask(2)
    assert mask.shape == (37,) and mask[0] and mask[36] and mask.sum() == 2


def test_bitmask_store_matches_index_sink(tmp_path):
    """BitmaskStore must agree with IndexSink bit-for-bit and keep its
    packed representation on disk (~n/8 bytes)."""
    idx, bits = IndexSink(), BitmaskStore(tmp_path / "sel.bits")
    _fill(idx)
    counts = _fill(bits)
    np.testing.assert_array_equal(counts, [12, 0, 2])
    for sh in range(3):
        np.testing.assert_array_equal(bits.indices(sh), idx.indices(sh))
        np.testing.assert_array_equal(bits.mask(sh), idx.mask(sh))
    import os
    assert os.path.getsize(tmp_path / "sel.bits") == (100 + 7) // 8 + 0 + \
        (37 + 7) // 8


def test_callback_sink_streams_global_ids():
    got = []
    sink = CallbackSink(lambda sh, gids, folded: got.append(
        (sh, gids.tolist(), folded)))
    counts = _fill(sink)
    np.testing.assert_array_equal(counts, [12, 0, 2])
    assert got[0] == (0, [5, 99], True)               # folded positives
    assert got[1] == (0, list(range(10, 20)), False)
    assert got[2] == (2, [100, 136], False)           # offset by 100 + 0
    with pytest.raises(NotImplementedError):
        sink.indices(0)


def test_selection_stream_iterates_and_returns_result():
    def run(sink):
        _fill(sink)
        return "payload"

    stream = SelectionStream(run, depth=2)
    chunks = list(stream)
    assert [c[0] for c in chunks] == [0, 0, 2]
    assert stream.result == "payload"


def test_selection_stream_propagates_errors():
    def run(sink):
        sink.open(_SIZES)
        sink.emit(0, np.asarray([1]))
        raise RuntimeError("boom")

    stream = SelectionStream(run, depth=2)
    with pytest.raises(RuntimeError):
        list(stream)
    with pytest.raises(StopIteration):   # exhausted, not blocked
        next(stream)


def test_selection_stream_close_cancels_producer():
    """A consumer that stops early must not leak a producer blocked on the
    bounded queue: close() cancels the run at its next chunk and reaps the
    thread (the context manager closes automatically)."""
    def run(sink):
        sink.open([1000])
        for i in range(100):                      # >> queue depth
            sink.emit(0, np.asarray([i]))
        sink.close()
        return "finished"

    with SelectionStream(run, depth=2) as stream:
        next(stream)                              # consume one chunk, bail
    assert not stream._thread.is_alive()
    assert stream.result is None                  # cancelled, not finished
    with pytest.raises(StopIteration):
        next(stream)
    stream.close()                                # idempotent


def test_lm_batches_resumable():
    a = list(synthetic.lm_batches(0, 3, 4, 16, 100))
    b = list(synthetic.lm_batches(0, 3, 4, 16, 100, start_step=1))
    np.testing.assert_array_equal(a[1]["tokens"], b[0]["tokens"])
