"""Beyond-paper extensions: calibration, multi-proxy fusion, the
distributed SelectionEngine."""
import jax
import numpy as np
import pytest

from repro.core import calibration, multiproxy, queries
from repro.core.engine import SelectionEngine
from repro.core.oracle import array_oracle
from repro.data.synthetic import make_beta, make_miscalibrated


def test_platt_recovers_calibration():
    ds = make_miscalibrated(100_000, 0.05, 1.0, seed=0, temperature=3.0)
    rng = np.random.default_rng(0)
    idx = rng.integers(0, ds.scores.shape[0], 3000)
    a, b = calibration.platt_fit(ds.scores[idx], ds.labels[idx])
    cal = calibration.platt_apply(ds.scores, a, b)
    # calibrated scores match empirical positive rates per bucket better
    hi = ds.scores > np.quantile(ds.scores, 0.99)
    err_raw = abs(ds.scores[hi].mean() - ds.labels[hi].mean())
    err_cal = abs(cal[hi].mean() - ds.labels[hi].mean())
    assert err_cal < err_raw


def test_isotonic_monotone():
    rng = np.random.default_rng(1)
    s = rng.random(2000).astype(np.float32)
    y = (rng.random(2000) < s).astype(np.float32)
    knots, vals = calibration.isotonic_fit(s, y)
    assert np.all(np.diff(vals) >= -1e-6)
    out = calibration.isotonic_apply(np.linspace(0, 1, 50), knots, vals)
    assert np.all(np.diff(out) >= -1e-6)


def test_calibrated_weights_monotone_in_score():
    ds = make_miscalibrated(20_000, 0.05, 1.0, seed=2)
    rng = np.random.default_rng(2)
    idx = rng.integers(0, 20_000, 2000)
    w = calibration.calibrated_weights(ds.scores, ds.scores[idx],
                                       ds.labels[idx])
    order = np.argsort(ds.scores[:500])
    assert np.all(np.diff(w[:500][order]) >= -1e-6)


def test_multiproxy_fusion_beats_single():
    """Two weak complementary proxies fuse into a stronger one."""
    rng = np.random.default_rng(3)
    n = 60_000
    latent = rng.beta(0.05, 1.0, n).astype(np.float32)
    labels = (rng.random(n) < latent).astype(np.float32)
    # proxy 1/2: noisy monotone views of the latent probability
    p1 = np.clip(latent + rng.normal(0, 0.08, n), 1e-4, 1).astype(np.float32)
    p2 = np.clip(latent + rng.normal(0, 0.08, n), 1e-4, 1).astype(np.float32)
    fused, calls = multiproxy.fuse_proxies(
        0, np.stack([p1, p2], 1), array_oracle(labels), pilot_budget=800)
    assert calls <= 800

    def auc(scores):
        order = np.argsort(-scores)
        y = labels[order]
        tp = np.cumsum(y) / max(y.sum(), 1)
        fp = np.cumsum(1 - y) / max((1 - y).sum(), 1)
        return float(np.trapezoid(tp, fp))

    assert auc(fused) >= max(auc(p1), auc(p2)) - 0.005


def test_selection_engine_matches_guarantee():
    ds = make_beta(120_000, 0.01, 1.0, seed=4)
    shards = np.array_split(ds.scores, 5)
    engine = SelectionEngine(shards, num_bins=1024)
    assert engine.n_total == 120_000
    fails = 0
    for t in range(6):
        q = queries.SUPGQuery(target="recall", gamma=0.9, delta=0.05,
                              budget=4000, method="is")
        sel = engine.run(jax.random.PRNGKey(t), array_oracle(ds.labels), q)
        mask = np.concatenate(sel.masks)
        got = queries.recall_of(np.nonzero(mask)[0], ds.truth_mask())
        fails += got < 0.9
        assert sel.oracle_calls <= 4000
    assert fails <= 1


def test_selection_engine_two_stage_pt():
    ds = make_beta(120_000, 0.01, 1.0, seed=5)
    engine = SelectionEngine(np.array_split(ds.scores, 4), num_bins=1024)
    q = queries.SUPGQuery(target="precision", gamma=0.9, delta=0.05,
                          budget=4000, method="is", two_stage=True)
    sel = engine.run(jax.random.PRNGKey(9), array_oracle(ds.labels), q)
    mask = np.concatenate(sel.masks)
    prec = queries.precision_of(np.nonzero(mask)[0], ds.truth_mask())
    assert prec >= 0.85       # one run; guarantee tested statistically above


def test_engine_sample_reweighting_unbiased():
    ds = make_beta(80_000, 0.05, 1.0, seed=6)
    engine = SelectionEngine(np.array_split(ds.scores, 3))
    idx, m = engine.draw_sample(jax.random.PRNGKey(1), 20_000, "sqrt")
    est = float(np.mean(ds.labels[idx] * m))
    assert est == pytest.approx(float(ds.labels.mean()), rel=0.2)
