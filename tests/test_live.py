"""Live corpus plane: incremental-ingestion equivalence, epoch pinning,
standing-query re-emission, the drift sentinel, and the serve surface."""
import time

import numpy as np
import pytest

import jax
from hypothesis import given, settings, strategies as st

from repro.core import binned, sampling
from repro.core.engine import SelectionEngine
from repro.core.oracle import array_oracle
from repro.core.queries import JointSUPGQuery, SUPGQuery
from repro.data.pipeline import CallbackSink
from repro.data.synthetic import make_beta, make_drift_pair
from repro.live import DriftSentinel, IngestPlane, StandingRegistry
from repro.serve.server import SelectionServer

N_SHARDS, SHARD = 6, 20_000

QUERIES = [
    SUPGQuery(target="recall", gamma=0.9, budget=2000, method="is"),
    SUPGQuery(target="precision", gamma=0.9, budget=2000, method="is"),
    JointSUPGQuery(gamma_recall=0.85, stage_budget=2000),
]

ENGINE_KW = dict(num_bins=1024, use_kernel=False, chunk_records=1 << 13)


@pytest.fixture(scope="module")
def corpus():
    ds = make_beta(N_SHARDS * SHARD, 0.05, 1.0, seed=3)
    shards = [ds.scores[i * SHARD:(i + 1) * SHARD]
              for i in range(N_SHARDS)]
    return ds, shards


def _assert_same(a, b):
    """Bit-for-bit selection equality: tau, counts, per-shard masks."""
    assert float(a.tau) == float(b.tau)
    assert a.total_selected == b.total_selected
    assert len(a.masks) == len(b.masks)
    for ma, mb in zip(a.masks, b.masks):
        np.testing.assert_array_equal(ma, mb)
    np.testing.assert_array_equal(a.sampled_positive_global,
                                  b.sampled_positive_global)


# -- incremental ingestion == cold build ------------------------------------

@pytest.mark.parametrize("workers", [1, 4, 8])
def test_incremental_append_matches_cold_build(corpus, workers):
    """The acceptance bar: build over S1..S3, append S4..S6 (one single
    then one batch append), and every RT/PT/JT result — tau, counts,
    masks, sampled positives — is bit-for-bit the cold build's."""
    ds, shards = corpus
    oracle = array_oracle(ds.labels)
    key = jax.random.PRNGKey(42)
    with SelectionEngine(shards, workers=workers, **ENGINE_KW) as cold:
        want = cold.run_many(key, oracle, QUERIES)
    with SelectionEngine(shards[:3], workers=workers, **ENGINE_KW) as warm:
        plane = IngestPlane(warm)
        assert plane.append(shards[3]) == 1
        assert plane.append([shards[4], shards[5]]) == 2
        assert warm.epoch == 2
        assert warm.n_total == N_SHARDS * SHARD
        assert plane.shards_since(0) == [3, 4, 5]
        assert plane.shards_since(1) == [4, 5]
        got = warm.run_many(key, oracle, QUERIES)
    for a, b in zip(want, got):
        _assert_same(a, b)


def test_incremental_append_matches_cold_via_server(corpus):
    """Same equivalence through the serving plane: `SelectionServer`
    hosting an appended-to engine answers like a cold build."""
    ds, shards = corpus
    key = jax.random.PRNGKey(7)
    keys = jax.random.split(key, len(QUERIES))
    with SelectionEngine(shards, workers=2, **ENGINE_KW) as cold:
        want = cold.run_many(key, array_oracle(ds.labels), QUERIES)
    eng = SelectionEngine(shards[:3], workers=2, **ENGINE_KW)
    with SelectionServer(eng, array_oracle(ds.labels)) as srv:
        assert srv.append(shards[3]) == 1
        assert srv.append(shards[4:]) == 2
        handles = [srv.submit(q, key=k) for q, k in zip(QUERIES, keys)]
        got = [h.result(timeout=300) for h in handles]
    for a, b in zip(want, got):
        _assert_same(a, b)


def test_inflight_plan_pins_epoch_across_append(corpus):
    """A partially-stepped plan keeps its pinned epoch: an append landing
    mid-query must not change the result (or the mask shard count)."""
    ds, shards = corpus
    oracle = array_oracle(ds.labels)
    q = QUERIES[0]
    key = jax.random.PRNGKey(5)
    with SelectionEngine(shards[:3], **ENGINE_KW) as ref:
        want = ref.run(key, oracle, q)
    with SelectionEngine(shards[:3], **ENGINE_KW) as eng:
        with eng.session(oracle) as sess:
            h = sess.submit(q, key=key)
            sess.step()                      # plan started, epoch pinned
            assert IngestPlane(eng).append(shards[3]) == 1
            sel = h.result()
        assert len(sel.masks) == 3           # the pinned epoch's shards
        _assert_same(want, sel)


def test_append_rejects_unknown_epoch(corpus):
    _, shards = corpus
    with SelectionEngine(shards[:1], **ENGINE_KW) as eng:
        plane = IngestPlane(eng)
        with pytest.raises(ValueError, match="not recorded"):
            plane.shards_since(7)


# -- standing queries -------------------------------------------------------

def test_standing_query_reemits_exact_threshold_set(corpus):
    """After an append, one catch-up walk streams exactly {A >= tau} over
    the appended shards (and only those) into the standing sink."""
    ds, shards = corpus
    oracle = array_oracle(ds.labels)
    got = []
    sink = CallbackSink(
        lambda sid, idx, folded: got.append((sid, np.asarray(idx).copy())))
    with SelectionEngine(shards[:4], **ENGINE_KW) as eng:
        with eng.session(oracle) as sess:
            reg = StandingRegistry(IngestPlane(eng), sess)
            sq = reg.register(QUERIES[0], key=jax.random.PRNGKey(11),
                              sink=sink)
            reg.settle()
            tau = sq.wait_certified(timeout=0)
            got.clear()                       # keep only re-emissions
            reg.plane.append([shards[4], shards[5]])
            assert reg.pump() == 1            # both shards, one walk
            reg.settle()
            assert (sq.emissions, sq.epoch, sq.reemit_failures) == (1, 1, 0)
            assert reg.pump() == 0            # caught up: nothing to do
    assert got and all(sid >= 4 for sid, _ in got)
    emitted = np.sort(np.concatenate([idx for _, idx in got]))
    want = np.sort(np.concatenate(
        [j * SHARD + np.flatnonzero(shards[j] >= np.float32(tau))
         for j in (4, 5)]))
    np.testing.assert_array_equal(emitted, want)
    assert sq.records_reemitted == want.size


# -- drift sentinel ---------------------------------------------------------

def test_sentinel_triggers_on_drift_and_stays_quiet_on_control():
    """Table 3's drift scenario: appending the shifted Beta(0.01, 2) half
    trips the sentinel and auto re-validates; appending a fresh
    same-distribution sample does not."""
    train, shifted = make_drift_pair(n=200_000, seed=0)
    control = make_beta(200_000, 0.01, 1.0, seed=99)
    q = SUPGQuery(target="recall", gamma=0.9, budget=4000, method="is")

    def run(appended):
        labels = np.concatenate([train.labels, appended.labels])
        shards = [np.ascontiguousarray(a)
                  for a in np.array_split(train.scores, 4)]
        with SelectionEngine(shards, num_bins=1024,
                             use_kernel=False) as eng:
            sent = DriftSentinel(eng, array_oracle(labels),
                                 probe_budget=4096, sigma=4.0)
            watch = sent.watch(q, key=jax.random.PRNGKey(0))
            tau0 = watch.tau
            IngestPlane(eng).append(appended.scores)
            rep = sent.audit(watch, key=jax.random.PRNGKey(1))
            return sent, watch, tau0, rep

    sent, watch, tau0, rep = run(shifted)
    assert rep.drifted and rep.revalidated and rep.epoch == 1
    assert rep.tau_before == tau0 and watch.tau == rep.tau_after
    assert watch.epoch == 1                  # re-baselined on the new epoch
    assert rep.revalidation_spent > 0
    assert (sent.checks, sent.triggers, sent.revalidations) == (1, 1, 1)

    sent, watch, tau0, rep = run(control)
    assert not rep.drifted and not rep.revalidated
    assert watch.tau == tau0                 # nothing re-validated
    assert (sent.checks, sent.triggers, sent.revalidations) == (1, 0, 0)


# -- serve surface ----------------------------------------------------------

def test_server_live_surface_counters(corpus):
    """subscribe(audit=True) + append: the scheduler certifies, audits the
    new epoch, re-emits the catch-up walk, and the stats snapshot carries
    the live counters."""
    ds, shards = corpus
    eng = SelectionEngine(shards[:4], **ENGINE_KW)
    got = []
    sink = CallbackSink(
        lambda sid, idx, folded: got.append((sid, np.asarray(idx).copy())))
    with SelectionServer(eng, array_oracle(ds.labels),
                         sentinel_probe_budget=512) as srv:
        sq = srv.subscribe(QUERIES[0], key=jax.random.PRNGKey(1),
                           sink=sink, audit=True)
        tau = sq.wait_certified(timeout=300)
        assert tau == pytest.approx(sq.tau)
        n_certified = len(got)               # certification walk output
        assert srv.append(shards[4]) == 1
        deadline = time.monotonic() + 300
        while sq.emissions < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert sq.emissions == 1 and sq.epoch == 1
        assert sq.last_error is None
        stats = srv.stats()
    assert stats.epochs == 1
    assert stats.records_ingested == SHARD
    assert stats.standing_queries == 1
    assert stats.standing_emissions == 1
    assert stats.sentinel_checks >= 1
    assert "live:" in stats.format()
    assert all(sid == 4 for sid, _ in got[n_certified:])


def test_server_append_and_subscribe_refused_after_close(corpus):
    ds, shards = corpus
    srv = SelectionServer(SelectionEngine(shards[:1], **ENGINE_KW),
                          array_oracle(ds.labels))
    srv.close()
    with pytest.raises(Exception, match="closed"):
        srv.append(shards[1])
    with pytest.raises(Exception, match="closed"):
        srv.subscribe(QUERIES[0])


# -- merge/fold properties (satellite: split-corpus bitwise invariants) -----

@settings(max_examples=15)
@given(st.integers(0, 2**31 - 1), st.integers(1, 400))
def test_chunk_sketch_fold_split_invariance(seed, chunk):
    """Folding a prefix of per-chunk sketches, then merging the rest on
    top, is bit-for-bit the full left fold — counts, sum_w, sum_a, and
    both weight schemes' raw masses. This is the exact operation
    `_append_shards` performs on the global sketch, so it is the whole
    incremental-ingestion bitwise story in one invariant; it holds for
    tile-aligned and ragged chunk sizes alike."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 3000))
    scores = rng.random(n).astype(np.float32)
    parts = [binned.chunk_sketch_stats(scores[i:i + chunk], 64,
                                       use_kernel=False)
             for i in range(0, n, chunk)]
    sketches = [p[0] for p in parts]
    full = binned.merge_sketches(*sketches)
    for k in {1, len(sketches) // 2, len(sketches)}:
        prefix = binned.merge_sketches(*sketches[:k])
        refold = binned.merge_sketches(prefix, *sketches[k:])
        np.testing.assert_array_equal(np.asarray(full.counts),
                                      np.asarray(refold.counts))
        np.testing.assert_array_equal(np.asarray(full.sum_w),
                                      np.asarray(refold.sum_w))
        np.testing.assert_array_equal(np.asarray(full.sum_a),
                                      np.asarray(refold.sum_a))
        # raw sampling masses (sqrt and a schemes) fold the same way
        for j in (1, 2):
            masses = np.asarray([p[j] for p in parts], np.float64)
            whole = sampling.append_cdf(np.empty(0, np.float64), masses)
            grown = sampling.append_cdf(
                sampling.append_cdf(np.empty(0, np.float64), masses[:k]),
                masses[k:])
            np.testing.assert_array_equal(whole, grown)


@settings(max_examples=25)
@given(st.lists(st.floats(0.0, 10.0), min_size=1, max_size=50),
       st.integers(0, 50))
def test_append_cdf_continues_cold_cumsum_bitwise(masses, split):
    """`append_cdf` over a split mass list equals the cold cumsum over
    the whole list, element-for-element bitwise."""
    m = np.asarray(masses, np.float64)
    k = min(split, m.size)
    cold = sampling.append_cdf(np.empty(0, np.float64), m)
    grown = sampling.append_cdf(
        sampling.append_cdf(np.empty(0, np.float64), m[:k]), m[k:])
    np.testing.assert_array_equal(cold, grown)
