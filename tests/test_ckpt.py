"""Checkpoint manager: atomicity, keep-k, async, elastic restore."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.optim import adamw


def _params():
    return {"layer": {"w": jnp.arange(12.0).reshape(3, 4),
                      "b": jnp.ones(4)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    params = _params()
    opt = adamw.init(params)
    mgr.save(7, params, opt, extra={"data_seed": 42})
    p2, o2, step, extra = mgr.restore()
    assert step == 7
    assert extra["data_seed"] == 42
    np.testing.assert_allclose(np.asarray(p2["layer"]["w"]),
                               np.asarray(params["layer"]["w"]))
    assert isinstance(o2, adamw.AdamWState)
    assert int(o2.step) == 0


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _params())
    assert mgr.all_steps() == [3, 4]


def test_stale_tmp_cleanup(tmp_path):
    (tmp_path / "tmp.0000000009.0").mkdir()
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _params())
    assert not list(pathlib.Path(tmp_path).glob("tmp.*"))


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(3, _params(), None)
    _, _, step, _ = mgr.restore()       # restore waits for the writer
    assert step == 3


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    for s in (1, 2, 3):
        p = jax.tree.map(lambda x, s=s: x * s, _params())
        mgr.save(s, p)
    p2, _, step, _ = mgr.restore(step=2)
    assert step == 2
    np.testing.assert_allclose(np.asarray(p2["layer"]["b"]), 2.0)


def test_elastic_restore_with_mesh(tmp_path):
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_test_mesh
    mgr = CheckpointManager(tmp_path)
    params = _params()
    mgr.save(1, params)
    mesh = make_test_mesh((1, 1))
    specs = {"layer": {"w": P(None, None), "b": P(None)}}
    p2, _, _, _ = mgr.restore(mesh=mesh, specs=specs)
    np.testing.assert_allclose(np.asarray(p2["layer"]["w"]),
                               np.asarray(params["layer"]["w"]))


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        CheckpointManager(tmp_path).restore()
