"""Optimizer + gradient compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, grad_compress


def test_adamw_minimizes_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                            weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw.apply(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lr0 = float(adamw.schedule(cfg, jnp.asarray(1)))
    lr_peak = float(adamw.schedule(cfg, jnp.asarray(10)))
    lr_end = float(adamw.schedule(cfg, jnp.asarray(100)))
    assert lr0 == pytest.approx(0.1, rel=1e-3)
    assert lr_peak == pytest.approx(1.0, rel=1e-3)
    assert lr_end == pytest.approx(0.1, rel=1e-2)


def test_int8_quantization_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(0, 3, 1000),
                    jnp.float32)
    q, scale, res = grad_compress.quantize_int8(x)
    err = float(jnp.max(jnp.abs(grad_compress.dequantize_int8(q, scale)
                                + res - x)))
    assert err < 1e-5          # value = dequant + residual exactly


def test_error_feedback_reduces_bias():
    """With error feedback, repeated compression of a constant gradient
    converges to zero accumulated error (mean of dequantized ~= truth)."""
    g = jnp.asarray([0.001, 1.0, -0.5])
    res = jnp.zeros(3)
    acc = jnp.zeros(3)
    for _ in range(64):
        q, scale, res = grad_compress.quantize_int8(g + res)
        acc = acc + grad_compress.dequantize_int8(q, scale)
    np.testing.assert_allclose(np.asarray(acc / 64), np.asarray(g),
                               atol=1e-3)
