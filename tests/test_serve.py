"""Serving plane: TokenBucket semantics, SelectionServer admission
control, queue timeouts, per-tenant quota enforcement inside coalesced
drains, paced-drain equivalence, and ServerStats consistency."""
import threading
import time

import numpy as np
import pytest

import jax

from repro.core.engine import SelectionEngine
from repro.core.oracle import array_oracle
from repro.core.queries import JointSUPGQuery, SUPGQuery
from repro.data.synthetic import make_beta
from repro.serve import (AdmissionError, BudgetExceededError,
                         QueueTimeoutError, RateLimitError, SelectionServer,
                         ServerClosedError, TokenBucket)


class _FakeTime:
    """Hand-driven clock + sleep pair for deterministic bucket tests."""

    def __init__(self):
        self.now = 0.0

    def clock(self):
        return self.now

    def sleep(self, s):
        self.now += s


def _bucket(rate, burst):
    ft = _FakeTime()
    return TokenBucket(rate, burst, clock=ft.clock, sleep=ft.sleep), ft


# -- TokenBucket --------------------------------------------------------------

def test_bucket_burst_then_pays_rate():
    bucket, ft = _bucket(rate=10.0, burst=5)
    assert bucket.acquire(5) == 0.0          # starts full: burst is free
    assert bucket.acquire(3) == pytest.approx(0.3)   # 3 tokens at 10/s
    assert bucket.acquired == 8
    assert bucket.wait_s == pytest.approx(0.3)
    # refill is capped at capacity: a long idle stretch buys one burst,
    # not unbounded credit
    ft.now += 100.0
    assert bucket.acquire(5) == 0.0
    assert bucket.acquire(1) == pytest.approx(0.1)


def test_bucket_try_acquire_never_blocks():
    bucket, ft = _bucket(rate=10.0, burst=4)
    assert bucket.try_acquire(4)
    assert not bucket.try_acquire(1)         # empty, and try never waits
    ft.now += 0.1                            # 1 token refilled
    assert bucket.try_acquire(1)
    assert not bucket.try_acquire(5)         # over capacity: always False
    assert bucket.try_acquire(0)             # degenerate: trivially granted


def test_bucket_over_capacity_acquire_raises_typed():
    bucket, _ = _bucket(rate=100.0, burst=8)
    with pytest.raises(RateLimitError, match="exceeds bucket capacity"):
        bucket.acquire(9)
    assert bucket.acquire(8) == 0.0          # bucket still usable after


def test_bucket_zero_capacity_rejects_not_deadlocks():
    """The degenerate zero-capacity bucket can never satisfy a nonzero
    acquire; it must fail fast with the typed error, never wait."""
    bucket, ft = _bucket(rate=5.0, burst=0)
    with pytest.raises(RateLimitError):
        bucket.acquire(1)
    assert not bucket.try_acquire(1)
    assert bucket.acquire(0) == 0.0          # zero-token acquire is free
    assert ft.now == 0.0                     # no sleep ever happened
    assert bucket.acquired == 0


def test_bucket_concurrent_acquirers_account_all_tokens():
    bucket = TokenBucket(rate=1e6, burst=64)
    total = 200
    done = []

    def worker():
        for _ in range(total // 4):
            bucket.acquire(1)
        done.append(1)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert len(done) == 4 and bucket.acquired == total


# -- server fixtures ----------------------------------------------------------

def _dataset(n=50_000, seed=12):
    ds = make_beta(n, 0.02, 1.0, seed=seed)
    return ds, array_oracle(ds.labels)


def _engine(ds, shards=4):
    return SelectionEngine(np.array_split(ds.scores, shards),
                           num_bins=1024, use_kernel=False)


def _batch():
    return [
        SUPGQuery(target="recall", gamma=0.9, budget=2000, method="is"),
        SUPGQuery(target="precision", gamma=0.8, budget=2000, method="is"),
        JointSUPGQuery(gamma_recall=0.8, stage_budget=2000),
        SUPGQuery(target="recall", gamma=0.85, budget=1500,
                  method="uniform"),
    ]


class _GatedOracle:
    """Oracle whose fn blocks until released — holds a server slot open."""

    def __init__(self, labels):
        self.inner = array_oracle(labels)
        self.gate = threading.Event()
        self.calls = 0

    def __call__(self, idx):
        self.calls += 1
        assert self.gate.wait(timeout=60), "gated oracle never released"
        return self.inner(idx)


# -- acceptance: server path is bit-for-bit the library path ------------------

def test_server_results_bit_for_bit_vs_run_many():
    """Admission order, queue waits, tenant metering, and session-pool
    scheduling change *when* the oracle runs, never *what* a query
    returns: the served results equal engine.run_many exactly."""
    ds, oracle = _dataset()
    qs = _batch()
    key = jax.random.PRNGKey(7)
    keys = list(jax.random.split(key, len(qs)))

    with _engine(ds) as engine:
        ref = engine.run_many(key, oracle, qs)

    with SelectionServer(_engine(ds), oracle, max_inflight=2, sessions=2,
                         quotas={"a": 10**9}) as server:
        handles = [server.submit(q, tenant="a" if i % 2 else "b", key=k)
                   for i, (q, k) in enumerate(zip(qs, keys))]
        out = [h.result(timeout=120) for h in handles]
        stats = server.stats()

    for r, o in zip(ref, out):
        # tau, counts, and masks are the guarantee; per-query oracle_calls
        # *attribution* is scheduling-dependent (earliest submitter claims
        # shared records), exactly as across run_many concurrency levels.
        assert r.tau == o.tau
        assert r.total_selected == o.total_selected
        np.testing.assert_array_equal(np.concatenate(r.masks),
                                      np.concatenate(o.masks))
    assert stats.completed == len(qs) and stats.failed == 0
    assert stats.tenants["a"].oracle_charged > 0


def test_server_paced_results_match_unpaced():
    """A throttled channel slows drains down; it must not change results.
    The bucket must actually engage (wait_s > 0) for this to test pacing."""
    ds, oracle = _dataset(30_000)
    qs = _batch()[:2]
    key = jax.random.PRNGKey(3)
    keys = list(jax.random.split(key, len(qs)))

    with SelectionServer(_engine(ds), oracle) as fast:
        ref = [fast.submit(q, key=k).result(timeout=120)
               for q, k in zip(qs, keys)]

    with SelectionServer(_engine(ds), oracle, rate=40_000, burst=256,
                         max_batch=256) as paced:
        out = [paced.submit(q, key=k) for q, k in zip(qs, keys)]
        out = [h.result(timeout=120) for h in out]
        stats = paced.stats()
    assert paced.bucket is not None and paced.bucket.wait_s > 0.0
    assert stats.throttle_wait_s == paced.bucket.wait_s
    for r, o in zip(ref, out):
        assert r.tau == o.tau
        np.testing.assert_array_equal(np.concatenate(r.masks),
                                      np.concatenate(o.masks))


# -- admission control --------------------------------------------------------

def test_admission_queue_full_rejects_synchronously():
    ds, _ = _dataset(20_000)
    gated = _GatedOracle(ds.labels)
    q = SUPGQuery(target="recall", gamma=0.9, budget=500, method="is")
    server = SelectionServer(_engine(ds, shards=2), gated,
                             max_inflight=1, queue_depth=1)
    try:
        first = server.submit(q, tenant="t0")
        deadline = time.monotonic() + 30
        while server.stats().in_flight < 1:       # wait for admission
            assert time.monotonic() < deadline, "first query never admitted"
            time.sleep(0.005)
        second = server.submit(q, tenant="t1")    # fills the overflow queue
        with pytest.raises(AdmissionError, match="admission queue full"):
            server.submit(q, tenant="t2")
        stats = server.stats()
        assert stats.rejected == 1 and stats.tenants["t2"].rejected == 1
        gated.gate.set()
        assert first.result(timeout=60).total_selected >= 0
        assert second.result(timeout=60).total_selected >= 0
    finally:
        gated.gate.set()
        server.close()
    assert server.stats().completed == 2


def test_queue_timeout_expires_with_typed_error():
    ds, _ = _dataset(20_000)
    gated = _GatedOracle(ds.labels)
    q = SUPGQuery(target="recall", gamma=0.9, budget=500, method="is")
    server = SelectionServer(_engine(ds, shards=2), gated,
                             max_inflight=1, queue_timeout_s=0.15)
    try:
        first = server.submit(q)
        deadline = time.monotonic() + 30
        while server.stats().in_flight < 1:
            assert time.monotonic() < deadline
            time.sleep(0.005)
        starved = server.submit(q)            # queued behind the held slot
        time.sleep(0.3)                       # out-wait the deadline...
        gated.gate.set()                      # ...then free the slot
        assert first.result(timeout=60) is not None
        with pytest.raises(QueueTimeoutError, match="waited"):
            starved.result(timeout=60)
        stats = server.stats()
        assert stats.timed_out == 1 and stats.completed == 1
        assert stats.tenants["default"].in_flight == 0
    finally:
        gated.gate.set()
        server.close()


def test_submit_after_close_raises_server_closed():
    ds, oracle = _dataset(20_000)
    server = SelectionServer(_engine(ds, shards=2), oracle)
    server.close()
    with pytest.raises(ServerClosedError):
        server.submit(SUPGQuery(target="recall", gamma=0.9, budget=500))
    server.close()                            # idempotent


def test_close_abandon_fails_pending_handles():
    ds, _ = _dataset(20_000)
    gated = _GatedOracle(ds.labels)
    q = SUPGQuery(target="recall", gamma=0.9, budget=500, method="is")
    server = SelectionServer(_engine(ds, shards=2), gated, max_inflight=1)
    held = server.submit(q)
    queued = server.submit(q)
    server.close(abandon=True)
    gated.gate.set()
    for h in (held, queued):
        with pytest.raises(ServerClosedError):
            h.result(timeout=60)


# -- tenant quotas ------------------------------------------------------------

def test_tenant_quota_exhausted_mid_drain_fails_alone():
    """A tenant blowing its quota inside a coalesced drain poisons only
    its own query; co-batched tenants complete, and the server keeps
    serving the broke tenant's *later* queries that fit the remainder."""
    ds, oracle = _dataset()
    qs = _batch()[:2]
    keys = list(jax.random.split(jax.random.PRNGKey(7), 2))
    with SelectionServer(_engine(ds), oracle, max_inflight=4,
                         quotas={"broke": 300, "rich": 10**9}) as server:
        hb = server.submit(qs[0], tenant="broke", key=keys[0])  # budget 2000
        hr = server.submit(qs[1], tenant="rich", key=keys[1])
        with pytest.raises(BudgetExceededError, match="tenant 'broke'"):
            hb.result(timeout=120)
        assert hr.result(timeout=120).total_selected > 0
        # the plane survives the failure: a small query still fits under
        # what is left of the quota
        tiny = SUPGQuery(target="recall", gamma=0.9, budget=100,
                         method="is")
        assert server.submit(tiny, tenant="broke",
                             key=keys[0]).result(timeout=120) is not None
        stats = server.stats()
    broke = stats.tenants["broke"]
    assert broke.failed == 1 and broke.completed == 1
    assert broke.oracle_charged <= 300        # quota held mid-drain
    assert stats.tenants["rich"].completed == 1


def test_session_ledger_parent_direct():
    """The hook under the server: QuerySession.submit(ledger_parent=...)
    chains the per-query ledger under a shared quota, enforced inside
    the session's coalesced drains with fail-alone semantics."""
    from repro.core.oracle import BudgetLedger
    ds, oracle = _dataset()
    quota = BudgetLedger(300, label="tenant 'q' quota")
    qs = _batch()[:2]
    keys = list(jax.random.split(jax.random.PRNGKey(7), 2))
    with _engine(ds) as engine:
        with engine.session(oracle) as sess:
            metered = sess.submit(qs[0], key=keys[0], ledger_parent=quota)
            free = sess.submit(qs[1], key=keys[1])
            with pytest.raises(BudgetExceededError, match="tenant 'q'"):
                metered.result()
            assert free.result().total_selected > 0   # pumpable after
            assert quota.charged <= 300


def test_default_quota_meters_unknown_tenants():
    ds, oracle = _dataset(20_000)
    q = SUPGQuery(target="recall", gamma=0.9, budget=2000, method="is")
    with SelectionServer(_engine(ds, shards=2), oracle,
                         default_quota=100) as server:
        with pytest.raises(BudgetExceededError, match="quota"):
            server.submit(q, tenant="anon").result(timeout=120)
        assert server.stats().tenants["anon"].quota == 100


# -- stats --------------------------------------------------------------------

def test_server_stats_snapshot_consistency():
    ds, oracle = _dataset()
    qs = _batch()
    keys = list(jax.random.split(jax.random.PRNGKey(9), len(qs)))
    with SelectionServer(_engine(ds), oracle, max_inflight=2,
                         quotas={"a": 10**9}) as server:
        for q, k in zip(qs, keys):
            server.submit(q, tenant="a", key=k).result(timeout=120)
        stats = server.stats()
    assert stats.admitted == stats.completed == len(qs)
    assert stats.failed == stats.rejected == stats.timed_out == 0
    assert stats.queued == stats.in_flight == 0
    assert stats.oracle_calls > 0
    assert stats.records_labeled >= stats.tenants["a"].oracle_charged > 0
    assert 0.0 < stats.p50_s <= stats.p99_s
    assert stats.rounds > 0 and stats.drains > 0
    text = stats.format()
    assert "tenant 'a'" in text and "p99" in text and "oracle" in text
