"""Fault tolerance: straggler detection, restart-and-resume training."""
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import DeterministicSource
from repro.launch.fault import (HeartbeatConfig, HeartbeatMonitor,
                                LoopConfig, RestartRequired, TrainLoop)


def test_monitor_flags_missing_heartbeat():
    mon = HeartbeatMonitor(3, HeartbeatConfig(deadline_s=10))
    now = 1000.0
    for w in range(3):
        mon.report(w, 1.0, now=now)
    assert mon.dead_workers(now=now + 5) == []
    mon.report(0, 1.0, now=now + 20)
    mon.report(1, 1.0, now=now + 20)
    assert mon.dead_workers(now=now + 20) == [2]


def test_monitor_flags_straggler():
    mon = HeartbeatMonitor(4, HeartbeatConfig(min_history=4,
                                              straggler_mad_k=5.0))
    for _ in range(8):
        for w in range(3):
            mon.report(w, 1.0 + 0.01 * w)
        mon.report(3, 30.0)
    assert mon.stragglers() == [3]


def test_train_loop_restarts_and_completes(tmp_path):
    """Inject a failure mid-run; the loop restores and finishes with the
    exact same data stream (deterministic source)."""
    ckpt = CheckpointManager(tmp_path)
    seen = []
    fail_once = {"armed": True}

    def step_fn(params, opt, batch):
        step_id = int(batch["x"][0])
        if fail_once["armed"] and step_id == 7:
            fail_once["armed"] = False
            raise RestartRequired("injected failure")
        seen.append(step_id)
        return params + 1, opt, {"loss": 0.0}

    src = DeterministicSource(
        lambda rng, step: {"x": np.full(2, step)}, seed=0)
    loop = TrainLoop(step_fn, src, ckpt,
                     LoopConfig(total_steps=10, ckpt_every=2))
    ckpt.save(0, np.asarray(0.0), None)
    params, _, step = loop.run(np.asarray(0.0), None, start_step=0)
    assert step == 10
    assert loop.restarts == 1
    # steps replay from the last checkpoint (6) after failing at 7
    assert seen == [0, 1, 2, 3, 4, 5, 6, 6, 7, 8, 9]
    # params restored to the step-6 checkpoint value (6) + 4 replayed steps
    assert float(params) == 10.0


def test_loop_gives_up_after_max_restarts(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    ckpt.save(0, np.asarray(0.0), None)

    def always_fail(params, opt, batch):
        raise RestartRequired("down")

    src = DeterministicSource(lambda rng, step: {"x": np.zeros(1)}, seed=0)
    loop = TrainLoop(always_fail, src, ckpt,
                     LoopConfig(total_steps=5, max_restarts=2))
    with pytest.raises(RestartRequired):
        loop.run(np.asarray(0.0), None)
