"""Tests for uniform / importance samplers (Theorem-1 weights)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sampling


def test_sqrt_weights_normalized_and_defensive():
    scores = jnp.asarray(np.random.default_rng(0).beta(0.1, 1, 1000),
                         jnp.float32)
    w = sampling.sqrt_proxy_weights(scores)
    assert float(jnp.sum(w)) == pytest.approx(1.0, abs=1e-4)
    # defensive floor: every record keeps >= kappa/n mass
    assert float(jnp.min(w)) >= 0.1 / 1000 * 0.999


def test_degenerate_all_zero_scores_fall_back_to_uniform():
    w = sampling.sqrt_proxy_weights(jnp.zeros(100))
    np.testing.assert_allclose(np.asarray(w), 1 / 100, rtol=1e-5)


def test_inverse_cdf_distribution():
    """Draw frequencies converge to the target probabilities."""
    probs = jnp.asarray([0.5, 0.25, 0.125, 0.125])
    s = 40_000
    ws = sampling.sample_weighted(jax.random.PRNGKey(0), probs, s)
    freq = np.bincount(np.asarray(ws.indices), minlength=4) / s
    np.testing.assert_allclose(freq, np.asarray(probs), atol=0.02)


def test_reweighting_unbiased():
    """E[O(x) m(x)] over a weighted sample == population mean of O."""
    rng = np.random.default_rng(1)
    n = 50_000
    scores = rng.beta(0.05, 1, n).astype(np.float32)
    labels = (rng.random(n) < scores).astype(np.float32)
    ws = sampling.draw_oracle_sample(jax.random.PRNGKey(2),
                                     jnp.asarray(scores), 20_000,
                                     scheme="sqrt")
    est = float(np.mean(labels[np.asarray(ws.indices)] * np.asarray(ws.m)))
    assert est == pytest.approx(float(labels.mean()), rel=0.15)


def test_sqrt_beats_uniform_variance_on_calibrated_proxy():
    """Theorem 1: sqrt weights reduce the estimator variance vs uniform."""
    rng = np.random.default_rng(2)
    n, s, reps = 200_000, 2000, 30
    scores = rng.beta(0.01, 1, n).astype(np.float32)
    labels = (rng.random(n) < scores).astype(np.float32)
    sj = jnp.asarray(scores)

    def estimates(scheme, seed0):
        vals = []
        for t in range(reps):
            ws = sampling.draw_oracle_sample(
                jax.random.PRNGKey(seed0 + t), sj, s, scheme=scheme)
            vals.append(np.mean(labels[np.asarray(ws.indices)]
                                * np.asarray(ws.m)))
        return np.var(vals)

    assert estimates("sqrt", 0) < estimates("uniform", 1000)


def test_masked_sampling_stays_in_mask():
    scores = jnp.linspace(0, 1, 1000)
    mask = (scores >= 0.8).astype(jnp.float32)
    ws = sampling.sample_weighted_masked(jax.random.PRNGKey(3),
                                         jnp.ones(1000), mask, 500)
    assert np.all(np.asarray(ws.indices) >= 800)


@given(st.integers(10, 2000), st.integers(1, 500))
@settings(max_examples=20, deadline=None)
def test_uniform_sample_shape_and_m(n, s):
    ws = sampling.sample_uniform(jax.random.PRNGKey(0), n, s)
    assert ws.indices.shape == (s,)
    assert np.all(np.asarray(ws.indices) < n)
    np.testing.assert_allclose(np.asarray(ws.m), 1.0)


# -- hierarchical chunk-mass primitives --------------------------------------

def test_chunk_raw_masses_ignore_sentinels():
    rng = np.random.default_rng(7)
    scores = rng.random(5000).astype(np.float32)
    scores[::7] = -1.0                         # unscored sentinel
    s_sqrt, s_a = sampling.chunk_raw_masses(scores)
    a = np.clip(scores, 0.0, 1.0)              # sentinel clips to 0 raw mass
    assert s_sqrt == pytest.approx(float(np.sum(np.sqrt(a), dtype=np.float64)))
    assert s_a == pytest.approx(float(np.sum(a, dtype=np.float64)))


def test_defensive_chunk_mass_is_sum_of_record_probs():
    """A chunk's defensive mass from the cached raw sums must equal the sum
    of its records' p(x) — the identity that makes the hierarchical draw
    reproduce the dense defensive mixture exactly."""
    rng = np.random.default_rng(8)
    n_total, kappa = 20_000, 0.1
    scores = rng.beta(0.3, 1.0, n_total).astype(np.float32)
    z = float(np.sum(np.sqrt(scores), dtype=np.float64))
    chunks = np.array_split(scores, 7)
    sizes = np.asarray([c.shape[0] for c in chunks], np.int64)
    raws = np.asarray([sampling.chunk_raw_masses(c)[0] for c in chunks])
    masses = sampling.defensive_chunk_mass(raws, sizes, z, kappa, n_total)
    for c, m in zip(chunks, masses):
        p = sampling.defensive_probs(c, "sqrt", z, kappa, n_total)
        assert float(np.sum(p, dtype=np.float64)) == pytest.approx(m,
                                                                   rel=1e-5)
    # all chunk masses together carry the whole defensive mixture
    assert float(masses.sum()) == pytest.approx(1.0, rel=1e-5)


def test_defensive_probs_match_dense_formula():
    """defensive_probs must be bit-identical to the dense per-record
    formula (float32), for both schemes."""
    rng = np.random.default_rng(9)
    scores = rng.random(4096).astype(np.float32)
    n_total, kappa, z = 100_000, 0.1, 777.5
    for scheme in ("sqrt", "prop"):
        a = np.clip(scores, 0.0, 1.0)
        raw = np.sqrt(a) if scheme == "sqrt" else a
        dense = ((1.0 - kappa) * raw / z + kappa / n_total).astype(np.float32)
        got = sampling.defensive_probs(scores, scheme, z, kappa, n_total)
        np.testing.assert_array_equal(got, dense)
