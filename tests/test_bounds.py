"""Unit + property tests for the Lemma-1 confidence bounds."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bounds


def test_ub_lb_symmetry():
    assert float(bounds.ub(0.5, 0.1, 100, 0.05)) == pytest.approx(
        1.0 - float(bounds.lb(0.5, 0.1, 100, 0.05)))


def test_zero_sigma_gives_tight_bounds():
    assert float(bounds.ub(0.3, 0.0, 100, 0.05)) == pytest.approx(0.3)
    assert float(bounds.lb(0.3, 0.0, 100, 0.05)) == pytest.approx(0.3)


def test_empty_prefix_is_infinite():
    assert np.isinf(float(bounds.gaussian_width(1.0, 0, 0.05)))


@given(st.floats(0.01, 0.99), st.floats(0.01, 0.5),
       st.integers(10, 10_000), st.floats(0.001, 0.2))
@settings(max_examples=50, deadline=None)
def test_width_monotonicity(mu, sigma, s, delta):
    """Width shrinks with s, grows as delta shrinks."""
    w = float(bounds.gaussian_width(sigma, s, delta))
    w_more_samples = float(bounds.gaussian_width(sigma, 4 * s, delta))
    w_stricter = float(bounds.gaussian_width(sigma, s, delta / 10))
    assert w_more_samples == pytest.approx(w / 2, rel=1e-5)
    assert w_stricter > w


def test_lemma1_coverage_bernoulli():
    """Empirical coverage: UB >= true mean with frequency >= 1 - delta."""
    rng = np.random.default_rng(0)
    p_true, s, delta, trials = 0.1, 500, 0.1, 400
    miss = 0
    for _ in range(trials):
        z = (rng.random(s) < p_true).astype(np.float32)
        mu, sg = bounds.sample_mean_std(z)
        if float(bounds.ub(mu, sg, s, delta)) < p_true:
            miss += 1
    assert miss / trials <= delta + 0.05


@given(st.lists(st.floats(0.0, 1.0), min_size=2, max_size=200))
@settings(max_examples=50, deadline=None)
def test_prefix_stats_match_naive(xs):
    z = np.asarray(xs, np.float32)
    mu, sg, n = bounds.prefix_mean_std(z)
    for i in (0, len(xs) // 2, len(xs) - 1):
        prefix = z[:i + 1]
        assert float(mu[i]) == pytest.approx(float(prefix.mean()), abs=1e-4)
        assert float(sg[i]) == pytest.approx(float(prefix.std()), abs=1e-3)
        assert float(n[i]) == i + 1


def test_weighted_prefix_reduces_to_uniform():
    z = np.asarray([1, 0, 1, 1, 0], np.float32)
    w = np.ones_like(z)
    mu_w, sg_w, ess = bounds.weighted_prefix_mean_std(z, w)
    mu_u, sg_u, n = bounds.prefix_mean_std(z)
    np.testing.assert_allclose(mu_w, mu_u, atol=1e-6)
    np.testing.assert_allclose(ess, n, atol=1e-4)


def test_masked_prefix_counts_only_masked():
    z = np.asarray([1.0, 0.5, 0.0, 1.0], np.float32)
    m = np.asarray([1, 0, 1, 1], np.float32)
    mu, sg, n = bounds.masked_prefix_mean_std(z, m)
    assert float(n[-1]) == 3
    assert float(mu[-1]) == pytest.approx((1.0 + 0.0 + 1.0) / 3)
