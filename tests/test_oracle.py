"""Batched labeling channel: BatchingOracle coalescing, BudgetLedger
views, per-query enforcement inside coalesced drains, the plain-callable
adapter, and the vectorized BudgetedOracle facade."""
import numpy as np
import pytest

from repro.core.oracle import (BatchingOracle, BudgetedOracle,
                               BudgetExceededError, BudgetLedger,
                               OracleClient, array_oracle, as_oracle_client)


def _counting_oracle(labels):
    """array_oracle plus a log of every underlying fn invocation."""
    arr = np.asarray(labels, np.float32)
    calls = []

    def fn(indices):
        idx = np.asarray(indices, np.int64)
        calls.append(idx.copy())
        return arr[idx]

    return fn, calls


# -- coalescing ---------------------------------------------------------------

def test_drain_coalesces_tickets_into_one_fn_call():
    labels = np.arange(100) % 2
    fn, calls = _counting_oracle(labels)
    client = BatchingOracle(fn)
    la, lb = BudgetLedger(50), BudgetLedger(50)
    ta = client.submit([3, 1, 4, 1, 5], ledger=la)
    tb = client.submit([5, 9, 2, 6], ledger=lb)
    client.drain()
    np.testing.assert_array_equal(ta.result(), labels[[3, 1, 4, 1, 5]])
    np.testing.assert_array_equal(tb.result(), labels[[5, 9, 2, 6]])
    # one fn call for both queries; the shared record 5 labeled once,
    # charged to the earlier ticket
    assert len(calls) == 1 and client.fn_calls == 1
    np.testing.assert_array_equal(calls[0], [1, 2, 3, 4, 5, 6, 9])
    assert la.charged == 4 and lb.charged == 3
    assert client.records_labeled == 7 == client.cache_size


def test_cache_shared_across_queries_and_drains():
    fn, calls = _counting_oracle(np.ones(50))
    client = BatchingOracle(fn)
    client.submit(np.arange(10), ledger=BudgetLedger(10)).result()
    lb = BudgetLedger(5)
    out = client.submit([2, 4, 6], ledger=lb).result()
    np.testing.assert_array_equal(out, 1.0)
    assert len(calls) == 1          # fully answered from the shared cache
    assert lb.charged == 0          # free for the second query


def test_max_batch_micro_batches_and_auto_drain():
    fn, calls = _counting_oracle(np.zeros(1000))
    client = BatchingOracle(fn, max_batch=8)
    t = client.submit(np.arange(20), ledger=BudgetLedger(100))
    # 20 pending new records >= max_batch triggered the submit-time drain
    assert t.done
    assert [c.size for c in calls] == [8, 8, 4]
    # under max_batch nothing fires until the explicit barrier
    t2 = client.submit([100, 101], ledger=BudgetLedger(10))
    assert not t2.done and len(calls) == 3
    client.drain()
    assert t2.done and [c.size for c in calls] == [8, 8, 4, 2]


def test_ticket_result_drains_implicitly():
    fn, calls = _counting_oracle(np.ones(10))
    client = BatchingOracle(fn)
    t = client.submit([1, 2, 3])            # ledger-less: uncapped
    assert not t.done and not calls
    np.testing.assert_array_equal(t.result(), 1.0)
    assert t.done and len(calls) == 1


def test_oracle_wrong_label_count_poisons_drain():
    client = BatchingOracle(lambda idx: np.zeros(len(idx) + 1))
    t = client.submit([1, 2], ledger=BudgetLedger(10))
    client.drain()      # drains no longer raise: the ticket fails alone
    with pytest.raises(ValueError, match="wrong number"):
        t.result()
    assert client.batch_failures == 1
    assert client.cache_size == 0   # malformed labels are never cached


# -- per-query enforcement inside a coalesced drain ---------------------------

def test_budget_enforced_mid_micro_batch_without_poisoning_cobatched():
    """A coalesced batch that would push one query's ledger past its
    ORACLE LIMIT must fail that query alone: the co-batched query still
    resolves, the failing query is not charged, and the failing query's
    exclusive records are neither labeled nor cached."""
    fn, calls = _counting_oracle(np.ones(100))
    client = BatchingOracle(fn)
    la, lb = BudgetLedger(5), BudgetLedger(100)
    ta = client.submit(np.arange(10), ledger=la)        # needs 10 > 5
    tb = client.submit(np.arange(5, 15), ledger=lb)     # needs 10 <= 100
    client.drain()
    with pytest.raises(BudgetExceededError):
        ta.result()
    np.testing.assert_array_equal(tb.result(), 1.0)
    assert la.charged == 0 and lb.charged == 10
    # records 0..4 were exclusive to the over-budget ticket: never sent to
    # fn, never cached — no label leaks out of a rejected query
    assert len(calls) == 1
    np.testing.assert_array_equal(calls[0], np.arange(5, 15))
    lc = BudgetLedger(100)
    client.submit(np.arange(5), ledger=lc).result()
    assert lc.charged == 5          # still cost fn labels afterwards
    assert len(calls) == 2


def test_budget_cumulative_across_same_ledger_tickets_in_one_drain():
    client = BatchingOracle(array_oracle(np.ones(100)))
    ledger = BudgetLedger(10)
    t1 = client.submit(np.arange(6), ledger=ledger)
    t2 = client.submit(np.arange(6, 12), ledger=ledger)   # 6 + 6 > 10
    client.drain()
    t1.result()                                           # first fits
    with pytest.raises(BudgetExceededError):
        t2.result()
    assert ledger.charged == 6


def test_budget_boundary_exact_fit_allowed():
    oracle = BudgetedOracle(array_oracle(np.zeros(20)), budget=10)
    oracle(np.arange(10))                 # exactly the limit
    assert oracle.calls_used == 10 and oracle.remaining == 0
    oracle(np.arange(10))                 # cached: still free
    with pytest.raises(BudgetExceededError):
        oracle([11])


# -- ledger views -------------------------------------------------------------

def test_labeled_positives_per_query_view_not_session_wide():
    """R1 must reflect only the owning query's sample even when another
    query labeled far more positives through the same channel."""
    labels = np.ones(100, np.float32)
    client = BatchingOracle(array_oracle(labels))
    la, lb = BudgetLedger(50), BudgetLedger(50)
    ta = client.submit([7, 3, 3, 11], ledger=la)
    tb = client.submit(np.arange(40, 80), ledger=lb)
    client.drain()
    ta.result(), tb.result()
    np.testing.assert_array_equal(la.labeled_positives(), [3, 7, 11])
    np.testing.assert_array_equal(lb.labeled_positives(), np.arange(40, 80))


def test_labeled_positives_sorted_regression():
    """Regression: positives used to come back in dict insertion order,
    which stops being deterministic once batches interleave across a
    session's queries — they are now sorted by contract."""
    labels = np.zeros(100, np.float32)
    labels[[2, 50, 97, 13]] = 1.0
    oracle = BudgetedOracle(array_oracle(labels), budget=50)
    oracle([97, 2])                       # insertion order: high then low
    oracle([50, 13, 60, 61])
    pos = oracle.labeled_positives()
    np.testing.assert_array_equal(pos, [2, 13, 50, 97])   # sorted, exact
    # and stable under interleaved resubmission of cached records
    oracle([13, 97, 2])
    np.testing.assert_array_equal(oracle.labeled_positives(),
                                  [2, 13, 50, 97])


# -- vectorized facade --------------------------------------------------------

def test_budgeted_oracle_vectorized_1e6_batch():
    """The per-element dict probe loop is gone: a 1e6-index batch resolves
    through vectorized membership passes with the historical dedup
    accounting (unique records charged once, repeats answered free)."""
    rng = np.random.default_rng(0)
    n = 2_000_000
    labels = (rng.random(n) < 0.01).astype(np.float32)
    fn, calls = _counting_oracle(labels)
    oracle = BudgetedOracle(fn, budget=n)
    idx = rng.integers(0, n, 1_000_000)
    out = oracle(idx)
    np.testing.assert_array_equal(out, labels[idx])
    uniq = np.unique(idx)
    assert oracle.calls_used == uniq.size        # dedup accounting
    assert len(calls) == 1 and calls[0].size == uniq.size
    # the repeat batch is a pure cache pass: no fn call, no budget burn
    out2 = oracle(idx[::-1])
    np.testing.assert_array_equal(out2, labels[idx[::-1]])
    assert oracle.calls_used == uniq.size and len(calls) == 1
    np.testing.assert_array_equal(
        oracle.labeled_positives(), uniq[labels[uniq] > 0.5])


# -- adapter ------------------------------------------------------------------

def test_as_oracle_client_passthrough_and_wrap():
    client = BatchingOracle(array_oracle(np.ones(5)))
    assert as_oracle_client(client) is client
    assert isinstance(client, OracleClient)
    wrapped = as_oracle_client(array_oracle(np.ones(5)), max_batch=3)
    assert isinstance(wrapped, BatchingOracle)
    assert wrapped.max_batch == 3
    with pytest.raises(TypeError):
        as_oracle_client(42)


def test_batching_oracle_rejects_bad_max_batch():
    with pytest.raises(ValueError):
        BatchingOracle(array_oracle(np.ones(5)), max_batch=0)


def test_label_cache_interleaved_insert_order():
    """The linear-merge insert must keep the store sorted across
    interleaved key ranges arriving in separate drains."""
    labels = np.arange(200, dtype=np.float32) % 7
    oracle = BudgetedOracle(array_oracle(labels), budget=200)
    oracle(np.arange(0, 200, 2))            # evens first
    oracle(np.arange(1, 200, 2))            # odds interleave everywhere
    mixed = np.asarray([0, 199, 57, 58, 3, 3, 100])
    np.testing.assert_array_equal(oracle(mixed), labels[mixed])
    assert oracle.calls_used == 200
    np.testing.assert_array_equal(
        oracle.labeled_positives(), np.nonzero(labels > 0.5)[0])


def test_mid_drain_failure_charges_completed_micro_batches():
    """Regression: charging used to happen only after *all* micro-batches
    succeeded, so a failure on chunk k left chunks < k labeled and cached
    but charged to nobody — cumulative real oracle usage could then
    exceed every ledger's ORACLE LIMIT via free retry cache hits. Charges
    now land per completed micro-batch."""
    calls = [0]

    def fn(idx):
        calls[0] += 1
        if calls[0] == 2:
            raise IOError("down")
        return np.zeros(len(idx), np.float32)

    client = BatchingOracle(fn, max_batch=2)
    ledger = BudgetLedger(10)
    # submit-time auto-drain fires; the failed chunk {3,4} poisons the
    # ticket (fail-alone) while chunks {1,2} and {5} complete and stay paid
    t0 = client.submit([1, 2, 3, 4, 5], ledger=ledger)
    with pytest.raises(IOError):
        t0.result()
    assert ledger.charged == 3 == client.records_labeled
    # the retry pays only for what was never labeled
    t = client.submit([1, 2, 3, 4, 5], ledger=ledger)
    np.testing.assert_array_equal(t.result(), 0.0)
    assert ledger.charged == 5              # total == unique records labeled
    assert client.records_labeled == 5


# -- async drain (PR 6) -------------------------------------------------------

def test_drain_async_coalesces_and_resolves_tickets():
    """drain_async labels the pending set in one underlying fn call on the
    drain thread; after the handle settles, every ticket resolves from its
    snapshot exactly like a sync drain."""
    labels = np.arange(100, dtype=np.float32)
    fn, calls = _counting_oracle(labels)
    client = BatchingOracle(fn)
    led = BudgetLedger(50)
    t1 = client.submit([3, 1, 4], ledger=led)
    t2 = client.submit([1, 5, 9], ledger=led)
    handle = client.drain_async()
    assert handle.result() is None          # blocks until resolved, no error
    assert handle.done and handle.exception() is None
    assert handle.tickets == 2 and handle.duration_s >= 0.0
    assert len(calls) == 1                  # one coalesced invocation
    np.testing.assert_array_equal(t1.result(), [3.0, 1.0, 4.0])
    np.testing.assert_array_equal(t2.result(), [1.0, 5.0, 9.0])
    client.close()


def test_drain_async_empty_pending_settles_inline():
    """Zero pending tickets: the handle comes back already settled and no
    drain thread is ever created."""
    client = BatchingOracle(array_oracle(np.ones(10)))
    handle = client.drain_async()
    assert handle.done and handle.tickets == 0
    assert handle.result() is None
    assert client._drain_worker is None     # fast path spawned nothing
    client.close()


def test_drain_async_snapshot_excludes_later_submits():
    """Tickets are popped at drain_async() call time: a submit issued after
    the call belongs to the *next* drain, not the in-flight one — the
    invariant the double-buffered scheduler's determinism rests on."""
    fn, calls = _counting_oracle(np.zeros(50))
    client = BatchingOracle(fn)
    led = BudgetLedger(50)
    t1 = client.submit([1, 2], ledger=led)
    handle = client.drain_async()
    late = client.submit([7, 8], ledger=led)
    handle.result()
    assert handle.tickets == 1
    np.testing.assert_array_equal(t1.result(), 0.0)
    # the late ticket is still pending until the next drain
    assert np.concatenate(calls).tolist() == [1, 2]
    client.drain()
    np.testing.assert_array_equal(late.result(), 0.0)
    client.close()


def test_drain_async_poisoning_parity_with_sync_drain():
    """A mid-drain failure poisons the snapshot's tickets — identical
    semantics to the sync drain: the handle settles cleanly (fail-alone
    means drains never raise for transport errors) while each owning
    ticket carries the typed error."""
    client = BatchingOracle(lambda idx: np.zeros(len(idx) + 1))
    t = client.submit([1, 2], ledger=BudgetLedger(10))
    handle = client.drain_async()
    handle.wait()
    assert handle.exception() is None
    assert handle.batch_failures == 1
    with pytest.raises(ValueError, match="wrong number"):
        t.result()
    # the channel itself is not wedged: a clean retry still works
    ok = BatchingOracle(array_oracle(np.ones(10)))
    t2 = ok.submit([1], ledger=BudgetLedger(5))
    ok.drain_async().result()
    np.testing.assert_array_equal(t2.result(), 1.0)
    ok.close()
    client.close()


def test_close_reaps_drain_worker_and_client_stays_usable():
    """close() joins the drain thread and is idempotent; the client still
    serves synchronous submit/drain afterwards (sessions own the async
    surface, not the channel's whole lifetime)."""
    fn, calls = _counting_oracle(np.ones(20))
    client = BatchingOracle(fn)
    led = BudgetLedger(20)
    client.submit([1, 2], ledger=led)
    client.drain_async().result()
    assert client._drain_worker is not None
    client.close()
    client.close()                          # idempotent
    assert client._drain_worker is None
    t = client.submit([3, 4], ledger=led)   # sync path unaffected
    client.drain()
    np.testing.assert_array_equal(t.result(), 1.0)
    # and drain_async lazily re-creates its worker after a close
    t2 = client.submit([5], ledger=led)
    client.drain_async().result()
    np.testing.assert_array_equal(t2.result(), 1.0)
    client.close()
