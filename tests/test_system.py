"""End-to-end system test: the paper's full pipeline on a tiny stack.

train a proxy LM on the planted-marker corpus -> score the corpus with the
served model -> run a SUPG query against the exact oracle -> the returned
set must satisfy the statistical target.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import SUPGQuery, array_oracle, recall_of, run_query
from repro.data import synthetic
from repro.launch import train as trainlib
from repro.models import model
from repro.optim import adamw


@pytest.fixture(scope="module")
def tiny_cfg():
    return ModelConfig(
        name="tiny-proxy", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
        dtype="float32")


@pytest.fixture(scope="module")
def trained_proxy(tiny_cfg):
    """Train the proxy to classify marker presence via next-token signal:
    sequences are labeled by appending a class token; the proxy score is
    P(class=1 token | sequence)."""
    toks, labels = synthetic.make_token_corpus(2048, 32, 128,
                                               positive_rate=0.3, seed=0)
    params = model.init(jax.random.PRNGKey(0), tiny_cfg)
    opts = trainlib.TrainOptions(adamw=adamw.AdamWConfig(
        lr=3e-3, warmup_steps=10, total_steps=60, weight_decay=0.0))
    step = jax.jit(trainlib.make_train_step(tiny_cfg, opts))
    opt_state = adamw.init(params)
    # supervised stream: predict the class token at EVERY position — the
    # causal model learns it at all post-marker positions, which makes the
    # last-position proxy score sharp with few steps.
    rng = np.random.default_rng(0)
    for i in range(60):
        idx = rng.integers(0, 2048, 64)
        batch_toks = toks[idx].copy()
        y = labels[idx].astype(np.int32)          # 0/1 class tokens
        lab = np.broadcast_to(y[:, None], batch_toks.shape).astype(np.int32)
        params, opt_state, metrics = step(
            params, opt_state, {"tokens": jnp.asarray(batch_toks),
                                "labels": jnp.asarray(lab)})
    return params, toks, labels


def test_proxy_learns_signal(trained_proxy, tiny_cfg):
    params, toks, labels = trained_proxy
    scores = np.asarray(model.proxy_scores(
        params, tiny_cfg, jnp.asarray(toks[:512]), target_token=1))
    pos = scores[labels[:512] > 0.5].mean()
    neg = scores[labels[:512] < 0.5].mean()
    assert pos > neg + 0.1     # informative proxy


def test_supg_query_on_served_scores(trained_proxy, tiny_cfg):
    params, toks, labels = trained_proxy
    scores = np.asarray(model.proxy_scores(
        params, tiny_cfg, jnp.asarray(toks), target_token=1))
    truth = labels > 0.5
    q = SUPGQuery(target="recall", gamma=0.8, delta=0.05, budget=400,
                  method="is")
    res = run_query(jax.random.PRNGKey(7), scores,
                    array_oracle(labels), q)
    assert recall_of(res.selected, truth) >= 0.8
    assert res.oracle_calls <= 400
