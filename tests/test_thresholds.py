"""Statistical-guarantee tests for Algorithms 2-5 — the paper's core claims."""
import jax
import numpy as np
import pytest

from repro.core import queries, thresholds
from repro.core.oracle import array_oracle
from repro.data.synthetic import make_adversarial, make_beta

GAMMA, DELTA = 0.9, 0.05
N, BUDGET, TRIALS = 300_000, 4000, 20


@pytest.fixture(scope="module")
def beta_ds():
    return make_beta(N, 0.01, 1.0, seed=7)


def _run_many(ds, target, method, trials=TRIALS, gamma=GAMMA):
    fails, quality = 0, []
    for t in range(trials):
        q = queries.SUPGQuery(target=target, gamma=gamma, delta=DELTA,
                              budget=BUDGET, method=method)
        res = queries.run_query(jax.random.PRNGKey(1000 + t), ds.scores,
                                array_oracle(ds.labels), q)
        p = queries.precision_of(res.selected, ds.truth_mask())
        r = queries.recall_of(res.selected, ds.truth_mask())
        achieved, qual = (r, p) if target == "recall" else (p, r)
        fails += achieved < gamma
        quality.append(qual)
    return fails / trials, float(np.median(quality))


@pytest.mark.parametrize("target", ["recall", "precision"])
def test_supg_guarantee_holds(beta_ds, target):
    """Pr[target met] >= 1 - delta (binomial slack for 20 trials)."""
    fail_rate, _ = _run_many(beta_ds, target, "is")
    assert fail_rate <= DELTA + 0.11   # 20-trial binomial 95% slack


@pytest.mark.parametrize("target", ["recall", "precision"])
def test_uniform_ci_guarantee_holds(beta_ds, target):
    fail_rate, _ = _run_many(beta_ds, target, "uniform")
    assert fail_rate <= DELTA + 0.16


def test_importance_beats_uniform_quality_pt(beta_ds):
    """Figure 7: IS recall >> uniform recall at a precision target."""
    _, q_is = _run_many(beta_ds, "precision", "is", trials=8)
    _, q_u = _run_many(beta_ds, "precision", "uniform", trials=8)
    assert q_is > 2 * max(q_u, 1e-4)


def test_noci_baseline_fails_often(beta_ds):
    """Figures 1/5/6: the no-CI baseline violates the target frequently."""
    fail_rate, _ = _run_many(beta_ds, "recall", "noci", trials=12)
    assert fail_rate > 0.2


def test_guarantee_survives_adversarial_proxy():
    """Defensive mixing: validity even with an anti-correlated proxy."""
    ds = make_adversarial(100_000, 0.02, seed=3)
    fails = 0
    for t in range(10):
        q = queries.SUPGQuery(target="recall", gamma=0.8, delta=DELTA,
                              budget=5000, method="is")
        res = queries.run_query(jax.random.PRNGKey(t), ds.scores,
                                array_oracle(ds.labels), q)
        fails += queries.recall_of(res.selected, ds.truth_mask()) < 0.8
    assert fails <= 2


# ---------------------------------------------------------------------------
# estimator-level unit tests
# ---------------------------------------------------------------------------

def test_rt_estimator_monotone_in_gamma():
    rng = np.random.default_rng(0)
    a = rng.random(2000).astype(np.float32)
    o = (rng.random(2000) < a).astype(np.float32)
    taus = [float(thresholds.tau_ci_r(a, o, np.ones(2000), g, 0.05).tau)
            for g in (0.5, 0.7, 0.9)]
    assert taus[0] >= taus[1] >= taus[2]   # higher recall -> lower threshold


def test_pt_no_positives_returns_empty():
    a = np.linspace(0, 1, 1000).astype(np.float32)
    o = np.zeros(1000, np.float32)
    res = thresholds.tau_ci_p(a, o, 0.9, 0.05)
    assert np.isinf(float(res.tau))       # empty selection is the only valid


def test_rt_all_positives_includes_all():
    a = np.linspace(0.01, 1, 500).astype(np.float32)
    o = np.ones(500, np.float32)
    res = thresholds.tau_ci_r(a, o, np.ones(500), 0.99, 0.05)
    assert float(res.tau) <= float(a.min())


def test_unoci_matches_empirical_cutoff():
    a = np.asarray([0.9, 0.8, 0.7, 0.6, 0.5], np.float32)
    o = np.asarray([1, 1, 0, 1, 0], np.float32)
    res = thresholds.tau_unoci_r(a, o, 0.66)
    # two of three positives are at 0.8+ -> recall 2/3 at tau=0.8
    assert float(res.tau) == pytest.approx(0.8)


def test_stage1_nmatch_upper_bounds_truth():
    rng = np.random.default_rng(5)
    n = 100_000
    scores = rng.beta(0.05, 1, n).astype(np.float32)
    labels = (rng.random(n) < scores).astype(np.float32)
    miss = 0
    for t in range(20):
        idx = rng.integers(0, n, 3000)
        m = np.ones(3000, np.float32)
        nm, rank = thresholds.pt_stage1_nmatch(labels[idx], m, n, 0.9, 0.05)
        miss += float(nm) < labels.sum()
    assert miss / 20 <= 0.1
