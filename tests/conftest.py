"""Shared test fixtures + a minimal `hypothesis` fallback.

The container does not always ship `hypothesis`. Rather than losing three
property-test modules to collection errors, install a tiny deterministic
stand-in into ``sys.modules`` *before* the test modules import it. The
fallback draws `max_examples` pseudo-random examples per test from a seed
derived from the test name — no shrinking, no database, but the invariants
still get fuzzed on every run.

A real install is detected via `importlib.util.find_spec` — a spec probe,
not an import — so the shim never shadows an installed package (and a
present-but-broken install surfaces its own import error from the test
modules instead of being silently papered over). `HYPOTHESIS_IS_FALLBACK`
records which implementation this run fuzzes with.
"""
from __future__ import annotations

import hashlib
import importlib.util
import sys
import types

HYPOTHESIS_IS_FALLBACK = False


def _install_hypothesis_fallback():
    global HYPOTHESIS_IS_FALLBACK
    if importlib.util.find_spec("hypothesis") is not None:
        return      # real install present: use it untouched
    HYPOTHESIS_IS_FALLBACK = True

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    def floats(min_value, max_value, **_kw):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(size)]
        return _Strategy(draw)

    def settings(max_examples=100, deadline=None, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            n = getattr(fn, "_fallback_max_examples", 100)
            seed = int.from_bytes(
                hashlib.sha256(fn.__name__.encode()).digest()[:8], "big")

            def runner(*args, **kwargs):
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    drawn = [s.draw(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)

            # No functools.wraps: copying fn's signature would make pytest
            # treat the strategy parameters as fixture requests.
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.lists = lists
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


_install_hypothesis_fallback()
