"""Sharding rules: every produced spec must be valid on the mesh (uneven
shardings are rejected by jax), and the TP/EP/FSDP patterns must land on
the expected dims."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_smoke_config
from repro.launch import sharding as shardlib
from repro.launch.mesh import make_test_mesh
from repro.models import model


@pytest.fixture(scope="module")
def mesh11():
    return make_test_mesh((1, 1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_specs_divisible_everywhere(arch, mesh11):
    """On a 1x1 mesh every spec is trivially valid; the _check logic is
    exercised against the production mesh axis sizes via shape math."""
    cfg = get_smoke_config(arch)
    params = jax.eval_shape(lambda k: model.init(k, cfg),
                            jax.random.PRNGKey(0))
    specs = shardlib.param_specs(cfg, params, mesh11)

    def validate(leaf, spec):
        sizes = dict(zip(mesh11.axis_names, mesh11.devices.shape))
        for dim, entry in zip(leaf.shape, spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= sizes[a]
            assert dim % total == 0

    jax.tree.map(validate, params, specs)


def test_tp_patterns_on_big_mesh():
    """Production-mesh spec assignment: embedding vocab-sharded, column/row
    parallel matrices on the expected dims, MoE experts on the E dim."""
    import numpy as np
    from jax.sharding import Mesh
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = Mesh(devs, ("data", "model"))

    cfg = get_smoke_config("deepseek-v2-236b")
    params = jax.eval_shape(lambda k: model.init(k, cfg),
                            jax.random.PRNGKey(0))
    specs = shardlib.param_specs(cfg, params, mesh)
    # embedding (128, 64): vocab 128 % 16 == 0 -> sharded
    assert specs["embed"]["table"] == P("model", None)
    # MoE experts (L, E=8, d, ff): E=8 % 16 != 0 -> dropped to None
    moe_spec = specs["body"]["moe_blocks"]["moe"]["w_gate"]
    assert moe_spec[1] is None
    # column-parallel MLA up-projection exists and targets the last dim
    wuk = specs["body"]["moe_blocks"]["attn"]["w_uk"]
    assert wuk[-1] in ("model", None)


def test_fsdp_adds_data_axis():
    import numpy as np
    from jax.sharding import Mesh
    devs = np.array(jax.devices() * 4)[:4].reshape(2, 2)
    mesh = Mesh(devs, ("data", "model"))
    cfg = get_smoke_config("yi-6b")
    # fabricate a big leaf to trip the FSDP threshold
    params = {"body": {"blocks": {"mlp": {
        "w_gate": jax.ShapeDtypeStruct((4, 4096, 4096), jnp.bfloat16)}}}}
    specs = shardlib.param_specs(cfg, params, mesh, fsdp=True)
    spec = specs["body"]["blocks"]["mlp"]["w_gate"]
    flat = [e for e in spec if e is not None]
    assert "data" in str(flat)            # data axis engaged somewhere


def test_zero1_no_duplicate_axes():
    import numpy as np
    from jax.sharding import Mesh
    devs = np.array(jax.devices() * 4)[:4].reshape(2, 2)
    mesh = Mesh(devs, ("data", "model"))
    cfg = get_smoke_config("yi-6b")
    params = jax.eval_shape(lambda k: model.init(k, cfg),
                            jax.random.PRNGKey(0))
    specs = shardlib.zero1_specs(cfg, params, mesh, fsdp=True)

    def no_dupes(spec):
        axes = []
        for e in spec:
            if e is None:
                continue
            axes.extend(e if isinstance(e, tuple) else (e,))
        assert len(axes) == len(set(axes))

    jax.tree.map(lambda s: no_dupes(s), specs,
                 is_leaf=lambda x: isinstance(x, P))


def test_batch_spec_divisibility():
    import numpy as np
    from jax.sharding import Mesh
    devs = np.array(jax.devices() * 4)[:4].reshape(2, 2)
    mesh = Mesh(devs, ("data", "model"))
    assert shardlib.batch_spec(mesh, 1, batch=4)[0] == "data"
    assert shardlib.batch_spec(mesh, 1, batch=1)[0] is None
