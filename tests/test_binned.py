"""Binned sketch + distributed selection plane tests (1-device mesh)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binned, distributed
from repro.launch.mesh import make_test_mesh


def test_sketch_totals():
    rng = np.random.default_rng(0)
    s = rng.beta(0.2, 1, 10_000).astype(np.float32)
    sk = binned.build_sketch(jnp.asarray(s), 512)
    assert float(sk.total) == 10_000
    assert float(jnp.sum(sk.sum_a)) == pytest.approx(float(s.sum()), rel=1e-4)
    assert float(jnp.sum(sk.sum_w)) == pytest.approx(
        float(np.sqrt(s).sum()), rel=1e-4)


def test_sketch_sentinel_parity_across_backends():
    """The -1 "unscored" sentinel must be masked identically by the kernel
    and jnp fallback paths (the fallback used to clip it into bin 0), so
    partially-scored ScoreStore shards agree across backends."""
    rng = np.random.default_rng(7)
    s = rng.beta(0.3, 1.5, 8_192).astype(np.float32)
    s[rng.integers(0, s.shape[0], 2_000)] = -1.0
    n_valid = int((s >= 0).sum())
    sk_k = binned.build_sketch(jnp.asarray(s), 512, use_kernel=True)
    sk_j = binned.build_sketch(jnp.asarray(s), 512, use_kernel=False)
    for a, b in zip(sk_k, sk_j):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
    assert float(sk_j.total) == n_valid
    # normalizers agree too — the engine's cached sampling state depends
    # on them, never on re-reducing raw shards
    np.testing.assert_allclose(
        np.asarray(binned.weight_normalizers(sk_k)),
        np.asarray(binned.weight_normalizers(sk_j)), rtol=1e-5)


def test_rank_to_threshold_conservative():
    rng = np.random.default_rng(1)
    s = rng.random(50_000).astype(np.float32)
    sk = binned.build_sketch(jnp.asarray(s), 1024)
    for rank in (10, 500, 5000):
        tau = float(binned.rank_to_threshold(sk, rank))
        assert (s >= tau).sum() >= rank    # superset guarantee


def test_selection_size_upper_bound():
    s = np.linspace(0, 1, 10_000).astype(np.float32)
    sk = binned.build_sketch(jnp.asarray(s), 1000)
    assert float(binned.selection_size(sk, 0.5)) >= (s >= 0.5).sum()


def test_merge():
    a = binned.build_sketch(jnp.asarray([0.1, 0.2]), 64)
    b = binned.build_sketch(jnp.asarray([0.9]), 64)
    m = binned.merge_sketches(a, b)
    assert float(m.total) == 3


def test_global_sketch_matches_local():
    mesh = make_test_mesh((1, 1))
    rng = np.random.default_rng(2)
    scores = jnp.asarray(rng.beta(0.1, 1, 4096).astype(np.float32))
    sk_d = distributed.global_sketch(mesh, scores, 256)
    sk_l = binned.build_sketch(scores, 256)
    np.testing.assert_allclose(np.asarray(sk_d.counts),
                               np.asarray(sk_l.counts))


def test_two_level_sampler_mass():
    totals = jnp.asarray([[10.0, 100.0], [30.0, 100.0]])  # (shard, [w, n])
    ids, _ = distributed.two_level_sample(jax.random.PRNGKey(0), totals,
                                          20_000, kappa=0.0)
    frac = float((np.asarray(ids) == 1).mean())
    assert frac == pytest.approx(0.75, abs=0.02)


def test_local_selection_count():
    mesh = make_test_mesh((1, 1))
    scores = jnp.asarray(np.linspace(0, 1, 1000).astype(np.float32))
    cnt = distributed.global_selection_count(mesh, scores, 0.25)
    assert float(cnt) == (np.linspace(0, 1, 1000) >= 0.25).sum()
