"""Per-arch smoke tests (reduced configs) + prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, SHAPES
from repro.configs.base import shape_applicable
from repro.models import model

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    params = model.init(KEY, cfg)
    tok_shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks > 1 else (B, S)
    tokens = jax.random.randint(KEY, tok_shape, 0, cfg.vocab_size)
    logits, aux = model.apply_train(params, cfg, tokens)
    expect = (B, S, cfg.num_codebooks, cfg.vocab_size) \
        if cfg.num_codebooks > 1 else (B, S, cfg.vocab_size)
    assert logits.shape == expect
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss, (ce, _) = model.loss_fn(params, cfg, tokens, tokens)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = model.init(KEY, cfg)
    caches = model.init_caches(cfg, B, S, jnp.float32)
    tok_shape = (B, 1, cfg.num_codebooks) if cfg.num_codebooks > 1 else (B, 1)
    tokens = jax.random.randint(KEY, tok_shape, 0, cfg.vocab_size)
    pos = jnp.zeros((B,), jnp.int32)
    logits, new_caches = model.apply_decode(params, cfg, tokens, caches, pos)
    assert logits.shape[:2] == (B, 1)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # cache structure preserved
    assert jax.tree_util.tree_structure(caches) == \
        jax.tree_util.tree_structure(new_caches)


@pytest.mark.parametrize("arch", ["yi-6b", "deepseek-v2-236b", "rwkv6-7b",
                                  "zamba2-1.2b"])
def test_prefill_decode_consistency(arch):
    """Iterated decode must reproduce the prefill logits step by step —
    the strongest end-to-end correctness check of cache semantics."""
    cfg = get_smoke_config(arch)
    params = model.init(jax.random.PRNGKey(1), cfg)
    t = 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, t), 0,
                                cfg.vocab_size)
    logits_pre, _ = model.apply_train(params, cfg, tokens)

    caches = model.init_caches(cfg, B, t, jnp.float32)
    outs = []
    for i in range(t):
        pos = jnp.full((B,), i, jnp.int32)
        lo, caches = model.apply_decode(params, cfg, tokens[:, i:i + 1],
                                        caches, pos)
        outs.append(lo[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_pre), atol=2e-2, rtol=2e-2)


def test_proxy_scores_in_unit_interval():
    cfg = get_smoke_config("smollm-360m")
    params = model.init(KEY, cfg)
    tokens = jax.random.randint(KEY, (4, S), 0, cfg.vocab_size)
    scores = model.proxy_scores(params, cfg, tokens)
    assert scores.shape == (4,)
    assert float(scores.min()) >= 0.0 and float(scores.max()) <= 1.0


def test_long_500k_applicability_rules():
    long = [s for s in SHAPES if s.name == "long_500k"][0]
    runs = {a: shape_applicable(get_config(a), long)[0] for a in ARCH_IDS}
    assert runs["rwkv6-7b"] and runs["zamba2-1.2b"]
    assert not runs["yi-6b"] and not runs["chameleon-34b"]
    assert sum(runs.values()) == 2


def test_param_counts_match_published():
    expected = {"yi-6b": 6.1e9, "deepseek-7b": 6.9e9, "rwkv6-7b": 7.6e9,
                "chameleon-34b": 34.3e9, "deepseek-v2-236b": 236e9,
                "llama4-maverick-400b-a17b": 398e9, "zamba2-1.2b": 1.2e9,
                "smollm-360m": 0.36e9}
    for arch, n in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.05, f"{arch}: {got:.3g} vs {n:.3g}"
