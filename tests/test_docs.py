"""The docs are executable: every `>>>` snippet in the docs tree and in
the documented public modules must pass as a doctest. CI runs the same
set via `python -m doctest` in the lint job; this mirror keeps the
contract enforced by the tier-1 suite too."""
import doctest
import importlib
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent

DOC_FILES = sorted(str(p.relative_to(ROOT))
                   for p in (ROOT / "docs").glob("*.md")) + ["README.md"]

DOC_MODULES = [
    "repro.core.engine",
    "repro.core.oracle",
    "repro.core.resilience",
    "repro.data.pipeline",
    "repro.durable.atomic",
    "repro.durable.journal",
    "repro.durable.recovery",
    "repro.live.ingest",
    "repro.live.standing",
    "repro.live.sentinel",
    "repro.serve.limiter",
    "repro.serve.stats",
    "repro.serve.server",
    "repro.testing.faults",
    "repro.testing.crash",
]


def test_docs_tree_exists():
    assert "docs/architecture.md" in DOC_FILES
    assert "docs/guarantees.md" in DOC_FILES
    assert (ROOT / "README.md").is_file()


@pytest.mark.parametrize("relpath", DOC_FILES)
def test_markdown_snippets_run(relpath):
    failures, tests = doctest.testfile(str(ROOT / relpath),
                                       module_relative=False, verbose=False)
    assert tests > 0, f"{relpath} has no doctest examples"
    assert failures == 0


@pytest.mark.parametrize("modname", DOC_MODULES)
def test_module_docstring_examples_run(modname):
    mod = importlib.import_module(modname)
    failures, tests = doctest.testmod(mod, verbose=False)
    assert failures == 0
    if modname not in ("repro.serve.server",):   # server doc is prose-only
        assert tests > 0, f"{modname} lost its doctest examples"
