"""Durability plane: journal framing, atomic commits, crashpoint
acceptance (kill -> restore -> resume == the uncrashed run, bit for bit),
and epoch GC."""
import os
import time

import numpy as np
import pytest

import jax

from repro.core.engine import SelectionEngine
from repro.core.queries import JointSUPGQuery, SUPGQuery
from repro.data.pipeline import BitmaskStore, ScoreStore
from repro.durable import atomic
from repro.durable.journal import EpochJournal, scan
from repro.durable.recovery import DurabilityPlane
from repro.live.ingest import IngestPlane
from repro.serve.server import SelectionServer
from repro.testing import CrashInjector, SimulatedCrash, crash_schedule

BASE_N, DELTA_N = 2048, 1024
ENGINE_KW = dict(num_bins=64, use_kernel=False)

QUERIES = [
    SUPGQuery(target="recall", gamma=0.9, budget=192, method="is"),
    SUPGQuery(target="precision", gamma=0.9, budget=192, method="is"),
    JointSUPGQuery(gamma_recall=0.85, stage_budget=192),
]

# Crashpoints on the ingest/append/standing-catch-up path (the snapshot
# path's `pre_snapshot_publish` is exercised separately).
APPEND_PATH_POINTS = [
    "pre_fsync", "pre_rename", "journal_pre_append", "journal_pre_fsync",
    "post_journal_pre_install", "mid_bitmask_commit",
]


def _base_shards():
    return [np.linspace(0.0, 1.0, BASE_N, dtype=np.float32)]


def _deltas():
    rng = np.random.default_rng(11)
    return [rng.beta(0.05, 1.0, DELTA_N).astype(np.float32)
            for _ in range(3)]


def _oracle(idx):
    return (np.asarray(idx) % 7 == 0).astype(np.float32)


# ---------------------------------------------------------------------------
# journal framing
# ---------------------------------------------------------------------------

def test_journal_truncation_property(tmp_path):
    """Truncating the file at *every* byte offset: replay never raises
    and never invents a record — it returns a strict prefix."""
    path = str(tmp_path / "j.log")
    records = [{"type": "append", "epoch": e, "shards": []}
               for e in (1, 2, 3)]
    with EpochJournal(path) as j:
        for r in records:
            j.append(r)
    data = open(path, "rb").read()
    cut = str(tmp_path / "cut.log")
    prefix_lens = []
    for n in range(len(data) + 1):
        with open(cut, "wb") as f:
            f.write(data[:n])
        got, valid = scan(cut)
        assert valid <= n
        assert got == records[:len(got)]        # prefix, never invented
        prefix_lens.append(len(got))
    assert prefix_lens[0] == 0 and prefix_lens[-1] == 3
    assert prefix_lens == sorted(prefix_lens)   # monotone in bytes kept


def test_journal_corrupt_frame_stops_scan(tmp_path):
    path = str(tmp_path / "j.log")
    with EpochJournal(path) as j:
        j.append({"epoch": 1})
        j.append({"epoch": 2})
    data = bytearray(open(path, "rb").read())
    first_len = scan(path)[1] // 2  # two equal frames
    data[first_len + 14] ^= 0xFF    # corrupt the second frame's payload
    open(path, "wb").write(bytes(data))
    got, valid = scan(path)
    assert [r["epoch"] for r in got] == [1]
    assert valid == first_len


def test_journal_reopen_truncates_torn_tail_and_appends(tmp_path):
    path = str(tmp_path / "j.log")
    with EpochJournal(path) as j:
        j.append({"epoch": 1})
    with open(path, "ab") as f:
        f.write(b"EPJ1\x07\x00")    # half a header
    with EpochJournal(path) as j:
        assert [r["epoch"] for r in j.records] == [1]
        j.append({"epoch": 2})
    assert [r["epoch"] for r in EpochJournal(path).replay()] == [1, 2]


# ---------------------------------------------------------------------------
# crash injector + atomic replace
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("point", ["pre_fsync", "pre_rename"])
def test_atomic_replace_crash_leaves_old_file(tmp_path, point):
    path = str(tmp_path / "s.json")
    atomic.atomic_write_json(path, {"v": 1})
    with CrashInjector({point: 0}):
        with pytest.raises(SimulatedCrash):
            atomic.atomic_write_json(path, {"v": 2})
    assert atomic.read_json(path) == {"v": 1}
    atomic.atomic_write_json(path, {"v": 3})    # hook uninstalled
    assert atomic.read_json(path) == {"v": 3}


def test_crash_injector_latches(tmp_path):
    """After firing once, every later crashpoint raises too — a dead
    process cannot keep committing."""
    inj = CrashInjector({"pre_rename": 0})
    with inj:
        with pytest.raises(SimulatedCrash):
            atomic.atomic_write_json(str(tmp_path / "a.json"), {})
        with pytest.raises(SimulatedCrash):
            atomic.crashpoint("journal_pre_append")    # unscheduled point
    assert inj.fired and inj.fired_at == "pre_rename"


def test_crash_injector_rejects_unknown_points():
    with pytest.raises(ValueError, match="unknown crashpoint"):
        CrashInjector({"not_a_point": 0})


def test_crash_schedule_deterministic():
    assert crash_schedule(42) == crash_schedule(42)
    (point, hit), = crash_schedule(42).items()
    assert point in atomic.CRASHPOINTS and 0 <= hit < 3


# ---------------------------------------------------------------------------
# two-phase store commits
# ---------------------------------------------------------------------------

def test_score_store_append_two_phase(tmp_path):
    path = str(tmp_path / "s.scores")
    store = ScoreStore(path, 8, create=True)
    store.write(0, np.arange(8, dtype=np.float32))
    with CrashInjector({"pre_length_commit": 0}):
        with pytest.raises(SimulatedCrash):
            store.append(np.full(4, 9.0, np.float32))
    # The crashed grow was never acknowledged: reopening recovers to the
    # committed length, and re-issuing the append is exactly-once.
    again = ScoreStore(path, 1 << 20)     # over-ask: clamped to committed
    assert len(again) == 8
    assert again.append(np.full(4, 9.0, np.float32)) == 12
    assert np.array_equal(again.read(8), np.full(4, 9.0, np.float32))
    reopened = ScoreStore(path, 1 << 20)
    assert len(reopened) == 12


def test_bitmask_grow_preserves_committed_bits(tmp_path):
    path = str(tmp_path / "sel.bits")
    store = BitmaskStore(path)
    store.open([100, 37])
    store.emit(0, np.asarray([1, 3, 99]))
    store.emit(1, np.asarray([0, 36]))
    store.close()
    before0, before1 = store.mask(0).copy(), store.mask(1).copy()

    # A crash mid-grow commits nothing: the old layout stays current.
    grower = BitmaskStore(path)
    with CrashInjector({"mid_bitmask_commit": 0}):
        with pytest.raises(SimulatedCrash):
            grower.open([100, 37, 64])
    meta = atomic.read_json(path + ".meta.json")
    assert meta["shard_sizes"] == [100, 37]

    # Re-growing after the crash preserves every committed bit.
    grown = BitmaskStore(path)
    grown.open([100, 37, 64])
    grown.emit(2, np.asarray([5]))
    grown.close()
    assert np.array_equal(grown.mask(0), before0)
    assert np.array_equal(grown.mask(1), before1)
    assert grown.indices(2).tolist() == [5]


def test_bitmask_incompatible_layout_starts_fresh(tmp_path):
    path = str(tmp_path / "sel.bits")
    store = BitmaskStore(path)
    store.open([16])
    store.emit(0, np.asarray([0, 1]))
    store.close()
    fresh = BitmaskStore(path)
    fresh.open([32])          # shard 0 resized: not an extension
    fresh.close()
    assert fresh.indices(0).size == 0


# ---------------------------------------------------------------------------
# epoch GC
# ---------------------------------------------------------------------------

def test_epoch_gc_respects_pins():
    with SelectionEngine(_base_shards(), **ENGINE_KW) as eng:
        plane = IngestPlane(eng)
        pinned = eng.pin()                      # pin epoch 0
        for d in _deltas():
            plane.append(d)
        assert eng.epochs_live == 4             # current + 3 superseded
        assert eng.gc_epochs() == 2             # epoch 0 is pinned
        assert eng.epochs_live == 2
        assert pinned.shards                    # untouched while pinned
        eng.unpin(pinned)
        assert eng.gc_epochs() == 1
        assert eng.epochs_freed == 3
        assert eng.epochs_live == 1
        with pytest.raises(ValueError, match="no live pins"):
            eng.unpin(pinned)


def test_plans_unpin_their_epoch():
    with SelectionEngine(_base_shards(), **ENGINE_KW) as eng:
        eng.run(jax.random.PRNGKey(0), _oracle, QUERIES[0])
        IngestPlane(eng).append(_deltas()[0])
        assert eng.gc_epochs() == 1             # nothing left pinned


# ---------------------------------------------------------------------------
# replay: idempotence + engine-level bit-for-bit recovery
# ---------------------------------------------------------------------------

def test_replay_is_idempotent(tmp_path):
    dur = DurabilityPlane(str(tmp_path / "dur"))
    with SelectionEngine(_base_shards(), **ENGINE_KW) as eng:
        plane = IngestPlane(eng)
        for d in _deltas():
            arrs = dur.record_append(d, epoch=plane.epoch + 1)
            plane.append(arrs)
        assert dur.replay_into(plane) == 0      # already applied: no-op
    with SelectionEngine(_base_shards(), **ENGINE_KW) as eng2:
        plane2 = IngestPlane(eng2)
        assert dur.replay_into(plane2) == 3
        assert dur.replay_into(plane2) == 0     # replaying again: no-op
        assert eng2.n_total == BASE_N + 3 * DELTA_N


@pytest.mark.parametrize("workers", [1, 4, 8])
def test_run_many_crash_restore_bit_for_bit(tmp_path, workers):
    """Kill mid-append, rebuild from the journal, run RT/PT/JT through
    `run_many`: results equal the never-crashed engine's bit for bit."""
    kw = dict(ENGINE_KW, workers=workers)
    deltas = _deltas()
    key = jax.random.PRNGKey(5)

    with SelectionEngine(_base_shards(), **kw) as ref_eng:
        ref_plane = IngestPlane(ref_eng)
        for d in deltas:
            ref_plane.append(d)
        ref = ref_eng.run_many(key, _oracle, QUERIES)

    dur = DurabilityPlane(str(tmp_path / "dur"))
    with SelectionEngine(_base_shards(), **kw) as eng:
        plane = IngestPlane(eng)
        with CrashInjector({"post_journal_pre_install": 2}):
            with pytest.raises(SimulatedCrash):
                for d in deltas:
                    plane.append(dur.record_append(d, epoch=plane.epoch + 1))

    with SelectionEngine(_base_shards(), **kw) as rec_eng:
        rec_plane = IngestPlane(rec_eng)
        # The journaled-but-uninstalled epoch replays too: the append was
        # acknowledged to the journal, so recovery lands on the timeline
        # the caller was about to see.
        assert dur.replay_into(rec_plane) == 3
        got = rec_eng.run_many(key, _oracle, QUERIES)

    for r, g in zip(ref, got):
        assert g.tau == r.tau
        assert g.oracle_calls == r.oracle_calls
        assert np.array_equal(g.shard_counts, r.shard_counts)
        for sh in range(len(r.shard_sizes)):
            assert np.array_equal(g.indices(sh), r.indices(sh))


# ---------------------------------------------------------------------------
# server crashpoint acceptance
# ---------------------------------------------------------------------------

def _make_server(root, workers, sink_dir, tag):
    eng = SelectionEngine(_base_shards(), workers=workers, **ENGINE_KW)
    srv = SelectionServer(eng, _oracle, durable=root,
                          quotas={"t": 1_000_000})
    sqs = [srv.subscribe(q, tenant="t", key=jax.random.PRNGKey(j),
                         sink=BitmaskStore(
                             os.path.join(sink_dir, f"{tag}_{j}.bits")))
           for j, q in enumerate(QUERIES)]
    for sq in sqs:
        sq.wait_certified(timeout=120)
    srv.snapshot()
    return srv, sqs


def _wait_quiescent(srv, sqs, epoch, inj=None, timeout=120):
    deadline = time.monotonic() + timeout
    while True:
        if inj is not None and inj.fired:
            return False
        if all(sq.epoch >= epoch and not sq._busy for sq in sqs) \
                and not srv._registry.has_pending():
            return True
        if srv._fatal is not None:
            raise AssertionError(f"scheduler died: {srv._fatal!r}")
        assert time.monotonic() < deadline, "standing catch-up stalled"
        time.sleep(0.01)


def _collect(srv, sqs):
    n_shards = len(srv.engine.shards)
    taus = [sq.tau for sq in sqs]
    masks = [[sq.sink.mask(sh).copy() for sh in range(n_shards)]
             for sq in sqs]
    charged = srv.stats().tenants["t"].oracle_charged
    return taus, masks, charged


@pytest.fixture(scope="module")
def uncrashed_reference(tmp_path_factory):
    """tau / sink-bits / ledger of the never-crashed run, per worker count
    (computed lazily, cached for every crashpoint case)."""
    cache = {}

    def get(workers):
        if workers not in cache:
            d = str(tmp_path_factory.mktemp(f"ref_w{workers}"))
            srv, sqs = _make_server(os.path.join(d, "dur"), workers, d,
                                    "ref")
            for i, delta in enumerate(_deltas()):
                srv.append(delta)
                _wait_quiescent(srv, sqs, i + 1)
            cache[workers] = _collect(srv, sqs)
            srv.close()
        return cache[workers]

    return get


def _crash_restore_resume(tmp_path, workers, point, hit, reference):
    ref_taus, ref_masks, ref_charged = reference
    root = str(tmp_path / "dur")
    deltas = _deltas()
    srv, sqs = _make_server(root, workers, str(tmp_path), "crash")
    died = False
    inj = CrashInjector({point: hit})
    with inj:
        for i, delta in enumerate(deltas):
            try:
                srv.append(delta)
            except SimulatedCrash:
                died = True
                break
            if not _wait_quiescent(srv, sqs, i + 1, inj=inj):
                died = True
                break
    assert died or inj.fired, f"{point}[{hit}] never fired"
    srv.close(abandon=True)

    srv = SelectionServer.restore(
        root, _oracle, base_shards=_base_shards(),
        engine_kw=dict(ENGINE_KW, workers=workers),
        quotas={"t": 1_000_000})
    try:
        sqs = srv._registry.standing
        assert len(sqs) == len(QUERIES)
        assert srv.recovered_queries == len(QUERIES)
        # Resume protocol: the epoch number is the idempotency key — the
        # client re-issues exactly the appends the restored corpus shows
        # missing.
        for i in range(srv.plane.epoch, len(deltas)):
            srv.append(deltas[i])
        _wait_quiescent(srv, sqs, len(deltas))
        taus, masks, charged = _collect(srv, sqs)
    finally:
        srv.close()
    assert taus == ref_taus
    for got, ref in zip(masks, ref_masks):
        for sh, (g, r) in enumerate(zip(got, ref)):
            assert np.array_equal(g, r), f"shard {sh} bits diverged"
    # Zero oracle budget double-spent: certification + probes were never
    # re-run, and re-emission walks label nothing.
    assert charged == ref_charged


@pytest.mark.parametrize("point", APPEND_PATH_POINTS)
def test_server_crashpoint_acceptance(tmp_path, point, uncrashed_reference):
    _crash_restore_resume(tmp_path, 1, point, 1 if "journal" in point
                          else 0, uncrashed_reference(1))


@pytest.mark.slow
@pytest.mark.parametrize("workers", [4, 8])
@pytest.mark.parametrize("point", APPEND_PATH_POINTS)
def test_server_crashpoint_matrix(tmp_path, point, workers,
                                  uncrashed_reference):
    _crash_restore_resume(tmp_path, workers, point, 1 if "journal" in point
                          else 0, uncrashed_reference(workers))


def test_snapshot_crash_keeps_previous_snapshot(tmp_path):
    root = str(tmp_path / "dur")
    srv, sqs = _make_server(root, 1, str(tmp_path), "snap")
    before = srv.durable.read_snapshot()
    srv.append(_deltas()[0])
    _wait_quiescent(srv, sqs, 1)
    with CrashInjector({"pre_snapshot_publish": 0}):
        with pytest.raises(SimulatedCrash):
            srv.snapshot()
    assert srv.durable.read_snapshot() == before
    srv.close(abandon=True)


def test_restore_spends_nothing_with_audited_watch(tmp_path):
    """Restore re-adopts an audited watch from its snapshot: the tenant
    ledger sits exactly at its snapshot balance (certification and the
    reference probe are NOT re-run), and post-restore epochs are audited
    with the same per-epoch keys the uncrashed scheduler would use."""
    root = str(tmp_path / "dur")
    eng = SelectionEngine(_base_shards(), **ENGINE_KW)
    srv = SelectionServer(eng, _oracle, durable=root,
                          quotas={"t": 1_000_000}, sentinel_probe_budget=64)
    sq = srv.subscribe(QUERIES[0], tenant="t", key=jax.random.PRNGKey(9),
                       sink=BitmaskStore(str(tmp_path / "a.bits")),
                       audit=True)
    sq.wait_certified(timeout=120)
    deadline = time.monotonic() + 120
    while not srv._watches:        # the scheduler attaches the watch
        assert time.monotonic() < deadline
        time.sleep(0.01)
    srv.append(_deltas()[0])
    while srv._watches[0][3] < 1 or srv._registry.has_pending():
        assert time.monotonic() < deadline
        time.sleep(0.01)
    snap = srv.snapshot()
    snap_charged = snap["tenants"]["t"]["charged"]
    assert snap_charged > 0
    assert snap["watches"] and snap["watches"][0]["last_audited"] == 1
    srv.close(abandon=True)

    srv = SelectionServer.restore(
        root, _oracle, base_shards=_base_shards(), engine_kw=ENGINE_KW,
        quotas={"t": 1_000_000}, sentinel_probe_budget=64)
    try:
        assert srv.stats().tenants["t"].oracle_charged == snap_charged
        assert srv._watches and srv._watches[0][3] == 1
        [sq2] = srv._registry.standing
        assert sq2.tau == sq.tau and sq2.certified
        srv.append(_deltas()[1])
        deadline = time.monotonic() + 120
        while srv._watches[0][3] < 2 or srv._registry.has_pending():
            assert time.monotonic() < deadline
            time.sleep(0.01)
        st = srv.stats()
        assert st.sentinel_checks == 1          # epoch 2 only: 1 was done
        assert st.records_labeled >= 64         # the probe hit the oracle
        # Tenant balance still equals the snapshot's: probes ride their
        # own throwaway ledger, and nothing certified was re-charged.
        assert st.tenants["t"].oracle_charged == snap_charged
    finally:
        srv.close()


def test_fresh_server_refuses_crashed_journal(tmp_path):
    root = str(tmp_path / "dur")
    dur = DurabilityPlane(root)
    dur.record_append(_deltas()[0], epoch=1)
    dur.close()
    with SelectionEngine(_base_shards(), **ENGINE_KW) as eng:
        with pytest.raises(ValueError, match="restore"):
            SelectionServer(eng, _oracle, durable=root, own_engine=False)


def test_server_stats_report_durability(tmp_path):
    root = str(tmp_path / "dur")
    srv, sqs = _make_server(root, 1, str(tmp_path), "stats")
    srv.append(_deltas()[0])
    _wait_quiescent(srv, sqs, 1)
    srv.snapshot()
    st = srv.stats()
    assert st.durable and st.journal_records == 1 and st.journal_bytes > 0
    assert st.snapshots == 2
    assert st.epochs_freed >= 1 and st.epochs_live >= 1
    assert "durable: on" in st.format()
    srv.close()
