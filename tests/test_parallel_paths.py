"""Production-mesh parallel paths vs reference paths.

These run in a subprocess because they need a multi-device host platform
(XLA_FLAGS is locked at jax import; the main pytest process must stay
single-device for the smoke tests).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.models import attention, meshctx, moe
    from repro.configs import get_smoke_config
    from repro.launch.mesh import _make_mesh

    mesh = _make_mesh((2, 4), ("data", "model"))

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, h, kv, dh = 2, 1024, 6, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    with meshctx.mesh_context(mesh):
        o_cp = jax.jit(lambda q, k, v: attention.context_parallel_attention(
            q, k, v, m_size=4, kv_chunk=256))(q, k, v)
    o_ref = attention.chunked_causal_attention(q, k, v, q_chunk=256,
                                               kv_chunk=256)
    err = float(jnp.max(jnp.abs(o_cp - o_ref)))
    assert err < 1e-4, f"CP attention mismatch {err}"

    cfg = dataclasses.replace(get_smoke_config("deepseek-v2-236b"),
                              num_experts=8, shard_activations=True)
    p = moe.init_moe(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, cfg.d_model)) * 0.1
    with meshctx.mesh_context(mesh):
        out_sm, aux_sm = jax.jit(lambda p, x: moe.moe_apply(p, cfg, x))(p, x)
    cfg_d = dataclasses.replace(cfg, shard_activations=False)
    out_d, aux_d = moe.moe_apply(p, cfg_d, x)
    # capacity drop patterns are layout-dependent (per-shard vs global
    # capacity); outputs agree up to a few dropped-token contributions.
    err = float(jnp.max(jnp.abs(out_sm.astype(jnp.float32)
                                - out_d.astype(jnp.float32))))
    assert err < 0.05, f"MoE shard_map mismatch {err}"
    assert abs(float(aux_sm) - float(aux_d)) < 1e-3
    print("PARALLEL_PATHS_OK")
""")


@pytest.mark.slow
def test_parallel_paths_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "PARALLEL_PATHS_OK" in out.stdout, out.stderr[-2000:]
