"""SelectionEngine data-plane tests: cached-state sampling, vectorized
gathers, regression fixes, run_many batching, streamed-vs-materialized
equivalence, partially-scored stores, and equivalence against the
single-host exact path."""
import numpy as np
import pytest

import jax

from repro.core import queries
from repro.core.engine import SelectionEngine, ShardedSelection
from repro.core.oracle import array_oracle
from repro.core.queries import JointSUPGQuery, SUPGQuery
from repro.data.pipeline import (BitmaskStore, CallbackSink, IndexSink,
                                 ScoreStore, SelectionStream)
from repro.data.synthetic import make_beta


# -- regression: total_selected ---------------------------------------------

def test_total_selected_is_mask_sum():
    """Regression: the seed carried a dead expression that always added 0;
    total_selected must equal the plain sum over shard masks."""
    masks = [np.array([True, False, True]), np.array([False, True])]
    sel = ShardedSelection(masks=masks, tau=0.5, oracle_calls=7,
                           sampled_positive_global=np.array([0, 4]))
    assert sel.total_selected == 3


# -- regression: empty shards in _uniform_in_region -------------------------

def test_uniform_in_region_excludes_empty_shards():
    """Shards whose region {A >= tau} is empty must receive zero draws —
    the seed floored their mass at 1e-30 and then clamp-returned records
    *below* tau."""
    lo = np.zeros(1000, np.float32)             # region empty at tau=0.5
    hi = np.full(500, 0.9, np.float32)
    engine = SelectionEngine([lo, hi], num_bins=512)
    idx = engine._uniform_in_region(jax.random.PRNGKey(0), 300, 0.5)
    assert np.all(idx >= 1000)                  # never from the empty shard
    assert np.all(engine.score_at(idx) >= 0.5)


def test_uniform_in_region_chunked_rank_routing():
    """The chunk-streamed region draw (O(chunk) memory) must stay uniform
    over {A >= tau} when regions span many chunks, and never select the
    unscored sentinel."""
    rng = np.random.default_rng(5)
    scores = rng.random(10_000).astype(np.float32)
    scores[rng.integers(0, 10_000, 500)] = -1.0
    engine = SelectionEngine(np.array_split(scores, 3), num_bins=512,
                             chunk_records=256)    # many chunks per shard
    idx = engine._uniform_in_region(jax.random.PRNGKey(4), 5000, 0.6)
    got = engine.score_at(idx)
    assert np.all(got >= 0.6)                      # region + sentinel safe
    # roughly uniform across the region: compare shard allocation to the
    # true per-shard region sizes
    region_per_shard = np.asarray(
        [((s >= 0.6) & (s >= 0)).sum() for s in engine.shards], np.float64)
    shd = np.searchsorted(engine.offsets, idx, side="right") - 1
    frac = np.bincount(shd, minlength=3) / 5000
    np.testing.assert_allclose(
        frac, region_per_shard / region_per_shard.sum(), atol=0.05)


def test_uniform_in_region_count_and_resolve_agree_on_float64():
    """Regression: the counting pass used to compare in float32 while the
    rank-routed resolve pass compared in the shard's native dtype; a
    float64 score inside the float32 rounding gap around tau then made the
    counted region larger than the resolved one (IndexError on the rank).
    Both passes now run the identical threshold_select backend."""
    scores = np.array([0.5000000001, 0.7] * 500, np.float64)
    engine = SelectionEngine([scores], num_bins=512, chunk_records=128)
    tau = 0.5000000002                      # rounds below 0.5000000001 in f32
    idx = engine._uniform_in_region(jax.random.PRNGKey(0), 2000, tau)
    assert np.all(scores[idx] >= tau)


def test_uniform_in_region_globally_empty_falls_back_to_uniform():
    engine = SelectionEngine([np.zeros(100, np.float32),
                              np.zeros(50, np.float32)], num_bins=512)
    idx = engine._uniform_in_region(jax.random.PRNGKey(1), 64, 0.5)
    assert idx.shape == (64,)
    assert np.all((idx >= 0) & (idx < 150))


# -- vectorized gathers ------------------------------------------------------

def test_score_at_matches_elementwise_gather():
    rng = np.random.default_rng(0)
    shards = [rng.random(n).astype(np.float32) for n in (1000, 1, 2500, 700)]
    flat = np.concatenate(shards)
    gi = rng.integers(0, flat.shape[0], 5000)
    # both gather paths: flat concatenation cache and routed per-shard
    fast = SelectionEngine(shards, num_bins=512)
    routed = SelectionEngine(shards, num_bins=512, cache_flat=False)
    assert fast._flat is not None and routed._flat is None
    np.testing.assert_array_equal(fast.score_at(gi), flat[gi])
    np.testing.assert_array_equal(routed.score_at(gi), flat[gi])


def test_fold_positives_sink_level():
    """Labeled positives below tau are folded as a sink-level merge, routed
    to their shards; positives at/above tau stream out of their own chunks
    (fold/emit disjointness keeps per-shard counts exact)."""
    shards = [np.zeros(100, np.float32), np.zeros(50, np.float32)]
    shards[1][49] = 0.9                       # above tau: emitted, not folded
    engine = SelectionEngine(shards, num_bins=512)
    pos = np.asarray([0, 99, 100, 149], np.int64)
    sel = engine._emit_selection(0.5, pos, oracle_calls=0, sink=None,
                                 chunk_records=64)
    masks = sel.masks
    assert masks[0][0] and masks[0][99] and masks[1][0] and masks[1][49]
    assert masks[0].sum() == 2 and masks[1].sum() == 2
    np.testing.assert_array_equal(sel.shard_counts, [2, 2])
    assert sel.total_selected == 4


# -- cached sampling state ---------------------------------------------------

def test_draw_sample_reweighting_unbiased_from_cache():
    """m(x) factors from the sketch-derived cached CDFs stay unbiased."""
    ds = make_beta(80_000, 0.05, 1.0, seed=6)
    engine = SelectionEngine(np.array_split(ds.scores, 3), num_bins=1024)
    idx, m = engine.draw_sample(jax.random.PRNGKey(1), 20_000, "sqrt")
    est = float(np.mean(ds.labels[idx] * m))
    assert est == pytest.approx(float(ds.labels.mean()), rel=0.2)
    # second draw hits the cache — same state object, no rebuild
    assert len(engine._sampling_cache) == 1
    engine.draw_sample(jax.random.PRNGKey(2), 100, "sqrt")
    assert len(engine._sampling_cache) == 1


def test_scorestore_shards_work_end_to_end(tmp_path):
    ds = make_beta(40_000, 0.02, 1.0, seed=8)
    halves = np.array_split(ds.scores, 2)
    stores = []
    for i, half in enumerate(halves):
        st = ScoreStore(tmp_path / f"shard{i}.scores", half.shape[0],
                        create=True)
        st.write(0, half)
        stores.append(st)
    engine = SelectionEngine(stores, num_bins=1024)
    assert engine.n_total == 40_000
    # out-of-core shards must NOT be concatenated into a RAM flat cache
    assert engine._flat is None
    q = SUPGQuery(target="recall", gamma=0.9, delta=0.05, budget=3000,
                  method="is")
    sel = engine.run(jax.random.PRNGKey(3), array_oracle(ds.labels), q)
    mask = np.concatenate(sel.masks)
    assert queries.recall_of(np.nonzero(mask)[0], ds.truth_mask()) >= 0.85
    assert sel.oracle_calls <= 3000


# -- run_many ----------------------------------------------------------------

def test_run_many_batches_rt_pt_jt():
    ds = make_beta(100_000, 0.01, 1.0, seed=12)
    engine = SelectionEngine(np.array_split(ds.scores, 4), num_bins=1024)
    oracle = array_oracle(ds.labels)
    batch = [
        SUPGQuery(target="recall", gamma=0.9, delta=0.05, budget=3000,
                  method="is"),
        SUPGQuery(target="precision", gamma=0.9, delta=0.05, budget=3000,
                  method="is"),
        JointSUPGQuery(gamma_recall=0.8, stage_budget=3000),
    ]
    results = engine.run_many(jax.random.PRNGKey(5), oracle, batch)
    assert len(results) == 3
    truth = ds.truth_mask()
    rt_mask = np.concatenate(results[0].masks)
    assert queries.recall_of(np.nonzero(rt_mask)[0], truth) >= 0.85
    pt_mask = np.concatenate(results[1].masks)
    assert queries.precision_of(np.nonzero(pt_mask)[0], truth) >= 0.8
    # JT: exhaustive filtering => precision exactly 1.0, recall from RT stage
    jt_mask = np.concatenate(results[2].masks)
    assert queries.precision_of(np.nonzero(jt_mask)[0], truth) == \
        pytest.approx(1.0)
    assert queries.recall_of(np.nonzero(jt_mask)[0], truth) >= 0.75
    # run_many batches ride one shared labeling channel: records labeled
    # for the RT/PT queries answer the JT verification stage from the
    # cache for free, so the JT query's *attributed* oracle_calls can land
    # well below its stage budget (the exhaustive verification itself is
    # evident in the exact precision above). A solo run_joint on a plain
    # callable gets a private channel and still exceeds the stage budget.
    assert 0 < results[2].oracle_calls
    solo = engine.run_joint(jax.random.PRNGKey(5), oracle, batch[2])
    assert solo.oracle_calls > 3000          # stage-3 usage is unbounded
    # budgets stay per-query for plain queries
    for r in results[:2]:
        assert r.oracle_calls <= 3000


def test_run_many_matches_independent_runs():
    """run_many is a batching device, not a semantics change: with matched
    per-query keys it returns exactly what independent run() calls do."""
    ds = make_beta(50_000, 0.02, 1.0, seed=14)
    engine = SelectionEngine(np.array_split(ds.scores, 3), num_bins=1024)
    oracle = array_oracle(ds.labels)
    qs = [SUPGQuery(target="recall", gamma=0.85, budget=2000, method="is"),
          SUPGQuery(target="precision", gamma=0.8, budget=2000,
                    method="noci")]
    key = jax.random.PRNGKey(21)
    batched = engine.run_many(key, oracle, qs)
    keys = jax.random.split(key, 2)
    for k, q, b in zip(keys, qs, batched):
        solo = engine.run(k, oracle, q)
        assert solo.tau == b.tau
        np.testing.assert_array_equal(np.concatenate(solo.masks),
                                      np.concatenate(b.masks))


# -- streamed emission: sink equivalence -------------------------------------

def _materialized_baseline(engine, sel):
    """The PR-1 behavior, computed directly: full boolean masks
    {A >= tau} (never the unscored sentinel) with labeled positives folded
    in. The streamed plane must reproduce this bit-for-bit."""
    masks = []
    for s in engine.shards:
        s = np.asarray(s, np.float32)
        masks.append((s >= sel.tau) & (s >= 0.0))
    pos = sel.sampled_positive_global
    if pos.size:
        shd = np.searchsorted(engine.offsets, pos, side="right") - 1
        for i in range(len(masks)):
            masks[i][pos[shd == i] - engine.offsets[i]] = True
    return masks


@pytest.mark.parametrize("qspec", ["rt", "pt", "jt"])
def test_streamed_selection_matches_materialized(tmp_path, qspec):
    """Streamed emission through every sink type returns exactly the PR-1
    materialized masks on RT, PT, and JT queries (same key => same tau and
    sample => identical selections, bit-for-bit)."""
    ds = make_beta(60_000, 0.02, 1.0, seed=40)
    truth_split = np.array_split(ds.labels > 0.5, 3)
    oracle = array_oracle(ds.labels)
    engine = SelectionEngine(np.array_split(ds.scores, 3), num_bins=1024,
                             chunk_records=7_000)   # force multiple chunks
    q = {"rt": SUPGQuery(target="recall", gamma=0.9, budget=2000),
         "pt": SUPGQuery(target="precision", gamma=0.8, budget=2000),
         "jt": JointSUPGQuery(gamma_recall=0.85, stage_budget=2000)}[qspec]
    key = jax.random.PRNGKey(7)

    def run(sink=None):
        if qspec == "jt":
            return engine.run_joint(key, oracle, q, sink=sink)
        return engine.run(key, oracle, q, sink=sink)

    base = run()                      # default IndexSink
    assert isinstance(base.sink, IndexSink)
    expected = _materialized_baseline(engine, base)
    if qspec == "jt":                 # verified positives only
        expected = [m & t for m, t in zip(expected, truth_split)]
    np.testing.assert_array_equal(np.concatenate(base.masks),
                                  np.concatenate(expected))
    np.testing.assert_array_equal(
        base.shard_counts, [m.sum() for m in expected])

    # memmap-packed bitmask sink
    bits = BitmaskStore(tmp_path / f"{qspec}.bits")
    sel_b = run(sink=bits)
    assert sel_b.tau == base.tau
    np.testing.assert_array_equal(np.concatenate(sel_b.masks),
                                  np.concatenate(expected))

    # callback sink: rebuild masks from the streamed chunks
    got = [[] for _ in engine.shards]
    sel_c = run(sink=CallbackSink(
        lambda sh, gids, folded: got[sh].append(gids)))
    rebuilt = []
    for sh, chunks in enumerate(got):
        m = np.zeros(engine.shards[sh].shape[0], bool)
        if chunks:
            m[np.concatenate(chunks) - engine.offsets[sh]] = True
        rebuilt.append(m)
    np.testing.assert_array_equal(np.concatenate(rebuilt),
                                  np.concatenate(expected))
    assert sel_c.total_selected == int(np.concatenate(expected).sum())


def test_selection_stream_consumes_query_incrementally():
    ds = make_beta(20_000, 0.02, 1.0, seed=41)
    engine = SelectionEngine(np.array_split(ds.scores, 2), num_bins=512,
                             chunk_records=2_000)
    q = SUPGQuery(target="recall", gamma=0.9, budget=1000)
    stream = SelectionStream(
        lambda sink: engine.run(jax.random.PRNGKey(2),
                                array_oracle(ds.labels), q, sink=sink))
    seen = 0
    for shard_id, gids, folded in stream:
        assert np.all((gids >= engine.offsets[shard_id])
                      & (gids < engine.offsets[shard_id + 1]))
        seen += gids.size
    assert stream.result.total_selected == seen > 0


# -- partially-scored stores -------------------------------------------------

def test_partially_scored_store_sketch_parity_and_selection(tmp_path):
    """A store with unscored (-1) records must sketch identically on the
    kernel and jnp paths (sentinel masked, not clipped into bin 0) and the
    streamed selection must never emit unscored records."""
    rng = np.random.default_rng(9)
    n, scored = 40_000, 30_000
    scores = rng.beta(0.5, 2.0, scored).astype(np.float32)
    store = ScoreStore(tmp_path / "partial.scores", n, create=True)
    store.write(0, scores)
    assert store.num_scored == scored

    ek = SelectionEngine([store], num_bins=512, use_kernel=True)
    ej = SelectionEngine([store], num_bins=512, use_kernel=False)
    for a, b in zip(ek.sketch, ej.sketch):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
    assert float(ej.sketch.total) == scored       # sentinel not in bin 0
    assert float(ej.sketch.counts[0]) < scored

    labels = np.zeros(n, np.float32)
    labels[:scored] = (rng.random(scored) < scores).astype(np.float32)
    q = SUPGQuery(target="recall", gamma=0.85, budget=2000)
    sel = ej.run(jax.random.PRNGKey(3), array_oracle(labels), q)
    mask = np.concatenate(sel.masks)
    assert mask[:scored].any()
    assert not mask[scored:].any()                # unscored never selected
    assert sel.total_selected == int(mask.sum())


# -- hierarchical sampler: chunk-level state + dense equivalence --------------

def _dense_probs(engine, scheme):
    """The dense per-record defensive-mixture p(x) the pre-hierarchical
    engine materialized — the reference distribution for equivalence."""
    z = max(engine._z[scheme], 1e-30)
    flat = np.concatenate([np.asarray(s, np.float32) for s in engine.shards])
    a = np.clip(flat, 0.0, 1.0)
    raw = np.sqrt(a) if scheme == "sqrt" else a
    return ((1.0 - engine.kappa) * raw / z
            + engine.kappa / engine.n_total).astype(np.float32)


def test_sampling_state_is_chunk_level():
    """Persistent sampling state must be O(n / chunk_records) per
    (shard, scheme) — chunk-mass CDFs, never per-record arrays."""
    rng = np.random.default_rng(3)
    shards = [rng.random(n).astype(np.float32) for n in (9000, 100, 4096)]
    engine = SelectionEngine(shards, num_bins=512, chunk_records=1024,
                             weight_schemes=("sqrt", "prop"))
    assert len(engine._sampling_cache) == 2
    for states in engine._sampling_cache.values():
        for sh, st in enumerate(states):
            n_chunks = -(-shards[sh].shape[0] // 1024)
            assert st.cdf.size == n_chunks == engine.plan.num_chunks(sh)
            assert not hasattr(st, "p_global")
    for sh, cm in enumerate(engine._chunk_masses):
        assert cm.sizes.size == engine.plan.num_chunks(sh)
        assert int(cm.sizes.sum()) == shards[sh].shape[0]


@pytest.mark.parametrize("scheme", ["sqrt", "prop"])
def test_hierarchical_draw_matches_dense_distribution(scheme):
    """Fixed-key statistical equivalence vs the dense-CDF path: the
    hierarchical (shard → chunk → record) draw must target exactly the
    dense defensive-mixture p(x), verified by a chi-square over index bins
    against the dense probabilities."""
    from scipy import stats

    rng = np.random.default_rng(17)
    scores = rng.beta(0.2, 1.0, 30_000).astype(np.float32)
    engine = SelectionEngine(np.array_split(scores, 3), num_bins=1024,
                             chunk_records=2048)
    s = 60_000
    idx, _ = engine.draw_sample(jax.random.PRNGKey(0), s, scheme)
    p = _dense_probs(engine, scheme).astype(np.float64)
    bins = 50
    edges = np.linspace(0, engine.n_total, bins + 1).astype(np.int64)
    f_obs = np.histogram(idx, bins=edges)[0]
    mass = np.add.reduceat(p, edges[:-1])
    f_exp = f_obs.sum() * mass / mass.sum()
    assert stats.chisquare(f_obs, f_exp).pvalue > 1e-3


@pytest.mark.parametrize("scheme", ["sqrt", "prop"])
def test_hierarchical_draw_m_p_identity(scheme):
    """Exactness per draw: m(x)·p(x) ≡ 1/n against the dense p(x) — the
    within-chunk weights recomputed at query time reproduce the global
    defensive mixture record-for-record, so reweighting stays unbiased
    with no O(n) state."""
    rng = np.random.default_rng(23)
    scores = rng.random(20_000).astype(np.float32)
    scores[rng.integers(0, 20_000, 700)] = -1.0     # unscored sentinels
    engine = SelectionEngine(np.array_split(scores, 4), num_bins=512,
                             chunk_records=1500)
    idx, m = engine.draw_sample(jax.random.PRNGKey(11), 10_000, scheme)
    p = _dense_probs(engine, scheme).astype(np.float64)
    np.testing.assert_allclose(m.astype(np.float64) * p[idx],
                               1.0 / engine.n_total, rtol=1e-5)


def test_draw_sample_worker_count_invariant():
    """Thread count must never change a single output bit: draws are
    grouped to preassigned slots before the pool runs."""
    rng = np.random.default_rng(29)
    shards = [rng.random(n).astype(np.float32) for n in (7000, 0, 12_000)]
    key = jax.random.PRNGKey(3)
    e1 = SelectionEngine(shards, num_bins=512, chunk_records=1024, workers=1)
    e8 = SelectionEngine(shards, num_bins=512, chunk_records=1024, workers=8)
    for a, b in zip(e1.sketch, e8.sketch):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for scheme in ("sqrt", "prop", "uniform"):
        i1, m1 = e1.draw_sample(key, 5000, scheme)
        i8, m8 = e8.draw_sample(key, 5000, scheme)
        np.testing.assert_array_equal(i1, i8)
        np.testing.assert_array_equal(m1, m8)
    r1 = e1._uniform_in_region(key, 4000, 0.6)
    r8 = e8._uniform_in_region(key, 4000, 0.6)
    np.testing.assert_array_equal(r1, r8)


@pytest.mark.parametrize("qspec", ["rt", "pt", "jt"])
def test_threaded_queries_match_serial(tmp_path, qspec):
    """Full queries through the worker pool return bit-for-bit the serial
    results, through in-memory, memmap-bitmask and callback sinks."""
    ds = make_beta(50_000, 0.02, 1.0, seed=44)
    oracle = array_oracle(ds.labels)
    kw = dict(num_bins=1024, chunk_records=3000)
    serial = SelectionEngine(np.array_split(ds.scores, 4), **kw)
    threaded = SelectionEngine(np.array_split(ds.scores, 4), workers=4, **kw)
    q = {"rt": SUPGQuery(target="recall", gamma=0.9, budget=2000),
         "pt": SUPGQuery(target="precision", gamma=0.8, budget=2000,
                         method="is", two_stage=True),
         "jt": JointSUPGQuery(gamma_recall=0.85, stage_budget=2000)}[qspec]
    key = jax.random.PRNGKey(13)

    def run(engine, sink=None):
        if qspec == "jt":
            return engine.run_joint(key, oracle, q, sink=sink)
        return engine.run(key, oracle, q, sink=sink)

    base = run(serial)
    got = run(threaded)
    assert got.tau == base.tau
    np.testing.assert_array_equal(got.shard_counts, base.shard_counts)
    np.testing.assert_array_equal(np.concatenate(got.masks),
                                  np.concatenate(base.masks))
    bits = BitmaskStore(tmp_path / f"{qspec}.bits")
    np.testing.assert_array_equal(
        np.concatenate(run(threaded, sink=bits).masks),
        np.concatenate(base.masks))
    # callback sink: chunk arrival order is unspecified under the pool,
    # but the rebuilt selection must match exactly
    got_chunks = [[] for _ in threaded.shards]
    run(threaded, sink=CallbackSink(
        lambda sh, gids, folded: got_chunks[sh].append(gids)))
    rebuilt = np.zeros(threaded.n_total, bool)
    for chunks in got_chunks:
        if chunks:
            rebuilt[np.concatenate(chunks)] = True
    np.testing.assert_array_equal(rebuilt, np.concatenate(base.masks))


# -- 1e8-record acceptance: bounded-memory streaming -------------------------

@pytest.mark.slow
def test_1e8_memmap_query_streams_with_bounded_memory(tmp_path):
    """A 1e8-record memmap ScoreStore query completes with peak host
    memory bounded by chunk size: the sketch is built chunk-by-chunk, no
    flat cache or per-record sampling state is allocated, the selection
    lands packed in a memmap BitmaskStore, and no full-corpus boolean mask
    ever exists. Output is verified against the direct threshold baseline
    chunk-by-chunk (counts over the whole corpus, bits over windows)."""
    n = 100_000_000
    chunk = 4_000_000
    store = ScoreStore(tmp_path / "big.scores", n, create=True)
    rng = np.random.default_rng(0)
    for off in range(0, n, chunk):
        store.write(off, rng.random(chunk, dtype=np.float32))

    engine = SelectionEngine([store], num_bins=4096, use_kernel=False,
                             weight_schemes=(), select_backend="ref",
                             chunk_records=chunk)
    # structural bounded-memory guarantees: no O(n) host state beyond the
    # memmap itself
    assert engine._flat is None
    assert not engine._sampling_cache

    def oracle_fn(idx):
        return (store.scores[np.asarray(idx, np.int64)] > 0.9).astype(
            np.float32)

    q = SUPGQuery(target="recall", gamma=0.9, budget=3000, method="uniform")
    sink = BitmaskStore(tmp_path / "big.bits")
    sel = engine.run(jax.random.PRNGKey(1), oracle_fn, q, sink=sink)
    assert 0.0 < sel.tau < 1.0
    assert sel.sink is sink

    # folded positives (below tau) per chunk, for exact count accounting
    pos = sel.sampled_positive_global
    folded = pos[np.asarray(store.scores[pos]) < sel.tau]
    folded_per_chunk = np.bincount(folded // chunk, minlength=n // chunk)

    popcount = np.asarray([bin(i).count("1") for i in range(256)], np.int64)
    arr = sink._arr
    total = 0
    for ci, off in enumerate(range(0, n, chunk)):
        scores_chunk = np.asarray(store.scores[off:off + chunk])
        expect = int(np.count_nonzero(scores_chunk >= sel.tau))
        got = int(popcount[arr[off // 8:(off + chunk) // 8]].sum())
        assert got == expect + int(folded_per_chunk[ci]), (ci, got, expect)
        total += got
    assert sel.total_selected == total
    # windows decoded bit-for-bit against the direct baseline
    for w0 in (0, 48_000_000, n - 80_000):
        w1 = w0 + 80_000
        bits = np.unpackbits(np.asarray(arr[w0 // 8:w1 // 8]),
                             bitorder="little").astype(bool)
        expect = np.asarray(store.scores[w0:w1]) >= sel.tau
        for g in folded[(folded >= w0) & (folded < w1)]:
            expect[g - w0] = True
        np.testing.assert_array_equal(bits, expect)


@pytest.mark.slow
def test_1e8_memmap_is_query_bounded_memory(tmp_path):
    """An importance-weighted (method='is', scheme='sqrt') RT query over a
    1e8-record memmap ScoreStore runs at O(chunk) peak host memory: the
    persistent sampling state is ≤ n / chunk_records entries per
    (shard, scheme) — no per-record CDF or p(x) array ever exists — and the
    query's peak-RSS delta stays far below the ~1.2 GB the dense state
    would allocate. No `weight_schemes=()` escape hatch needed."""
    import resource

    n = 100_000_000
    chunk = 4_000_000
    store = ScoreStore(tmp_path / "big_is.scores", n, create=True)
    rng = np.random.default_rng(2)
    for off in range(0, n, chunk):
        store.write(off, rng.random(chunk, dtype=np.float32))

    engine = SelectionEngine([store], num_bins=4096, use_kernel=False,
                             select_backend="ref", chunk_records=chunk,
                             workers=2)
    assert engine._flat is None
    # persistent hierarchical state: chunk-level only
    assert len(engine._sampling_cache) == 1        # default ("sqrt",) warm
    for states in engine._sampling_cache.values():
        for st in states:
            assert st.cdf.size <= n // chunk
    for cm in engine._chunk_masses:
        assert cm.sizes.size <= n // chunk
        assert int(cm.sizes.sum()) == n

    def oracle_fn(idx):
        return (store.scores[np.asarray(idx, np.int64)] > 0.9).astype(
            np.float32)

    rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss   # KiB
    q = SUPGQuery(target="recall", gamma=0.9, budget=3000, method="is",
                  weight_scheme="sqrt")
    sink = BitmaskStore(tmp_path / "big_is.bits")
    sel = engine.run(jax.random.PRNGKey(5), oracle_fn, q, sink=sink)
    rss1 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    assert 0.0 < sel.tau < 1.0
    # the dense path allocated 12 B/record (~1.2 GB) on first IS draw;
    # the hierarchical draw streams O(chunk) transients only
    assert (rss1 - rss0) * 1024 < 500 * 1024 * 1024, (rss0, rss1)

    # exact count accounting, chunk by chunk, against the direct baseline
    pos = sel.sampled_positive_global
    folded = pos[np.asarray(store.scores[pos]) < sel.tau]
    folded_per_chunk = np.bincount(folded // chunk, minlength=n // chunk)
    popcount = np.asarray([bin(i).count("1") for i in range(256)], np.int64)
    arr = sink._arr
    total = 0
    for ci, off in enumerate(range(0, n, chunk)):
        expect = int(np.count_nonzero(
            np.asarray(store.scores[off:off + chunk]) >= sel.tau))
        got = int(popcount[arr[off // 8:(off + chunk) // 8]].sum())
        assert got == expect + int(folded_per_chunk[ci]), (ci, got, expect)
        total += got
    assert sel.total_selected == total


# -- equivalence: engine vs single-host exact path ---------------------------

def test_engine_consistent_with_run_query():
    """The sharded, sketch-backed engine and the single-host exact path must
    select statistically consistent sets at matched seeds/budgets: both meet
    their target (allowing one delta-level miss across seeds) and the
    selected-set sizes agree within a small factor."""
    ds = make_beta(60_000, 0.01, 1.0, seed=30)
    truth = ds.truth_mask()
    oracle = array_oracle(ds.labels)
    engine = SelectionEngine(np.array_split(ds.scores, 4), num_bins=1024)

    for target, gamma, metric in (
            ("recall", 0.9, queries.recall_of),
            ("precision", 0.8, queries.precision_of)):
        q = SUPGQuery(target=target, gamma=gamma, delta=0.05, budget=3000,
                      method="is")
        misses_engine = misses_exact = 0
        for t in range(3):
            key = jax.random.PRNGKey(100 + t)
            sel = engine.run(key, oracle, q)
            res = queries.run_query(key, ds.scores, oracle, q)
            got_e = metric(np.nonzero(np.concatenate(sel.masks))[0], truth)
            got_x = metric(res.selected, truth)
            misses_engine += got_e < gamma
            misses_exact += got_x < gamma
            n_e = max(sel.total_selected, 1)
            n_x = max(res.selected.shape[0], 1)
            assert 1 / 5 < n_e / n_x < 5, (target, t, n_e, n_x)
        assert misses_engine <= 1, target
        assert misses_exact <= 1, target


# -- QuerySession: async multi-query execution --------------------------------

def _sink_contents(sel):
    """Per-shard sorted selected indices — the sink-contents fingerprint."""
    return [sel.indices(sh) for sh in range(sel.num_shards)]


def test_run_many_session_bit_for_bit_vs_sequential():
    """Acceptance: run_many(concurrency=8) produces identical tau, counts,
    and sink contents to the sequential path (concurrency=1) and to
    independent run/run_joint calls, for an RT/PT/JT mix under one key."""
    ds = make_beta(60_000, 0.02, 1.0, seed=52)
    engine = SelectionEngine(np.array_split(ds.scores, 3), num_bins=1024,
                             chunk_records=7_000)
    oracle = array_oracle(ds.labels)
    batch = [
        SUPGQuery(target="recall", gamma=0.9, budget=2000, method="is"),
        SUPGQuery(target="recall", gamma=0.85, budget=1500, method="noci"),
        SUPGQuery(target="precision", gamma=0.8, budget=2000, method="is",
                  two_stage=True),
        SUPGQuery(target="precision", gamma=0.75, budget=1500,
                  method="uniform"),
        JointSUPGQuery(gamma_recall=0.85, stage_budget=2000),
    ]
    key = jax.random.PRNGKey(33)
    seq = engine.run_many(key, oracle, list(batch), concurrency=1)
    conc = engine.run_many(key, oracle, list(batch), concurrency=8)
    keys = jax.random.split(key, len(batch))
    for k, q, a, b in zip(keys, batch, seq, conc):
        assert a.tau == b.tau
        np.testing.assert_array_equal(a.shard_counts, b.shard_counts)
        for ia, ib in zip(_sink_contents(a), _sink_contents(b)):
            np.testing.assert_array_equal(ia, ib)
        # and both match a fully independent solo execution under the key
        solo = (engine.run_joint(k, oracle, q)
                if isinstance(q, JointSUPGQuery)
                else engine.run(k, oracle, q))
        assert solo.tau == a.tau
        for ia, ib in zip(_sink_contents(solo), _sink_contents(a)):
            np.testing.assert_array_equal(ia, ib)


def test_session_coalesces_oracle_calls_on_overlapping_samples():
    """Acceptance: a session issues fewer underlying oracle invocations
    (batched fn calls) and labels fewer records than the per-query
    sequential baseline when samples overlap, with per-query budgets
    still enforced."""
    ds = make_beta(40_000, 0.02, 1.0, seed=53)
    engine = SelectionEngine(np.array_split(ds.scores, 2), num_bins=1024)
    q = SUPGQuery(target="recall", gamma=0.9, budget=1500, method="is")
    key = jax.random.PRNGKey(9)

    def counting():
        log = []
        arr = np.asarray(ds.labels, np.float32)

        def fn(idx):
            log.append(np.asarray(idx))
            return arr[np.asarray(idx, np.int64)]

        return fn, log

    # sequential baseline: one private channel per query
    fn, log = counting()
    base = [engine.run(key, fn, q) for _ in range(8)]
    base_calls = len(log)
    base_labeled = sum(c.size for c in log)

    # session: same 8 queries (same key => fully overlapping samples)
    fn, log = counting()
    with engine.session(fn) as sess:
        handles = [sess.submit(q, key=key) for _ in range(8)]
        got = [h.result() for h in handles]
    assert len(log) < base_calls                 # coalesced fn batches
    assert sum(c.size for c in log) < base_labeled   # shared-cache reuse
    assert sess.client.fn_calls == len(log)
    for b, g in zip(base, got):
        assert g.tau == b.tau                    # identical results
        np.testing.assert_array_equal(g.shard_counts, b.shard_counts)
        assert g.oracle_calls <= q.budget        # budgets still enforced


def test_session_handles_lifecycle():
    ds = make_beta(20_000, 0.02, 1.0, seed=54)
    engine = SelectionEngine(np.array_split(ds.scores, 2), num_bins=512)
    oracle = array_oracle(ds.labels)
    q = SUPGQuery(target="recall", gamma=0.9, budget=800)
    with engine.session(oracle, concurrency=2) as sess:
        hs = [sess.submit(q, key=jax.random.PRNGKey(i)) for i in range(4)]
        assert not any(h.done for h in hs)
        first = hs[0].result()                   # pumps until hs[0] is done
        assert hs[0].done and first.total_selected > 0
    # context exit pumps the rest to completion
    assert all(h.done for h in hs)
    assert all(h.result().total_selected > 0 for h in hs)
    with pytest.raises(RuntimeError, match="closed"):
        sess.submit(q)
    # abandoned sessions reject unfinished queries instead of hanging
    sess2 = engine.session(oracle)
    h2 = sess2.submit(q)
    sess2.close(abandon=True)
    with pytest.raises(RuntimeError, match="abandoned"):
        h2.result()


def test_session_shared_client_across_sessions():
    """An explicit BatchingOracle passes through the adapter, so its label
    cache carries across sessions and run_many batches."""
    from repro.core.oracle import BatchingOracle

    ds = make_beta(20_000, 0.02, 1.0, seed=55)
    engine = SelectionEngine(np.array_split(ds.scores, 2), num_bins=512)
    client = BatchingOracle(array_oracle(ds.labels))
    q = SUPGQuery(target="recall", gamma=0.9, budget=800)
    key = jax.random.PRNGKey(4)
    a = engine.run(key, client, q)
    calls_after_first = client.fn_calls
    b = engine.run(key, client, q)               # same sample: all cached
    assert client.fn_calls == calls_after_first
    assert b.tau == a.tau and b.oracle_calls == 0


def test_run_many_validates_sinks_before_keys():
    """Regression: the sink-list length check must fire before any key
    handling, and sharing one sink object across queries is rejected."""
    ds = make_beta(5_000, 0.05, 1.0, seed=56)
    engine = SelectionEngine([ds.scores], num_bins=512)
    oracle = array_oracle(ds.labels)
    qs = [SUPGQuery(target="recall", gamma=0.9, budget=200)] * 2
    with pytest.raises(ValueError, match="one sink"):
        # key=None used to be split before the validation could fire
        engine.run_many(None, oracle, qs, sinks=[None])
    shared = IndexSink()
    with pytest.raises(ValueError, match="shared"):
        engine.run_many(None, oracle, qs, sinks=[shared, shared])
    assert engine.run_many(None, oracle, [], sinks=[]) == []


def test_sink_refuses_double_open():
    sink = IndexSink()
    sink.open([10, 5])
    with pytest.raises(RuntimeError, match="already open"):
        sink.open([10, 5])
    sink.close()
    sink.open([4])                               # sequential reuse is fine
    sink.emit(0, np.asarray([1, 2]))
    sink.close()
    np.testing.assert_array_equal(sink.indices(0), [1, 2])


def test_session_drain_failure_fails_loud_not_silent():
    """Regression: a drain that blows up mid-session (broken oracle) used
    to leave in-flight plans with stale inboxes — the next pump resumed
    them with the previous round's payload and returned silently corrupted
    selections. Every affected handle must now raise, and the session must
    stay pumpable (close() terminates cleanly)."""
    ds = make_beta(10_000, 0.05, 1.0, seed=57)
    engine = SelectionEngine(np.array_split(ds.scores, 2), num_bins=512)
    q = SUPGQuery(target="recall", gamma=0.9, budget=500)
    boom = [True]
    arr = np.asarray(ds.labels, np.float32)

    def flaky(idx):
        if boom[0]:
            raise IOError("labeling backend down")
        return arr[np.asarray(idx, np.int64)]

    sess = engine.session(flaky, concurrency=4)
    hs = [sess.submit(q, key=jax.random.PRNGKey(i)) for i in range(3)]
    with pytest.raises(IOError, match="backend down"):
        hs[0].result()
    boom[0] = False                       # backend recovers...
    for h in hs:                          # ...but the round was poisoned:
        with pytest.raises(IOError):      # affected plans fail loud, never
            h.result()                    # resume on stale labels
    sess.close()                          # and the session winds down clean
    fresh = engine.session(flaky)
    ok = fresh.submit(q, key=jax.random.PRNGKey(0)).result()
    assert ok.total_selected > 0
    fresh.close()


def test_failed_query_releases_sink_for_reuse():
    """Regression: a JT plan that dies mid-verification (or an emission
    pass whose consumer raises) must release its sink — the double-open
    guard would otherwise wedge the sink object forever."""
    ds = make_beta(10_000, 0.05, 1.0, seed=58)
    engine = SelectionEngine(np.array_split(ds.scores, 2), num_bins=512)
    arr = np.asarray(ds.labels, np.float32)
    calls = [0]

    def flaky(idx):
        calls[0] += 1
        if calls[0] > 1:                    # RT stage ok, verification dies
            raise IOError("down")
        return arr[np.asarray(idx, np.int64)]

    sink = IndexSink()
    jt = JointSUPGQuery(gamma_recall=0.8, stage_budget=400)
    with pytest.raises(IOError):
        engine.run_joint(jax.random.PRNGKey(1), flaky, jt, sink=sink,
                         chunk_records=500)
    # the sink is reusable: the same object serves the retry
    sel = engine.run_joint(jax.random.PRNGKey(1), array_oracle(ds.labels),
                           jt, sink=sink, chunk_records=500)
    assert sel.total_selected > 0 and sel.sink is sink


def test_session_submit_time_drain_failure_fails_loud():
    """Regression: with max_batch set, client.submit() inside a scheduler
    round can auto-drain and blow up *before* the round state was
    committed; stale inboxes then resumed plans on the previous round's
    labels. Every affected handle must raise instead."""
    ds = make_beta(10_000, 0.05, 1.0, seed=59)
    engine = SelectionEngine(np.array_split(ds.scores, 2), num_bins=512)
    q = SUPGQuery(target="recall", gamma=0.9, budget=400)
    boom = [True]
    arr = np.asarray(ds.labels, np.float32)

    def flaky(idx):
        if boom[0]:
            raise IOError("backend down")
        return arr[np.asarray(idx, np.int64)]

    # max_batch far below the per-query sample size => the first submit
    # crosses the threshold and auto-drains inside the round
    sess = engine.session(flaky, concurrency=4, max_batch=64)
    hs = [sess.submit(q, key=jax.random.PRNGKey(i)) for i in range(3)]
    with pytest.raises(IOError, match="backend down"):
        hs[0].result()
    boom[0] = False
    for h in hs:
        with pytest.raises(IOError):        # loud, never stale-label resumes
            h.result()
    sess.close()
    # the engine itself is unharmed
    ok = engine.run(jax.random.PRNGKey(0), array_oracle(ds.labels), q)
    assert ok.total_selected > 0


# -- PR 6: overlapped rounds, worker clamp, overlap stats ---------------------

def test_session_overlapped_drains_bit_for_bit_across_workers():
    """Acceptance: the double-buffered scheduler (drains overlapping the
    other cohort's compute) is bit-for-bit equal to the sequential path
    for an RT/PT/JT mix at workers in {1, 4, 8}. clamp_workers=False so
    the requested counts are honored even on small CI boxes."""
    ds = make_beta(30_000, 0.02, 1.0, seed=57)
    shards = np.array_split(ds.scores, 3)
    oracle = array_oracle(ds.labels)
    batch = [
        SUPGQuery(target="recall", gamma=0.9, budget=1200, method="is"),
        SUPGQuery(target="precision", gamma=0.8, budget=1200, method="is",
                  two_stage=True),
        SUPGQuery(target="recall", gamma=0.85, budget=1000, method="noci"),
        JointSUPGQuery(gamma_recall=0.85, stage_budget=1200),
    ]
    key = jax.random.PRNGKey(77)
    ref = SelectionEngine(shards, num_bins=1024, chunk_records=5_000,
                          workers=1).run_many(key, oracle, list(batch),
                                              concurrency=1)
    for w in (1, 4, 8):
        with SelectionEngine(shards, num_bins=1024, chunk_records=5_000,
                             workers=w, clamp_workers=False) as engine:
            got = engine.run_many(key, oracle, list(batch), concurrency=8)
        for a, b in zip(ref, got):
            assert a.tau == b.tau, w
            np.testing.assert_array_equal(a.shard_counts, b.shard_counts)
            for ia, ib in zip(_sink_contents(a), _sink_contents(b)):
                np.testing.assert_array_equal(ia, ib)


def test_engine_worker_clamp_logs_once_with_escape_hatch(monkeypatch,
                                                         caplog):
    """Oversubscription fix: requested workers are clamped to cpu_count
    (logged exactly once process-wide); clamp_workers=False keeps the
    requested count for determinism tests."""
    import logging

    from repro.core import engine as engine_mod

    monkeypatch.setattr(engine_mod.os, "cpu_count", lambda: 2)
    monkeypatch.setattr(engine_mod, "_clamp_logged", False)
    scores = np.linspace(0.0, 1.0, 1_000, dtype=np.float32)
    with caplog.at_level(logging.INFO, logger="repro.core.engine"):
        with SelectionEngine([scores], num_bins=64, workers=8) as e1:
            assert e1.workers == 2
            with SelectionEngine([scores], num_bins=64, workers=8) as e2:
                assert e2.workers == 2
    clamps = [r for r in caplog.records if "clamping" in r.getMessage()]
    assert len(clamps) == 1                 # logged once, not per engine
    with SelectionEngine([scores], num_bins=64, workers=8,
                         clamp_workers=False) as e3:
        assert e3.workers == 8              # escape hatch honored


def test_session_stats_record_overlap_and_fusion():
    """SessionStats from a batch of same-shape queries: rounds/drains are
    counted, drain timers are sane, and the emission walks of co-resident
    queries fused into shared chunk passes (spans_saved > 0)."""
    ds = make_beta(30_000, 0.02, 1.0, seed=58)
    engine = SelectionEngine(np.array_split(ds.scores, 2), num_bins=1024,
                             chunk_records=4_000)
    oracle = array_oracle(ds.labels)
    qs = [SUPGQuery(target="recall", gamma=0.9, budget=1000, method="is")
          for _ in range(4)]
    keys = jax.random.split(jax.random.PRNGKey(5), len(qs))
    with engine.session(oracle) as sess:
        handles = [sess.submit(q, key=k) for q, k in zip(qs, keys)]
        results = [h.result() for h in handles]
    assert all(r.total_selected > 0 for r in results)
    st = sess.stats
    assert st.rounds > 0
    assert st.plan_steps >= len(qs)         # every plan stepped >= once
    assert st.drains >= 1                   # labeling went through drains
    assert st.drain_busy_s >= st.drain_wait_s >= 0.0
    assert st.overlap_hidden_s >= 0.0
    # all four RT emission walks ran through the fusion path, and walks
    # sharing a round+geometry collapsed into shared spans
    assert st.fused_walks == len(qs)
    assert st.walk_spans >= st.fused_spans > 0
    assert st.spans_saved > 0
