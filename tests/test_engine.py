"""SelectionEngine data-plane tests: cached-state sampling, vectorized
gathers, regression fixes, run_many batching, and equivalence against the
single-host exact path."""
import numpy as np
import pytest

import jax

from repro.core import queries
from repro.core.engine import SelectionEngine, ShardedSelection
from repro.core.oracle import array_oracle
from repro.core.queries import JointSUPGQuery, SUPGQuery
from repro.data.pipeline import ScoreStore
from repro.data.synthetic import make_beta


# -- regression: total_selected ---------------------------------------------

def test_total_selected_is_mask_sum():
    """Regression: the seed carried a dead expression that always added 0;
    total_selected must equal the plain sum over shard masks."""
    masks = [np.array([True, False, True]), np.array([False, True])]
    sel = ShardedSelection(masks=masks, tau=0.5, oracle_calls=7,
                           sampled_positive_global=np.array([0, 4]))
    assert sel.total_selected == 3


# -- regression: empty shards in _uniform_in_region -------------------------

def test_uniform_in_region_excludes_empty_shards():
    """Shards whose region {A >= tau} is empty must receive zero draws —
    the seed floored their mass at 1e-30 and then clamp-returned records
    *below* tau."""
    lo = np.zeros(1000, np.float32)             # region empty at tau=0.5
    hi = np.full(500, 0.9, np.float32)
    engine = SelectionEngine([lo, hi], num_bins=512)
    idx = engine._uniform_in_region(jax.random.PRNGKey(0), 300, 0.5)
    assert np.all(idx >= 1000)                  # never from the empty shard
    assert np.all(engine.score_at(idx) >= 0.5)


def test_uniform_in_region_globally_empty_falls_back_to_uniform():
    engine = SelectionEngine([np.zeros(100, np.float32),
                              np.zeros(50, np.float32)], num_bins=512)
    idx = engine._uniform_in_region(jax.random.PRNGKey(1), 64, 0.5)
    assert idx.shape == (64,)
    assert np.all((idx >= 0) & (idx < 150))


# -- vectorized gathers ------------------------------------------------------

def test_score_at_matches_elementwise_gather():
    rng = np.random.default_rng(0)
    shards = [rng.random(n).astype(np.float32) for n in (1000, 1, 2500, 700)]
    flat = np.concatenate(shards)
    gi = rng.integers(0, flat.shape[0], 5000)
    # both gather paths: flat concatenation cache and routed per-shard
    fast = SelectionEngine(shards, num_bins=512)
    routed = SelectionEngine(shards, num_bins=512, cache_flat=False)
    assert fast._flat is not None and routed._flat is None
    np.testing.assert_array_equal(fast.score_at(gi), flat[gi])
    np.testing.assert_array_equal(routed.score_at(gi), flat[gi])


def test_fold_positives_vectorized():
    shards = [np.zeros(100, np.float32), np.zeros(50, np.float32)]
    engine = SelectionEngine(shards, num_bins=512)
    masks = [np.zeros(100, bool), np.zeros(50, bool)]
    engine._fold_positives(masks, np.asarray([0, 99, 100, 149], np.int64))
    assert masks[0][0] and masks[0][99] and masks[1][0] and masks[1][49]
    assert masks[0].sum() == 2 and masks[1].sum() == 2


# -- cached sampling state ---------------------------------------------------

def test_draw_sample_reweighting_unbiased_from_cache():
    """m(x) factors from the sketch-derived cached CDFs stay unbiased."""
    ds = make_beta(80_000, 0.05, 1.0, seed=6)
    engine = SelectionEngine(np.array_split(ds.scores, 3), num_bins=1024)
    idx, m = engine.draw_sample(jax.random.PRNGKey(1), 20_000, "sqrt")
    est = float(np.mean(ds.labels[idx] * m))
    assert est == pytest.approx(float(ds.labels.mean()), rel=0.2)
    # second draw hits the cache — same state object, no rebuild
    assert len(engine._sampling_cache) == 1
    engine.draw_sample(jax.random.PRNGKey(2), 100, "sqrt")
    assert len(engine._sampling_cache) == 1


def test_scorestore_shards_work_end_to_end(tmp_path):
    ds = make_beta(40_000, 0.02, 1.0, seed=8)
    halves = np.array_split(ds.scores, 2)
    stores = []
    for i, half in enumerate(halves):
        st = ScoreStore(tmp_path / f"shard{i}.scores", half.shape[0],
                        create=True)
        st.write(0, half)
        stores.append(st)
    engine = SelectionEngine(stores, num_bins=1024)
    assert engine.n_total == 40_000
    # out-of-core shards must NOT be concatenated into a RAM flat cache
    assert engine._flat is None
    q = SUPGQuery(target="recall", gamma=0.9, delta=0.05, budget=3000,
                  method="is")
    sel = engine.run(jax.random.PRNGKey(3), array_oracle(ds.labels), q)
    mask = np.concatenate(sel.masks)
    assert queries.recall_of(np.nonzero(mask)[0], ds.truth_mask()) >= 0.85
    assert sel.oracle_calls <= 3000


# -- run_many ----------------------------------------------------------------

def test_run_many_batches_rt_pt_jt():
    ds = make_beta(100_000, 0.01, 1.0, seed=12)
    engine = SelectionEngine(np.array_split(ds.scores, 4), num_bins=1024)
    oracle = array_oracle(ds.labels)
    batch = [
        SUPGQuery(target="recall", gamma=0.9, delta=0.05, budget=3000,
                  method="is"),
        SUPGQuery(target="precision", gamma=0.9, delta=0.05, budget=3000,
                  method="is"),
        JointSUPGQuery(gamma_recall=0.8, stage_budget=3000),
    ]
    results = engine.run_many(jax.random.PRNGKey(5), oracle, batch)
    assert len(results) == 3
    truth = ds.truth_mask()
    rt_mask = np.concatenate(results[0].masks)
    assert queries.recall_of(np.nonzero(rt_mask)[0], truth) >= 0.85
    pt_mask = np.concatenate(results[1].masks)
    assert queries.precision_of(np.nonzero(pt_mask)[0], truth) >= 0.8
    # JT: exhaustive filtering => precision exactly 1.0, recall from RT stage
    jt_mask = np.concatenate(results[2].masks)
    assert queries.precision_of(np.nonzero(jt_mask)[0], truth) == \
        pytest.approx(1.0)
    assert queries.recall_of(np.nonzero(jt_mask)[0], truth) >= 0.75
    assert results[2].oracle_calls > 3000    # stage-3 usage is unbounded
    # budgets stay per-query for plain queries
    for r in results[:2]:
        assert r.oracle_calls <= 3000


def test_run_many_matches_independent_runs():
    """run_many is a batching device, not a semantics change: with matched
    per-query keys it returns exactly what independent run() calls do."""
    ds = make_beta(50_000, 0.02, 1.0, seed=14)
    engine = SelectionEngine(np.array_split(ds.scores, 3), num_bins=1024)
    oracle = array_oracle(ds.labels)
    qs = [SUPGQuery(target="recall", gamma=0.85, budget=2000, method="is"),
          SUPGQuery(target="precision", gamma=0.8, budget=2000,
                    method="noci")]
    key = jax.random.PRNGKey(21)
    batched = engine.run_many(key, oracle, qs)
    keys = jax.random.split(key, 2)
    for k, q, b in zip(keys, qs, batched):
        solo = engine.run(k, oracle, q)
        assert solo.tau == b.tau
        np.testing.assert_array_equal(np.concatenate(solo.masks),
                                      np.concatenate(b.masks))


# -- equivalence: engine vs single-host exact path ---------------------------

def test_engine_consistent_with_run_query():
    """The sharded, sketch-backed engine and the single-host exact path must
    select statistically consistent sets at matched seeds/budgets: both meet
    their target (allowing one delta-level miss across seeds) and the
    selected-set sizes agree within a small factor."""
    ds = make_beta(60_000, 0.01, 1.0, seed=30)
    truth = ds.truth_mask()
    oracle = array_oracle(ds.labels)
    engine = SelectionEngine(np.array_split(ds.scores, 4), num_bins=1024)

    for target, gamma, metric in (
            ("recall", 0.9, queries.recall_of),
            ("precision", 0.8, queries.precision_of)):
        q = SUPGQuery(target=target, gamma=gamma, delta=0.05, budget=3000,
                      method="is")
        misses_engine = misses_exact = 0
        for t in range(3):
            key = jax.random.PRNGKey(100 + t)
            sel = engine.run(key, oracle, q)
            res = queries.run_query(key, ds.scores, oracle, q)
            got_e = metric(np.nonzero(np.concatenate(sel.masks))[0], truth)
            got_x = metric(res.selected, truth)
            misses_engine += got_e < gamma
            misses_exact += got_x < gamma
            n_e = max(sel.total_selected, 1)
            n_x = max(res.selected.shape[0], 1)
            assert 1 / 5 < n_e / n_x < 5, (target, t, n_e, n_x)
        assert misses_engine <= 1, target
        assert misses_exact <= 1, target
