"""Chunked / recurrent / step linear-scan equivalences (model substrate)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import scan_ops


def _inputs(seed=0, b=2, h=3, s=128, dk=16, dv=24):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    return (jax.random.normal(ks[0], (b, h, s, dk)) * 0.5,
            jax.random.normal(ks[1], (b, h, s, dk)) * 0.5,
            jax.random.normal(ks[2], (b, h, s, dv)) * 0.5,
            jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, s, dk)) + 2.0),
            jax.random.normal(ks[4], (h, dk)) * 0.3)


@pytest.mark.parametrize("bonus", [False, True])
def test_chunked_matches_recurrent(bonus):
    q, k, v, w, u = _inputs()
    uu = u if bonus else None
    o_r, s_r = scan_ops.linear_scan_recurrent(q, k, v, w, uu)
    o_c, s_c = scan_ops.linear_scan_chunked(q, k, v, w, uu, chunk=32)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r), atol=1e-4)


def test_state_carry_across_segments():
    q, k, v, w, u = _inputs(seed=1)
    o_full, _ = scan_ops.linear_scan_recurrent(q, k, v, w, u)
    _, st = scan_ops.linear_scan_recurrent(
        q[:, :, :64], k[:, :, :64], v[:, :, :64], w[:, :, :64], u)
    o2, _ = scan_ops.linear_scan_chunked(
        q[:, :, 64:], k[:, :, 64:], v[:, :, 64:], w[:, :, 64:], u,
        initial_state=st, chunk=32)
    np.testing.assert_allclose(np.asarray(o2),
                               np.asarray(o_full[:, :, 64:]), atol=1e-4)


def test_step_matches_recurrent():
    q, k, v, w, u = _inputs(seed=2, s=16)
    o_full, _ = scan_ops.linear_scan_recurrent(q, k, v, w, u)
    state = jnp.zeros((2, 3, 16, 24))
    for t in range(16):
        state, ot = scan_ops.step(state, q[:, :, t], k[:, :, t],
                                  v[:, :, t], w[:, :, t], u)
        np.testing.assert_allclose(np.asarray(ot),
                                   np.asarray(o_full[:, :, t]), atol=1e-4)


def test_gradients_flow():
    q, k, v, w, _ = _inputs(seed=3, s=64)

    def loss(q):
        o, _ = scan_ops.linear_scan_chunked(q, k, v, w, chunk=32)
        return jnp.sum(o ** 2)

    g = jax.grad(loss)(q)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.max(jnp.abs(g))) > 0
