"""Hypothesis property tests on the system's statistical invariants."""
import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import binned, sampling, thresholds

import jax.numpy as jnp


@given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.95))
@settings(max_examples=15, deadline=None)
def test_rt_threshold_never_above_empirical_cutoff(seed, gamma):
    """The CI-corrected threshold is always <= the uncorrected one:
    conservatism can only ADD records for a recall target."""
    rng = np.random.default_rng(seed)
    a = rng.random(1500).astype(np.float32)
    o = (rng.random(1500) < a).astype(np.float32)
    if o.sum() == 0:
        return
    t_noci = float(thresholds.tau_unoci_r(a, o, gamma).tau)
    t_ci = float(thresholds.tau_ci_r(a, o, np.ones(1500), gamma, 0.05).tau)
    assert t_ci <= t_noci + 1e-6


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_pt_selected_set_is_score_downward_closed(seed):
    """R2 = {A >= tau}: any record with score above a selected record's
    score is also selected (threshold semantics)."""
    rng = np.random.default_rng(seed)
    a = rng.random(2000).astype(np.float32)
    o = (rng.random(2000) < a ** 2).astype(np.float32)
    res = thresholds.tau_ci_p(a, o, 0.5, 0.1)
    tau = float(res.tau)
    sel = a >= tau
    if sel.any():
        assert a[sel].min() >= tau


@given(st.floats(0.01, 1.0), st.floats(0.2, 3.0),
       st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_weights_are_probabilities(alpha, beta, seed):
    rng = np.random.default_rng(seed)
    scores = rng.beta(alpha, beta, 3000).astype(np.float32)
    for scheme in (sampling.sqrt_proxy_weights,
                   sampling.proportional_proxy_weights):
        w = np.asarray(scheme(jnp.asarray(scores)))
        assert abs(w.sum() - 1.0) < 1e-3
        assert (w >= 0).all()


@given(st.integers(0, 2**31 - 1), st.integers(64, 2048))
@settings(max_examples=15, deadline=None)
def test_sketch_count_conservation(seed, n):
    rng = np.random.default_rng(seed)
    s = rng.random(n).astype(np.float32)
    sk = binned.build_sketch(jnp.asarray(s), 256)
    assert float(sk.total) == n


@given(st.integers(0, 2**31 - 1), st.integers(1, 500))
@settings(max_examples=15, deadline=None)
def test_rank_threshold_superset_property(seed, rank):
    rng = np.random.default_rng(seed)
    s = rng.random(5000).astype(np.float32)
    sk = binned.build_sketch(jnp.asarray(s), 512)
    tau = float(binned.rank_to_threshold(sk, rank))
    assert (s >= tau).sum() >= min(rank, 5000)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_importance_estimator_mean_matches_population(seed):
    """Self-normalized IS estimate of the positive rate is consistent."""
    rng = np.random.default_rng(seed)
    n = 30_000
    scores = rng.beta(0.1, 1, n).astype(np.float32)
    labels = (rng.random(n) < scores).astype(np.float32)
    ws = sampling.draw_oracle_sample(jax.random.PRNGKey(seed % 1000),
                                     jnp.asarray(scores), 8000, "sqrt")
    est = float(np.mean(labels[np.asarray(ws.indices)] * np.asarray(ws.m)))
    truth = float(labels.mean())
    assert abs(est - truth) < max(0.5 * truth, 0.01)
