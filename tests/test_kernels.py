"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.linear_scan import ops as ls_ops
from repro.kernels.score_hist import ops as sh_ops


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kv,s,dh", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 8, 2, 256, 64),     # GQA group 4
    (1, 6, 1, 128, 128),    # MQA
])
def test_flash_attention_matches_ref(b, h, kv, s, dh):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, dh), jnp.float32)
    o_k = fa_ops.flash_attention(q, k, v, block_q=64, block_k=64)
    o_r = fa_ops.flash_attention(q, k, v, backend="ref")
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64), dtype)
    k = jax.random.normal(ks[1], (1, 128, 2, 64), dtype)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), dtype)
    o_k = fa_ops.flash_attention(q, k, v, block_q=64, block_k=64)
    o_r = fa_ops.flash_attention(q, k, v, backend="ref")
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    o_k = fa_ops.flash_attention(q, k, v, causal=False, block_q=64,
                                 block_k=64)
    o_r = fa_ops.flash_attention(q, k, v, causal=False, backend="ref")
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5)


# --------------------------------------------------------------------------
# linear scan
# --------------------------------------------------------------------------

def _scan_inputs(key, b, h, s, dk, dv, decay_shift):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, h, s, dk)) * 0.5
    k = jax.random.normal(ks[1], (b, h, s, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, h, s, dv)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, s, dk))
                       + decay_shift)
    u = jax.random.normal(ks[4], (h, dk)) * 0.3
    return q, k, v, w, u


@pytest.mark.parametrize("bonus", [False, True])
@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_linear_scan_matches_recurrence(bonus, chunk):
    q, k, v, w, u = _scan_inputs(jax.random.PRNGKey(0), 2, 2, 128, 16, 24,
                                 2.5)
    uu = u if bonus else None
    o_k, s_k = ls_ops.linear_scan(q, k, v, w, uu, chunk=chunk)
    o_r, s_r = ls_ops.linear_scan(q, k, v, w, uu, backend="ref")
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=1e-4)


def test_linear_scan_envelope_boundary():
    """Decays at the documented floor (w >= 0.1) still match the oracle."""
    q, k, v, w, u = _scan_inputs(jax.random.PRNGKey(1), 1, 2, 256, 16, 16,
                                 0.0)
    w = jnp.clip(w, 0.1, 1.0)
    o_k, _ = ls_ops.linear_scan(q, k, v, w, u, chunk=32)
    o_r, _ = ls_ops.linear_scan(q, k, v, w, u, backend="ref")
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-3)


def test_linear_scan_out_of_envelope_is_finite():
    q, k, v, w, u = _scan_inputs(jax.random.PRNGKey(2), 1, 1, 128, 8, 8,
                                 -3.0)
    o_k, s_k = ls_ops.linear_scan(q, k, v, w, u, chunk=32)
    assert bool(jnp.all(jnp.isfinite(o_k)))
    assert bool(jnp.all(jnp.isfinite(s_k)))


# --------------------------------------------------------------------------
# score hist
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,bins", [(4096, 512), (10_000, 4096), (777, 512)])
def test_score_hist_matches_ref(n, bins):
    s = jax.random.beta(jax.random.PRNGKey(0), 0.1, 1.0, (n,))
    out_k = sh_ops.score_hist(s, bins, block_n=1024)
    out_r = sh_ops.score_hist(s, bins, backend="ref")
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_score_hist_total_count():
    s = jax.random.uniform(jax.random.PRNGKey(1), (5000,))
    counts, _, _ = sh_ops.score_hist(s, 512)
    assert float(jnp.sum(counts)) == 5000
