"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.linear_scan import ops as ls_ops
from repro.kernels.score_hist import ops as sh_ops
from repro.kernels.threshold_select import ops as ts_ops
from repro.kernels.threshold_select import ref as ts_ref


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kv,s,dh", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 8, 2, 256, 64),     # GQA group 4
    (1, 6, 1, 128, 128),    # MQA
])
def test_flash_attention_matches_ref(b, h, kv, s, dh):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, dh), jnp.float32)
    o_k = fa_ops.flash_attention(q, k, v, block_q=64, block_k=64)
    o_r = fa_ops.flash_attention(q, k, v, backend="ref")
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64), dtype)
    k = jax.random.normal(ks[1], (1, 128, 2, 64), dtype)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), dtype)
    o_k = fa_ops.flash_attention(q, k, v, block_q=64, block_k=64)
    o_r = fa_ops.flash_attention(q, k, v, backend="ref")
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    o_k = fa_ops.flash_attention(q, k, v, causal=False, block_q=64,
                                 block_k=64)
    o_r = fa_ops.flash_attention(q, k, v, causal=False, backend="ref")
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5)


# --------------------------------------------------------------------------
# linear scan
# --------------------------------------------------------------------------

def _scan_inputs(key, b, h, s, dk, dv, decay_shift):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, h, s, dk)) * 0.5
    k = jax.random.normal(ks[1], (b, h, s, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, h, s, dv)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, s, dk))
                       + decay_shift)
    u = jax.random.normal(ks[4], (h, dk)) * 0.3
    return q, k, v, w, u


@pytest.mark.parametrize("bonus", [False, True])
@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_linear_scan_matches_recurrence(bonus, chunk):
    q, k, v, w, u = _scan_inputs(jax.random.PRNGKey(0), 2, 2, 128, 16, 24,
                                 2.5)
    uu = u if bonus else None
    o_k, s_k = ls_ops.linear_scan(q, k, v, w, uu, chunk=chunk)
    o_r, s_r = ls_ops.linear_scan(q, k, v, w, uu, backend="ref")
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=1e-4)


def test_linear_scan_envelope_boundary():
    """Decays at the documented floor (w >= 0.1) still match the oracle."""
    q, k, v, w, u = _scan_inputs(jax.random.PRNGKey(1), 1, 2, 256, 16, 16,
                                 0.0)
    w = jnp.clip(w, 0.1, 1.0)
    o_k, _ = ls_ops.linear_scan(q, k, v, w, u, chunk=32)
    o_r, _ = ls_ops.linear_scan(q, k, v, w, u, backend="ref")
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-3)


def test_linear_scan_out_of_envelope_is_finite():
    q, k, v, w, u = _scan_inputs(jax.random.PRNGKey(2), 1, 1, 128, 8, 8,
                                 -3.0)
    o_k, s_k = ls_ops.linear_scan(q, k, v, w, u, chunk=32)
    assert bool(jnp.all(jnp.isfinite(o_k)))
    assert bool(jnp.all(jnp.isfinite(s_k)))


# --------------------------------------------------------------------------
# score hist
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,bins", [(4096, 512), (10_000, 4096), (777, 512)])
def test_score_hist_matches_ref(n, bins):
    s = jax.random.beta(jax.random.PRNGKey(0), 0.1, 1.0, (n,))
    out_k = sh_ops.score_hist(s, bins, block_n=1024)
    out_r = sh_ops.score_hist(s, bins, backend="ref")
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_score_hist_total_count():
    s = jax.random.uniform(jax.random.PRNGKey(1), (5000,))
    counts, _, _ = sh_ops.score_hist(s, 512)
    assert float(jnp.sum(counts)) == 5000


# --------------------------------------------------------------------------
# threshold select (streaming emission pass)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 777, 1024, 4096, 10_000])
@pytest.mark.parametrize("tau", [0.0, 0.3, 0.999, 1.0])
def test_threshold_select_matches_ref(n, tau):
    """Interpret-mode kernel == numpy nonzero reference, bit-for-bit,
    including the -1 "unscored" sentinel mask and block padding."""
    rng = np.random.default_rng(n)
    s = rng.random(n).astype(np.float32)
    s[rng.integers(0, n, max(n // 10, 1))] = -1.0   # unscored sentinels
    out_k = ts_ops.threshold_select(s, tau, backend="interpret")
    out_r = ts_ref.threshold_select_ref(s, tau)
    np.testing.assert_array_equal(out_k, out_r)
    assert out_k.dtype == np.int64
    # ascending, valid, and count-consistent with a direct mask
    assert np.all(np.diff(out_k) > 0)
    assert out_k.size == int(((s >= tau) & (s >= 0)).sum())


def test_threshold_select_never_selects_sentinel():
    """Even at tau <= 0 the sentinel (-1) must never be selected."""
    s = np.asarray([-1.0, 0.0, 0.5, -1.0, 1.0], np.float32)
    for backend in ("interpret", "ref"):
        out = ts_ops.threshold_select(s, 0.0, backend=backend)
        np.testing.assert_array_equal(out, [1, 2, 4])


def test_threshold_select_edge_cases():
    assert ts_ops.threshold_select(np.empty(0, np.float32), 0.5).size == 0
    all_sel = ts_ops.threshold_select(
        np.full(2048, 0.9, np.float32), 0.5, backend="interpret")
    np.testing.assert_array_equal(all_sel, np.arange(2048))
    none_sel = ts_ops.threshold_select(
        np.full(2048, 0.1, np.float32), 0.5, backend="interpret")
    assert none_sel.size == 0


def test_threshold_select_non_tile_aligned_block_falls_back():
    """block_n not covered by the slot-tile layout routes to the jnp/numpy
    fallback instead of failing (same contract as score_hist)."""
    assert not ts_ops.kernel_supported(300)
    assert ts_ops.kernel_supported(1024)
    s = np.random.default_rng(0).random(1000).astype(np.float32)
    out = ts_ops.threshold_select(s, 0.5, block_n=300, backend="interpret")
    np.testing.assert_array_equal(out, ts_ref.threshold_select_ref(s, 0.5))


def test_threshold_select_memmap_chunk(tmp_path):
    """The reference path operates on memmap chunks without copying."""
    p = tmp_path / "chunk.f32"
    arr = np.memmap(p, np.float32, "w+", shape=(5000,))
    arr[:] = np.random.default_rng(1).random(5000)
    out = ts_ops.threshold_select(arr[1000:3000], 0.7, backend="ref")
    np.testing.assert_array_equal(
        out, np.nonzero(np.asarray(arr[1000:3000]) >= 0.7)[0])
