"""Collection smoke test: import every repro.* module in one place.

Version-compat import breaks (e.g. a jax API that moved between releases)
should fail loudly here, as one parametrized case per module, instead of
knocking out whole test modules at collection time.
"""
import importlib
import pkgutil

import pytest

import repro

# repro.launch.dryrun force-sets XLA_FLAGS at import (device-count override)
# and is a CLI entry point, not a library module.
EXCLUDE = {"repro.launch.dryrun"}


def _all_modules():
    names = ["repro"]
    for info in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        names.append(info.name)
    return sorted(set(names) - EXCLUDE)


@pytest.mark.parametrize("name", _all_modules())
def test_module_imports(name):
    importlib.import_module(name)
