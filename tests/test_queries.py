"""Query-layer semantics: budget, Algorithm-1 set construction, JT queries."""
import jax
import numpy as np
import pytest

from repro.core import queries
from repro.core.oracle import (BudgetedOracle, BudgetExceededError,
                               array_oracle)
from repro.data.synthetic import make_beta


def test_budget_enforced():
    oracle = BudgetedOracle(lambda idx: np.zeros(len(idx)), budget=10)
    oracle(np.arange(10))
    with pytest.raises(BudgetExceededError):
        oracle(np.arange(10, 21))


def test_budget_dedup_and_cache():
    calls = []

    def fn(idx):
        calls.append(len(idx))
        return np.ones(len(idx))

    oracle = BudgetedOracle(fn, budget=5)
    out = oracle(np.asarray([3, 3, 1, 3]))
    assert oracle.calls_used == 2          # {1, 3}
    np.testing.assert_allclose(out, 1.0)
    oracle(np.asarray([1, 3]))             # fully cached, no budget burn
    assert oracle.calls_used == 2
    assert set(oracle.labeled_positives()) == {1, 3}


def test_result_includes_sampled_positives():
    """Algorithm 1: R = R1 (labeled positives) ∪ R2 (A >= tau)."""
    ds = make_beta(100_000, 0.01, 1.0, seed=11)
    q = queries.SUPGQuery(target="precision", gamma=0.9, delta=0.05,
                          budget=3000, method="is")
    res = queries.run_query(jax.random.PRNGKey(0), ds.scores,
                            array_oracle(ds.labels), q)
    above = set(np.nonzero(ds.scores >= res.tau)[0])
    extra = set(res.selected) - above
    # every extra record must be an oracle-verified positive
    assert all(ds.labels[i] > 0.5 for i in extra)
    assert res.oracle_calls <= q.budget


def test_joint_query_achieves_both_targets():
    ds = make_beta(100_000, 0.01, 1.0, seed=13)
    res = queries.run_joint_query(jax.random.PRNGKey(1), ds.scores,
                                  array_oracle(ds.labels),
                                  gamma_recall=0.8, gamma_precision=0.9,
                                  stage_budget=4000)
    truth = ds.truth_mask()
    # stage 3 filters exhaustively -> precision is exactly 1.0
    assert queries.precision_of(res.selected, truth) == pytest.approx(1.0)
    assert queries.recall_of(res.selected, truth) >= 0.8 - 1e-9
    # Both stages ride one labeling channel: stage 3 is uncapped (it may
    # run past the stage budget) but candidates the RT stage already
    # labeled are cache hits, so total attributed calls are bounded by
    # unique records actually labeled — the old `> stage_budget` bound
    # measured stage-3 *re*-labeling RT records, which the shared cache
    # eliminates. Exhaustiveness shows in the exact precision above.
    # calls == |RT-labeled ∪ selected|, so they cover the candidate set
    n_candidates = int((ds.scores >= res.stage2_tau).sum())
    assert n_candidates <= res.oracle_calls <= 4000 + n_candidates


def test_joint_query_accepts_oracle_client():
    """Regression: stage 3 used to rewrap oracle_fn in a fresh channel,
    which crashed (and double-labeled) when given an OracleClient."""
    from repro.core.oracle import BatchingOracle

    ds = make_beta(30_000, 0.02, 1.0, seed=15)
    client = BatchingOracle(array_oracle(ds.labels))
    res = queries.run_joint_query(jax.random.PRNGKey(2), ds.scores, client,
                                  gamma_recall=0.8, gamma_precision=0.9,
                                  stage_budget=1500)
    truth = ds.truth_mask()
    assert queries.precision_of(res.selected, truth) == pytest.approx(1.0)
    # every selected record's label came through the shared channel
    assert client.cache_size >= res.selected.shape[0]


def test_key_none_accepted_by_rt_and_pt():
    """Regression: key=None used to crash _run_rt (jax.random.split(None))
    while _run_pt silently defaulted; both now normalize identically."""
    ds = make_beta(20_000, 0.02, 1.0, seed=19)
    oracle = array_oracle(ds.labels)
    for target in ("recall", "precision"):
        q = queries.SUPGQuery(target=target, gamma=0.8, delta=0.05,
                              budget=1500, method="is")
        res = queries.run_query(None, ds.scores, oracle, q)
        assert np.isfinite(res.tau) or res.tau in (float("inf"),
                                                   float("-inf"))
        # and matches the explicit default key
        res2 = queries.run_query(jax.random.PRNGKey(0), ds.scores,
                                 array_oracle(ds.labels), q)
        assert res.tau == res2.tau


def test_query_validation():
    with pytest.raises(ValueError):
        queries.SUPGQuery(target="f1", gamma=0.9)
    with pytest.raises(ValueError):
        queries.SUPGQuery(target="recall", gamma=1.5)


def test_two_stage_restricts_sampling():
    """Stage 2 oracle calls concentrate in the top-score region."""
    ds = make_beta(200_000, 0.01, 1.0, seed=17)
    q = queries.SUPGQuery(target="precision", gamma=0.9, delta=0.05,
                          budget=2000, method="is", two_stage=True)
    res = queries.run_query(jax.random.PRNGKey(2), ds.scores,
                            array_oracle(ds.labels), q)
    assert res.oracle_calls <= 2000
