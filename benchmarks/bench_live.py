"""Live-plane benchmarks: incremental ingestion vs rebuild, standing lag.

Rows:
  ingest_delta_1e6     — grow a 1e6-record corpus by ten 1e5 appends
                         through `IngestPlane` (initial build + 9 delta
                         updates); derived carries the rebuild-per-append
                         time and the speedup (acceptance floor: >= 5x)
  engine_rebuild_per_append_1e6
                       — the baseline it beats: a cold `SelectionEngine`
                         build over the growing prefix after every append
  standing_query_lag   — certified standing RT query; wall time from
                         "1e5-record shard appended" to "its {A >= tau}
                         catch-up walk is fully re-emitted"

The delta path's advantage is structural: an append sketches only the
new records and rebuilds per-(scheme, kappa) chunk-mass CDFs from cached
masses in O(n_chunks), while the rebuild path re-reads and re-sketches
the whole prefix every time (O(n^2 / chunk) total work over the run).
"""
import time

import numpy as np

import jax


def _chunks(n_chunks=10, chunk=100_000, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.beta(0.05, 1.0, chunk).astype(np.float32)
            for _ in range(n_chunks)]


def bench_ingest_delta():
    """Ten-append growth race: IngestPlane delta vs cold rebuild."""
    from repro.core.engine import SelectionEngine
    from repro.live import IngestPlane

    chunks = _chunks()
    kw = dict(num_bins=4096, use_kernel=False, chunk_records=1 << 18,
              workers=1)

    # Baseline: rebuild the engine over the growing prefix per append.
    t0 = time.time()
    for k in range(1, len(chunks) + 1):
        with SelectionEngine(chunks[:k], **kw):
            pass
    t_rebuild = time.time() - t0

    # Delta path: one initial build, then delta-update per append.
    t0 = time.time()
    with SelectionEngine(chunks[:1], **kw) as eng:
        plane = IngestPlane(eng)
        for ch in chunks[1:]:
            plane.append(ch)
        assert eng.n_total == sum(c.size for c in chunks)
    t_delta = time.time() - t0

    speedup = t_rebuild / t_delta
    print(f"ingest_delta_1e6,{t_delta * 1e6:.0f},"
          f"appends=9;chunk=1e5;total=1e6;"
          f"rebuild_us={t_rebuild * 1e6:.0f};speedup={speedup:.1f}x")
    print(f"engine_rebuild_per_append_1e6,{t_rebuild * 1e6:.0f},"
          f"builds=10;chunk=1e5;total=1e6")


def bench_standing_query_lag():
    """Append-to-reemitted wall latency for one certified standing query."""
    from repro.core.engine import SelectionEngine
    from repro.core.oracle import array_oracle
    from repro.core.queries import SUPGQuery
    from repro.live import IngestPlane, StandingRegistry

    rng = np.random.default_rng(11)
    n, shard = 500_000, 100_000
    scores = rng.beta(0.05, 1.0, n).astype(np.float32)
    extra = [rng.beta(0.05, 1.0, shard).astype(np.float32)
             for _ in range(3)]
    labels = (rng.random(n + 3 * shard)
              < np.concatenate([scores] + extra)).astype(np.float32)
    q = SUPGQuery(target="recall", gamma=0.9, budget=2000, method="is")
    with SelectionEngine(np.array_split(scores, 4), num_bins=4096,
                         use_kernel=False, workers=1) as eng:
        with eng.session(array_oracle(labels)) as sess:
            reg = StandingRegistry(IngestPlane(eng), sess)
            sq = reg.register(q, key=jax.random.PRNGKey(2))
            reg.settle()
            sq.wait_certified(timeout=0)
            lags = []
            for ch in extra:                 # warm + 2 measured appends
                t0 = time.time()
                reg.plane.append(ch)
                reg.pump()
                reg.settle()
                lags.append(time.time() - t0)
            assert sq.emissions == len(extra) and sq.reemit_failures == 0
    lag = float(np.mean(lags[1:]))
    print(f"standing_query_lag,{lag * 1e6:.0f},"
          f"shard=1e5;reemitted_per_append="
          f"{sq.records_reemitted // len(extra)}")


ALL = [bench_ingest_delta, bench_standing_query_lag]

if __name__ == "__main__":
    for f in ALL:
        f()
