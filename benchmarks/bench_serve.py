"""Serving-plane load generator: sustained qps + tail latency.

Eight closed-loop client threads (the acceptance floor) hammer one
`SelectionServer` whose oracle sleeps 1 ms per underlying invocation —
the same rate-limited-oracle timescale as the `run_many_*_lat1ms` rows.
Each client submits RT queries back-to-back (submit, wait for the
result, submit again) with distinct PRNG keys, so the server sees a
steady multi-tenant mix: admission control bounds in-flight plans, all
clients' oracle requests coalesce into the one shared channel, and the
drain thread overlaps round-trips with plan compute.

Rows:
  serve_qps        — mean wall µs per completed query across the whole
                     run (derived carries the sustained queries/s)
  serve_p99_lat    — p99 end-to-end latency (submit -> result-ready,
                     queue wait included) from the server's histogram
  serve_qps_faulty — same closed loop with ~10% of underlying oracle
                     calls raising seeded transient faults, absorbed by
                     the channel's RetryPolicy (derived carries
                     retries_per_query) — the cost of resilience
"""
import threading
import time

import numpy as np

import jax


def bench_serve_load():
    """≥8 concurrent clients, 1 ms simulated-latency oracle, closed loop."""
    import time as _time

    from repro.core.engine import SelectionEngine
    from repro.core.oracle import array_oracle
    from repro.core.queries import SUPGQuery
    from repro.serve import SelectionServer

    rng = np.random.default_rng(13)
    n = 100_000
    scores = rng.beta(0.05, 1.0, n).astype(np.float32)
    labels = (rng.random(n) < scores).astype(np.float32)
    # 10k-record engine slice (same as the lat1ms rows): keeps the jax
    # dispatch floor small so oracle round-trips dominate.
    sl = slice(0, 10_000)
    base = array_oracle(labels[sl])

    def fn(idx):
        _time.sleep(1e-3)                   # simulated oracle RPC latency
        return base(idx)

    clients, per_client = 8, 4
    q = SUPGQuery(target="recall", gamma=0.9, budget=400, method="is")
    keys = jax.random.split(jax.random.PRNGKey(1), clients * per_client)
    engine = SelectionEngine(np.array_split(scores[sl], 2), num_bins=256,
                             use_kernel=False)
    # warmup outside the server: populate jit caches (a long-lived daemon
    # is warm) without polluting the serving-latency histogram; the
    # server's own label cache still starts cold.
    engine.run(jax.random.PRNGKey(0), fn, q)
    errors = []
    with SelectionServer(engine, fn, max_inflight=clients,
                         max_batch=256) as server:

        def client(cid):
            try:
                for i in range(per_client):
                    k = keys[cid * per_client + i]
                    server.submit(q, tenant=f"client{cid}",
                                  key=k).result(timeout=120)
            except Exception as e:  # noqa: BLE001 — surface, don't hang
                errors.append(e)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        stats = server.stats()

    if errors:
        raise errors[0]
    total = clients * per_client
    assert stats.completed == total and stats.failed == 0
    qps = total / wall
    print(f"serve_qps,{wall * 1e6 / total:.0f},clients={clients};"
          f"queries={total};qps={qps:.1f};"
          f"oracle_calls={stats.oracle_calls};"
          f"cache_hits={stats.cache_hits};"
          f"hidden_ms={stats.overlap_hidden_s * 1e3:.1f}")
    print(f"serve_p99_lat,{stats.p99_s * 1e6:.0f},"
          f"p50_us={stats.p50_s * 1e6:.0f};"
          f"mean_us={stats.mean_s * 1e6:.0f};clients={clients}")


def bench_serve_faults():
    """The faulty-load row: 8 clients, 1 ms oracle, ~10% of underlying
    calls raising seeded transient faults; retries must absorb every
    fault (zero failed queries) and the row prices the overhead."""
    import time as _time

    from repro.core.engine import SelectionEngine
    from repro.core.oracle import array_oracle
    from repro.core.queries import SUPGQuery
    from repro.core.resilience import RetryPolicy
    from repro.serve import SelectionServer
    from repro.testing import FaultInjector, fault_schedule

    rng = np.random.default_rng(13)
    n = 100_000
    scores = rng.beta(0.05, 1.0, n).astype(np.float32)
    labels = (rng.random(n) < scores).astype(np.float32)
    sl = slice(0, 10_000)
    base = array_oracle(labels[sl])

    def fn(idx):
        _time.sleep(1e-3)                   # simulated oracle RPC latency
        return base(idx)

    inj = FaultInjector(fn, fault_schedule(seed=29, n_calls=100_000,
                                           rate=0.10))
    clients, per_client = 8, 4
    q = SUPGQuery(target="recall", gamma=0.9, budget=400, method="is")
    keys = jax.random.split(jax.random.PRNGKey(1), clients * per_client)
    engine = SelectionEngine(np.array_split(scores[sl], 2), num_bins=256,
                             use_kernel=False)
    engine.run(jax.random.PRNGKey(0), fn, q)     # warm jit caches
    errors = []
    # Tiny real backoff: the row prices retry overhead under load, not
    # sleep time (the injected faults are instantaneous to re-ask).
    policy = RetryPolicy(max_attempts=6, base_delay_s=1e-4,
                         max_delay_s=1e-3)
    with SelectionServer(engine, inj, max_inflight=clients,
                         max_batch=256, retry=policy) as server:

        def client(cid):
            try:
                for i in range(per_client):
                    k = keys[cid * per_client + i]
                    server.submit(q, tenant=f"client{cid}",
                                  key=k).result(timeout=120)
            except Exception as e:  # noqa: BLE001 — surface, don't hang
                errors.append(e)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        stats = server.stats()

    if errors:
        raise errors[0]
    total = clients * per_client
    assert stats.completed == total and stats.failed == 0
    assert stats.batch_failures == 0        # every fault was absorbed
    print(f"serve_qps_faulty,{wall * 1e6 / total:.0f},clients={clients};"
          f"queries={total};qps={total / wall:.1f};"
          f"retries={stats.retries};"
          f"retries_per_query={stats.retries / total:.2f};"
          f"injected={inj.injected['transient']};"
          f"oracle_calls={stats.oracle_calls}")


ALL = [bench_serve_load, bench_serve_faults]

if __name__ == "__main__":
    for f in ALL:
        f()
