"""Benchmark suite entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Sections:
  paper_figures  — Figs 1/5/6/7/8/9/10/12 + Table 4 reproduction numbers
  bench_kernels  — per-kernel allclose + reference timings
  roofline       — per-(arch x shape) roofline terms from results/dryrun.json
                   (skipped silently if the dry-run artifact is absent)

``--json PATH`` additionally writes every captured row to a
machine-readable trajectory file (CI uploads it as the BENCH_PR10.json
artifact per commit; ``--fast --json`` is the quick tier CI runs, covering
engine cold-build at 1/4/8 workers, draw_sample throughput, the run_many
batch, threshold_select throughput at 1e6/1e7 records, the live-plane
rows — incremental ingestion vs rebuild-per-append and standing-query
lag — and the durability rows: fsync'd journal-append overhead and
journal-replay recovery of a 1e6-record corpus).
``--baseline PATH`` diffs the captured rows against a committed trajectory
file (the repo carries ``BENCH_PR10.json``) and prints a per-row delta
table, so every CI run shows its drift from the checked-in baseline.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import platform
import re
import sys
import time
import traceback

_ROW_RE = re.compile(r"^([A-Za-z0-9_.-]+),([-+0-9.eE]+)(?:,(.*))?$")


def _parse_rows(text: str):
    """Parse ``name,us_per_call[,derived]`` CSV rows out of bench output."""
    rows = []
    for line in text.splitlines():
        m = _ROW_RE.match(line.strip())
        if m:
            rows.append({"name": m.group(1),
                         "us_per_call": float(m.group(2)),
                         "derived": m.group(3) or ""})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--fast", action="store_true",
                    help="skip the slow statistical sweeps")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write captured rows as a machine-readable "
                         "trajectory file (e.g. BENCH_PR8.json)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed trajectory file to diff against; "
                         "prints a per-row delta table after the run")
    args = ap.parse_args()

    baseline_rows = {}
    if args.baseline:
        # Read up front: --json may legitimately overwrite the same path.
        try:
            with open(args.baseline) as f:
                baseline_rows = {r["name"]: r
                                 for r in json.load(f).get("rows", [])}
        except (OSError, ValueError, KeyError) as e:
            print(f"baseline {args.baseline} unreadable ({e}); "
                  "skipping delta table", file=sys.stderr)

    from benchmarks import (bench_durable, bench_kernels, bench_live,
                            bench_serve, paper_figures)

    benches = []
    if not args.fast:
        benches += [(f.__name__, f) for f in paper_figures.ALL]
    else:
        benches += [("bench_failure_precision",
                     paper_figures.bench_failure_precision),
                    ("bench_recall_target",
                     paper_figures.bench_recall_target)]
    benches += [(f.__name__, f) for f in bench_kernels.ALL]
    benches += [(f.__name__, f) for f in bench_serve.ALL]
    benches += [(f.__name__, f) for f in bench_live.ALL]
    benches += [(f.__name__, f) for f in bench_durable.ALL]

    failed = []
    rows = []
    t_start = time.time()
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        buf = io.StringIO()
        try:
            with contextlib.redirect_stdout(buf):
                fn()
        except Exception:  # noqa: BLE001
            sys.stdout.write(buf.getvalue())
            traceback.print_exc()
            failed.append(name)
            continue
        out = buf.getvalue()
        sys.stdout.write(out)
        rows += _parse_rows(out)

    try:
        from benchmarks import roofline
        import pathlib
        if pathlib.Path("results/dryrun.json").exists():
            roofline.main()
    except Exception:  # noqa: BLE001
        traceback.print_exc()
        failed.append("roofline")

    if baseline_rows:
        width = max((len(r["name"]) for r in rows), default=4) + 2
        print(f"\n== delta vs {args.baseline} (negative = faster) ==")
        print(f"{'name':<{width}}{'base_us':>12}{'now_us':>12}{'delta':>9}")
        for r in rows:
            base = baseline_rows.get(r["name"])
            if base is None or base["us_per_call"] <= 0:
                print(f"{r['name']:<{width}}{'(new)':>12}"
                      f"{r['us_per_call']:>12.0f}{'':>9}")
                continue
            delta = (r["us_per_call"] / base["us_per_call"] - 1.0) * 100.0
            print(f"{r['name']:<{width}}{base['us_per_call']:>12.0f}"
                  f"{r['us_per_call']:>12.0f}{delta:>+8.1f}%")
        gone = sorted(set(baseline_rows) - {r["name"] for r in rows})
        if gone:
            print(f"rows missing vs baseline: {gone}")

    if args.json:
        import jax
        payload = {
            "schema_version": 1,
            "suite": "fast" if args.fast else "full",
            "wall_seconds": round(time.time() - t_start, 3),
            "backend": jax.default_backend(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "failed": failed,
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {len(rows)} rows -> {args.json}")

    if failed:
        print(f"FAILED benchmarks: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
