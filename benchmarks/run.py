"""Benchmark suite entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Sections:
  paper_figures  — Figs 1/5/6/7/8/9/10/12 + Table 4 reproduction numbers
  bench_kernels  — per-kernel allclose + reference timings
  roofline       — per-(arch x shape) roofline terms from results/dryrun.json
                   (skipped silently if the dry-run artifact is absent)
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--fast", action="store_true",
                    help="skip the slow statistical sweeps")
    args = ap.parse_args()

    from benchmarks import bench_kernels, paper_figures

    benches = []
    if not args.fast:
        benches += [(f.__name__, f) for f in paper_figures.ALL]
    else:
        benches += [("bench_failure_precision",
                     paper_figures.bench_failure_precision),
                    ("bench_recall_target",
                     paper_figures.bench_recall_target)]
    benches += [(f.__name__, f) for f in bench_kernels.ALL]

    failed = []
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        try:
            fn()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)

    try:
        from benchmarks import roofline
        import pathlib
        if pathlib.Path("results/dryrun.json").exists():
            roofline.main()
    except Exception:  # noqa: BLE001
        traceback.print_exc()
        failed.append("roofline")

    if failed:
        print(f"FAILED benchmarks: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
