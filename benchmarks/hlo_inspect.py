"""HLO inspection tool for §Perf iterations: top collectives + big buffers.

    PYTHONPATH=src python -m benchmarks.hlo_inspect --arch deepseek-v2-236b \
        --shape train_4k [--units 1] [--top 20]

Compiles the loop-free 1-unit cost probe on the single-pod mesh and prints
the largest collective ops (kind, shape, bytes, replica-group size) — the
dry-run profiler's equivalent of reading a TPU trace.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse

from repro.configs import SHAPES_BY_NAME, get_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--units", type=int, default=1)
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    import dataclasses
    from repro.launch import dryrun
    from repro.launch.mesh import make_production_mesh

    from repro.models.meshctx import mesh_context
    mesh = make_production_mesh()
    cfg = dataclasses.replace(get_config(args.arch), shard_activations=True)
    rcfg = dryrun.reduced_config(cfg, args.units)
    shape = SHAPES_BY_NAME[args.shape]
    with mesh_context(mesh):
        lo = dryrun.lower_cell(rcfg, shape, mesh, donate=False, grad_accum=1)
        comp = lo.compile()
    txt = comp.as_text()

    rows = []
    for line in txt.splitlines():
        m = dryrun._COLLECTIVE_RE.search(line)
        if not m:
            continue
        blob, kind = m.group(1), m.group(2)
        nbytes = 0
        shapes = dryrun._SHAPE_RE.findall(blob)
        for dt, dims in shapes:
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * dryrun._DTYPE_BYTES[dt]
        g = dryrun._GROUP_RE.search(line)
        gsize = int(g.group(2)) if g else 0
        rows.append((nbytes, kind, gsize, blob[:80]))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"{len(rows)} collectives, {total/1e9:.2f} GB result bytes "
          f"(per device, {args.units} unit(s))")
    for nbytes, kind, gsize, blob in rows[:args.top]:
        print(f"  {nbytes/1e6:10.1f} MB  {kind:20s} g{gsize:<4d} {blob}")


if __name__ == "__main__":
    main()
