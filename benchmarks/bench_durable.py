"""Durability-plane benchmarks: journal overhead, cold-recovery speed.

Rows:
  journal_append_overhead — a 1e5-record append through `IngestPlane`
                            with the full durability path (spool shards
                            as CRC'd .npy files + fsync'd journal frame)
                            vs the same append unjournaled; derived
                            carries the paired ratio (acceptance
                            ceiling: <= 1.3x — the fsync must not
                            dominate the delta-sketch work it protects)
  recover_1e6             — replay a ten-record journal (1e6 records
                            total) into a fresh engine via
                            `DurabilityPlane.replay_into`; derived
                            carries the per-epoch replay time and the
                            cold-rebuild time it substitutes for

Journal overhead is paired on purpose: both sides run the identical
delta-append, same process, interleaved, so the ratio isolates the
spool + fsync cost rather than cache warmth.
"""
import os
import tempfile
import time

import numpy as np


def _chunks(n_chunks=10, chunk=100_000, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.beta(0.05, 1.0, chunk).astype(np.float32)
            for _ in range(n_chunks)]


def bench_journal_overhead():
    """Paired journaled vs unjournaled append cost (ratio must stay small)."""
    from repro.core.engine import SelectionEngine
    from repro.durable import DurabilityPlane
    from repro.live import IngestPlane

    chunks = _chunks()
    kw = dict(num_bins=4096, use_kernel=False, chunk_records=1 << 18,
              workers=1)
    t_plain, t_journaled = 0.0, 0.0
    with tempfile.TemporaryDirectory() as root:
        dur = DurabilityPlane(os.path.join(root, "dur"))
        with SelectionEngine(chunks[:1], **kw) as plain_eng, \
                SelectionEngine(chunks[:1], **kw) as dur_eng:
            plain, durable = IngestPlane(plain_eng), IngestPlane(dur_eng)
            for ch in chunks[1:]:           # interleaved pairs
                t0 = time.time()
                plain.append(ch)
                t_plain += time.time() - t0
                t0 = time.time()
                arrs = dur.record_append(ch, epoch=durable.epoch + 1)
                durable.append(arrs)
                t_journaled += time.time() - t0
            assert dur_eng.n_total == plain_eng.n_total
        dur.close()
    n = len(chunks) - 1
    ratio = t_journaled / t_plain
    print(f"journal_append_overhead,{t_journaled / n * 1e6:.0f},"
          f"appends={n};chunk=1e5;"
          f"unjournaled_us={t_plain / n * 1e6:.0f};ratio={ratio:.2f}x")


def bench_recover():
    """Cold recovery: journal replay of 1e6 records into a fresh engine."""
    from repro.core.engine import SelectionEngine
    from repro.durable import DurabilityPlane
    from repro.live import IngestPlane

    chunks = _chunks()
    kw = dict(num_bins=4096, use_kernel=False, chunk_records=1 << 18,
              workers=1)
    with tempfile.TemporaryDirectory() as root:
        dur = DurabilityPlane(os.path.join(root, "dur"))
        with SelectionEngine(chunks[:1], **kw) as eng:
            plane = IngestPlane(eng)
            for ch in chunks[1:]:
                plane.append(dur.record_append(ch, epoch=plane.epoch + 1))

        t0 = time.time()
        with SelectionEngine(chunks[:1], **kw) as eng:
            plane = IngestPlane(eng)
            replayed = dur.replay_into(plane)
            assert replayed == len(chunks) - 1
            assert eng.n_total == sum(c.size for c in chunks)
        t_recover = time.time() - t0
        dur.close()

    t0 = time.time()
    with SelectionEngine(chunks, **kw):     # what recovery substitutes for
        pass
    t_cold = time.time() - t0
    print(f"recover_1e6,{t_recover * 1e6:.0f},"
          f"epochs={replayed};total=1e6;"
          f"per_epoch_us={t_recover / replayed * 1e6:.0f};"
          f"cold_build_us={t_cold * 1e6:.0f}")


ALL = [bench_journal_overhead, bench_recover]

if __name__ == "__main__":
    for f in ALL:
        f()
