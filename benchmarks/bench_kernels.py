"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference timings and
allclose verification. On CPU the interpret-mode timing is NOT a TPU perf
signal (the kernels are emulated); the value here is (a) correctness at
bench shapes, (b) the jnp-reference baseline the roofline compares against.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.linear_scan import ops as ls_ops
from repro.kernels.score_hist import ops as sh_ops


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)[0].block_until_ready() if isinstance(
        fn(*args, **kw), tuple) else fn(*args, **kw).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
        leaf = out[0] if isinstance(out, tuple) else out
        leaf.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def bench_flash_attention():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, h, kv, dh = 1, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    t_ref = _time(fa_ops.flash_attention, q, k, v, backend="ref")
    o_k = fa_ops.flash_attention(q, k, v, block_q=128, block_k=128)
    o_r = fa_ops.flash_attention(q, k, v, backend="ref")
    err = float(jnp.max(jnp.abs(o_k - o_r)))
    print(f"kernel_flash_attention,{t_ref:.0f},maxerr={err:.2e}")


def bench_linear_scan():
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    b, h, s, dk, dv = 1, 8, 1024, 64, 64
    q = jax.random.normal(ks[0], (b, h, s, dk)) * 0.5
    k = jax.random.normal(ks[1], (b, h, s, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, h, s, dv)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, s, dk)) + 3.0)
    t_ref = _time(ls_ops.linear_scan, q, k, v, w, backend="ref")
    o_k, _ = ls_ops.linear_scan(q, k, v, w, chunk=64)
    o_r, _ = ls_ops.linear_scan(q, k, v, w, backend="ref")
    err = float(jnp.max(jnp.abs(o_k - o_r)))
    print(f"kernel_linear_scan,{t_ref:.0f},maxerr={err:.2e}")


def bench_engine_selection():
    """SelectionEngine data-plane micro-benchmarks at 1e6 scores.

    (a) vectorized searchsorted score_at vs the seed's per-element Python
        gather loop;
    (b) engine cold-build (sketch + cached sampling state) — the
        trajectory row CI tracks per commit;
    (c) run_many over 8 RT queries on one cached engine vs 8 independent
        cold runs (fresh engine per query = per-query sketch build + O(n)
        weight recomputation — the seed's amortization behavior).
    """
    import numpy as _np

    from repro.core.engine import SelectionEngine
    from repro.core.oracle import array_oracle
    from repro.core.queries import SUPGQuery

    rng = _np.random.default_rng(0)
    n = 1_000_000
    scores = rng.beta(0.05, 1.0, n).astype(_np.float32)
    labels = (rng.random(n) < scores).astype(_np.float32)
    shards = _np.array_split(scores, 8)
    engine = SelectionEngine(shards, num_bins=4096, use_kernel=False)

    # (a) score_at vs the seed's per-element loop — both vectorized paths:
    # the flat-cache gather (in-RAM default) and the searchsorted-routed
    # per-shard gather (what memmap/out-of-core shards use).
    routed = SelectionEngine(shards, num_bins=4096, use_kernel=False,
                             cache_flat=False)
    gi = rng.integers(0, n, 100_000)

    def _seed_loop(gidx):
        sh = _np.searchsorted(engine.offsets, gidx, side="right") - 1
        out = _np.empty(gidx.shape[0], _np.float32)
        for i, (s_, g) in enumerate(zip(sh, gidx)):
            out[i] = engine.shards[s_][g - engine.offsets[s_]]
        return out

    t0 = time.perf_counter()
    out_vec = engine.score_at(gi)
    t_flat = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_routed = routed.score_at(gi)
    t_routed = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_loop = _seed_loop(gi)
    t_loop = time.perf_counter() - t0
    _np.testing.assert_array_equal(out_vec, out_loop)
    _np.testing.assert_array_equal(out_routed, out_loop)
    print(f"engine_score_at,{t_flat * 1e6:.0f},"
          f"routed_us={t_routed * 1e6:.0f};loop_us={t_loop * 1e6:.0f};"
          f"speedup_flat={t_loop / t_flat:.1f}x;"
          f"speedup_routed={t_loop / t_routed:.1f}x")

    # (b) engine cold-build (sketch + cached sampling state, no queries) —
    # the trajectory row CI tracks per commit.
    t0 = time.perf_counter()
    SelectionEngine(shards, num_bins=4096, use_kernel=False)
    t_build = time.perf_counter() - t0
    print(f"engine_cold_build,{t_build * 1e6:.0f},n=1e6;shards=8")

    # (c) run_many batch vs independent cold runs
    oracle = array_oracle(labels)
    qs = [SUPGQuery(target="recall", gamma=0.9, delta=0.05, budget=1000,
                    method="is") for _ in range(8)]
    engine.run(jax.random.PRNGKey(0), oracle, qs[0])   # jit warmup

    t0 = time.perf_counter()
    batch_engine = SelectionEngine(shards, num_bins=4096, use_kernel=False)
    batch_engine.run_many(jax.random.PRNGKey(1), oracle, qs)
    t_batch = time.perf_counter() - t0
    t0 = time.perf_counter()
    for i, q in enumerate(qs):
        cold = SelectionEngine(shards, num_bins=4096, use_kernel=False)
        cold.run(jax.random.PRNGKey(100 + i), oracle, q)
    t_cold = time.perf_counter() - t0
    print(f"engine_run_many8,{t_batch * 1e6:.0f},"
          f"independent_us={t_cold * 1e6:.0f};"
          f"speedup={t_cold / t_batch:.1f}x")


def bench_engine_build_workers():
    """Engine cold-build wall time vs worker-pool size at 1e6 / 1e7.

    Construction is one ChunkPlan-driven pass (fused sketch + sampling
    chunk masses per span) through `pipeline.parallel_map`; workers=1
    bypasses the pool entirely (the single-threaded baseline), workers>=4
    should show the multi-core speedup on machines with the cores to back
    it (CI trajectory row)."""
    from repro.core.engine import SelectionEngine

    rng = np.random.default_rng(5)
    for n, label in ((1_000_000, "1e6"), (10_000_000, "1e7")):
        scores = rng.beta(0.05, 1.0, n).astype(np.float32)
        shards = np.array_split(scores, 8)
        for w in (1, 4, 8):
            t0 = time.perf_counter()
            SelectionEngine(shards, num_bins=4096, use_kernel=False,
                            chunk_records=1 << 18, workers=w)
            t_us = (time.perf_counter() - t0) * 1e6
            print(f"engine_cold_build_{label}_w{w},{t_us:.0f},"
                  f"n={label};workers={w};shards=8;"
                  f"recs_per_s={n / (t_us / 1e6):.3e}")


def bench_engine_emission_workers():
    """Streamed selection emission throughput vs worker-pool size at 1e7:
    the ChunkPlan spans run threshold_select concurrently and the sink
    serializes only its consume step, so emission scales with cores while
    staying bit-for-bit identical to the serial walk. Uses the production
    chunk size (4M records/span): spans small enough to sit in cache make
    the serial walk artificially fast and the pool pure overhead."""
    from repro.core.engine import SelectionEngine
    from repro.data.pipeline import IndexSink

    rng = np.random.default_rng(7)
    n = 10_000_000
    scores = rng.beta(0.05, 1.0, n).astype(np.float32)
    shards = np.array_split(scores, 8)
    pos = np.empty(0, np.int64)
    base = None
    for w in (1, 4, 8):
        engine = SelectionEngine(shards, num_bins=4096, use_kernel=False,
                                 workers=w)
        engine._emit_selection(0.8, pos, 0, IndexSink(), None)   # warmup
        # min over reps: the walk is a ~10 ms memory-bound pass, so the
        # minimum is the stable estimator under scheduler noise.
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            sel = engine._emit_selection(0.8, pos, 0, IndexSink(), None)
            times.append(time.perf_counter() - t0)
        t_us = min(times) * 1e6
        base = t_us if base is None else base
        print(f"engine_emission_1e7_w{w},{t_us:.0f},workers={w};"
              f"selected={sel.total_selected};"
              f"recs_per_s={n / (t_us / 1e6):.3e};"
              f"vs_w1={base / t_us:.2f}x")


def bench_run_many_session():
    """run_many batch execution: sequential (concurrency=1) vs the
    QuerySession scheduler (concurrency=8) over one cached engine, with
    the oracle-coalescing metric the session exists for: underlying
    oracle invocations (batched fn calls) per query. Outputs are
    bit-for-bit identical between the two paths; the session divides the
    oracle's call count by funneling all in-flight plans' requests
    through one BatchingOracle drain per round."""
    from repro.core.engine import SelectionEngine
    from repro.core.oracle import array_oracle
    from repro.core.queries import SUPGQuery

    rng = np.random.default_rng(11)
    n = 1_000_000
    scores = rng.beta(0.05, 1.0, n).astype(np.float32)
    labels = (rng.random(n) < scores).astype(np.float32)
    engine = SelectionEngine(np.array_split(scores, 8), num_bins=4096,
                             use_kernel=False)
    qs = [SUPGQuery(target="recall", gamma=0.9, delta=0.05, budget=1000,
                    method="is") for _ in range(8)]
    base = array_oracle(labels)

    def timed(concurrency):
        calls = [0]

        def fn(idx):
            calls[0] += 1
            return base(idx)

        engine.run_many(jax.random.PRNGKey(1), fn, qs,
                        concurrency=concurrency)       # warmup
        calls[0] = 0
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            engine.run_many(jax.random.PRNGKey(1), fn, qs,
                            concurrency=concurrency)
            times.append(time.perf_counter() - t0)
        return min(times) * 1e6, calls[0] / 3 / len(qs)

    t_seq, bpq_seq = timed(1)
    t_sess, bpq_sess = timed(8)
    print(f"run_many_8q_seq,{t_seq:.0f},concurrency=1;"
          f"oracle_batches_per_query={bpq_seq:.3f}")
    print(f"run_many_8q_session,{t_sess:.0f},concurrency=8;"
          f"oracle_batches_per_query={bpq_sess:.3f};"
          f"vs_seq={t_seq / t_sess:.2f}x")
    print(f"oracle_batches_per_query,{bpq_sess:.3f},"
          f"seq={bpq_seq:.3f};coalescing={bpq_seq / bpq_sess:.1f}x")


def bench_run_many_session_latency():
    """The session's reason to exist, measured at RPC timescales: every
    underlying oracle invocation sleeps 1 ms (the paper's rate-limited
    oracle model), with `max_batch=256` bounding records per round-trip.
    Eight JT queries -- the most oracle-hungry type: an RT stage plus
    exhaustive candidate verification -- run (a) sequentially, each with
    its own private labeling channel (per-query execution without a
    session; note run_many(concurrency=1) already shares the cache, so
    the private-channel loop is the honest no-session baseline), and
    (b) through one QuerySession. The shared label cache answers the
    overlapping RT samples and the near-identical verification candidate
    sets once, so the session needs a fraction of the round-trips; the
    vs_seq speedup is the wall-clock value of that coalescing
    (acceptance: >= 2x)."""
    import time as _time

    from repro.core.engine import SelectionEngine
    from repro.core.oracle import BatchingOracle, array_oracle
    from repro.core.queries import JointSUPGQuery

    rng = np.random.default_rng(13)
    n = 100_000
    scores = rng.beta(0.05, 1.0, n).astype(np.float32)
    labels = (rng.random(n) < scores).astype(np.float32)
    # 10k-record engine slice: keeps the jax dispatch floor small enough
    # that oracle round-trips, not plan compute, dominate both paths.
    sl = slice(0, 10_000)
    engine = SelectionEngine(np.array_split(scores[sl], 2), num_bins=256,
                             use_kernel=False)
    base = array_oracle(labels[sl])
    qs = [JointSUPGQuery(gamma_recall=0.9, stage_budget=1000)
          for _ in range(8)]
    keys = jax.random.split(jax.random.PRNGKey(1), len(qs))
    mb = 256

    def instrumented():
        calls, recs = [0], [0]

        def fn(idx):
            calls[0] += 1
            recs[0] += len(idx)
            _time.sleep(1e-3)               # simulated oracle RPC latency
            return base(idx)

        return fn, calls, recs

    def timed(once, calls, recs):
        once()                              # warmup
        calls[0] = recs[0] = 0
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            once()
            times.append(time.perf_counter() - t0)
        return min(times) * 1e6, calls[0] / 3, recs[0] / 3

    fn, calls, recs = instrumented()

    def seq_once():
        for k, q in zip(keys, qs):
            engine.run_joint(k, BatchingOracle(fn, max_batch=mb), q)

    t_seq, tr_seq, rc_seq = timed(seq_once, calls, recs)

    fn2, calls2, recs2 = instrumented()

    def sess_once():
        with engine.session(fn2, max_batch=mb) as s:
            handles = [s.submit(q, key=k) for q, k in zip(qs, keys)]
            for h in handles:
                h.result()

    t_sess, tr_sess, rc_sess = timed(sess_once, calls2, recs2)
    print(f"run_many_8q_seq_lat1ms,{t_seq:.0f},latency_ms=1;"
          f"private_channels=8;trips={tr_seq:.1f};"
          f"records_labeled={rc_seq:.0f}")
    print(f"run_many_8q_session_lat1ms,{t_sess:.0f},latency_ms=1;"
          f"shared_session=1;trips={tr_sess:.1f};"
          f"records_labeled={rc_sess:.0f};"
          f"vs_seq={t_seq / t_sess:.2f}x")


def bench_draw_sample():
    """Hierarchical draw_sample throughput off the cached chunk-level
    state: 1e6 records in 8 shards split into ~64 chunks, 1e4 draws per
    call — the per-query sampling hot path (chunk categorical + streamed
    within-chunk inverse-CDF)."""
    from repro.core.engine import SelectionEngine

    rng = np.random.default_rng(6)
    scores = rng.beta(0.05, 1.0, 1_000_000).astype(np.float32)
    engine = SelectionEngine(np.array_split(scores, 8), num_bins=4096,
                             use_kernel=False, chunk_records=1 << 17)
    s = 10_000
    engine.draw_sample(jax.random.PRNGKey(0), s, "sqrt")      # warmup
    reps = 5
    t0 = time.perf_counter()
    for r in range(reps):
        engine.draw_sample(jax.random.PRNGKey(r), s, "sqrt")
    t_us = (time.perf_counter() - t0) / reps * 1e6
    print(f"engine_draw_sample,{t_us:.0f},s={s};scheme=sqrt;"
          f"draws_per_s={s / (t_us / 1e6):.3e}")


def bench_threshold_select():
    """Streaming-emission pass throughput at 1e6 / 1e7 records.

    Times the platform-default backend the engine streams through (numpy
    nonzero reference on CPU, compiled Pallas on TPU) and cross-checks the
    interpret-mode kernel against the reference at 1e6.
    """
    from repro.kernels.threshold_select import ops as ts_ops

    rng = np.random.default_rng(3)
    tau = 0.8
    for n, label in ((1_000_000, "1e6"), (10_000_000, "1e7")):
        s = rng.beta(0.05, 1.0, n).astype(np.float32)
        backend = ts_ops.default_backend()
        ts_ops.threshold_select(s, tau, backend=backend)   # warmup
        reps = 3
        t0 = time.perf_counter()
        for _ in range(reps):
            out = ts_ops.threshold_select(s, tau, backend=backend)
        t_us = (time.perf_counter() - t0) / reps * 1e6
        recs_per_s = n / (t_us / 1e6)
        extra = ""
        if n == 1_000_000:
            kern = ts_ops.threshold_select(s, tau, backend="interpret")
            extra = (";kernel_match="
                     f"{int(np.array_equal(kern, out))}")
        print(f"kernel_threshold_select_{label},{t_us:.0f},"
              f"backend={backend};selected={out.size};"
              f"recs_per_s={recs_per_s:.3e}{extra}")


def bench_score_hist():
    s = jax.random.beta(jax.random.PRNGKey(2), 0.05, 1.0, (1 << 20,))
    t_ref = _time(sh_ops.score_hist, s, 4096, backend="ref")
    ck, wk, ak = sh_ops.score_hist(s, 4096, block_n=4096)
    cr, wr, ar = sh_ops.score_hist(s, 4096, backend="ref")
    err = float(jnp.max(jnp.abs(ck - cr)))
    # derived: single-pass HBM time at v5e bandwidth for 1e9 records
    t_v5e_ms = 4e9 / 819e9 * 1e3
    print(f"kernel_score_hist,{t_ref:.0f},maxerr={err:.0f};"
          f"v5e_1e9rec_est={t_v5e_ms:.1f}ms")


ALL = [bench_flash_attention, bench_linear_scan, bench_score_hist,
       bench_threshold_select, bench_engine_selection,
       bench_engine_build_workers, bench_engine_emission_workers,
       bench_draw_sample, bench_run_many_session,
       bench_run_many_session_latency]
