"""Roofline analysis from the dry-run artifacts (results/dryrun.json).

Per (arch x shape) on the single-pod mesh:
    compute   = HLO_FLOPs / (chips * 197e12)        [bf16 peak / chip]
    memory    = HLO_bytes / (chips * 819e9)         [HBM bw / chip]
    collective= wire_bytes / (chips * 50e9)         [ICI per link]

HLO_FLOPs / bytes are per-device numbers reconstructed from unrolled
1-unit / 2-unit compiles (XLA's cost model does not multiply while-loop
trip counts) and already reflect the sharding. Collective wire bytes per
chip from the HLO result sizes:
    all-reduce ~ 2x result bytes (ring reduce-scatter + all-gather),
    all-gather / reduce-scatter / all-to-all ~ 1x, permute ~ 1x.
MODEL_FLOPS = 6 * N_active * tokens (train; 3x less for inference) +
attention term — the "useful" fraction of compiled compute.
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import SHAPES_BY_NAME, get_config
from repro.models.model import count_params_analytic

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256

_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def model_flops(cfg, shape):
    tokens = shape.global_batch * shape.seq_len
    n_active = count_params_analytic(cfg, active_only=True)
    mult = 6.0 if shape.kind == "train" else 2.0
    if shape.kind == "decode":
        tokens = shape.global_batch            # one token per request
    flops = mult * n_active * tokens
    if cfg.num_heads and cfg.block == "attn" and shape.kind != "decode":
        hd = cfg.head_dim if not cfg.use_mla else (
            cfg.qk_nope_head_dim + cfg.qk_rope_head_dim + cfg.v_head_dim)
        att = 2.0 * shape.global_batch * shape.seq_len ** 2 \
            * cfg.num_heads * hd / 2.0 * cfg.num_layers   # causal half
        flops += att * (3.0 if shape.kind == "train" else 1.0)
    return flops


def collective_wire_bytes(colls):
    total = 0.0
    by_group = {}
    for key, ent in colls.items():
        kind, grp = key.split("/")
        factor = _WIRE_FACTOR.get(kind, 1.0)
        b = max(ent["bytes"], 0) * factor
        total += b
        by_group[grp] = by_group.get(grp, 0.0) + b
    return total, by_group


def analyze(record):
    arch, shape_name = record["arch"], record["shape"]
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    flops_dev = record.get("hlo_flops_per_device", 0.0)
    bytes_dev = record.get("hlo_bytes_per_device", 0.0)
    coll_bytes, by_group = collective_wire_bytes(
        record.get("collectives", {}))

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = flops_dev * CHIPS
    useful = mf / hlo_global if hlo_global else 0.0
    bound = max(terms.values())
    # roofline fraction: useful model flops per second at the bound vs peak
    ach_flops = mf / CHIPS / bound if bound else 0.0
    return {
        "arch": arch, "shape": shape_name,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_fraction": useful,
        "roofline_fraction": ach_flops / PEAK_FLOPS,
        "coll_by_group": by_group,
        "memory_gb": (record.get("memory", {})
                      .get("temp_size_in_bytes", 0)) / 1e9,
    }


def main(path="results/dryrun.json"):
    recs = json.loads(pathlib.Path(path).read_text())
    rows = []
    for r in recs:
        if not r.get("ok") or r.get("skipped") or \
                not r["mesh"].startswith("single") or \
                "hlo_flops_per_device" not in r:
            continue
        a = analyze(r)
        rows.append(a)
        print(f"roofline_{a['arch']}_{a['shape']},0,"
              f"dom={a['dominant']};comp={a['t_compute_s']:.4f}s;"
              f"mem={a['t_memory_s']:.4f}s;coll={a['t_collective_s']:.4f}s;"
              f"useful={a['useful_fraction']:.2f};"
              f"roofline={a['roofline_fraction']:.3f}")
    out = pathlib.Path("results/roofline.json")
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    main()
