"""Shared benchmark driver utilities."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import SUPGQuery, array_oracle, precision_of, recall_of, \
    run_query


def run_trials(ds, target, method, gamma, budget, trials, delta=0.05,
               seed0=0, weight_scheme="sqrt", two_stage=True):
    """Repeated SUPG queries; returns dict of achieved/quality/failure."""
    achieved, quality = [], []
    t0 = time.time()
    for t in range(trials):
        q = SUPGQuery(target=target, gamma=gamma, delta=delta, budget=budget,
                      method=method, weight_scheme=weight_scheme,
                      two_stage=two_stage)
        res = run_query(jax.random.PRNGKey(seed0 + t), ds.scores,
                        array_oracle(ds.labels), q)
        p = precision_of(res.selected, ds.truth_mask())
        r = recall_of(res.selected, ds.truth_mask())
        a, ql = (r, p) if target == "recall" else (p, r)
        achieved.append(a)
        quality.append(ql)
    achieved, quality = np.asarray(achieved), np.asarray(quality)
    return {
        "failure_rate": float((achieved < gamma).mean()),
        "achieved_p50": float(np.median(achieved)),
        "achieved_min": float(achieved.min()),
        "quality_p50": float(np.median(quality)),
        "us_per_call": (time.time() - t0) / trials * 1e6,
    }


def emit(name, result, derived=""):
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{result.get('us_per_call', 0):.0f},{derived}")
