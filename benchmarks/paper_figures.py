"""One benchmark per paper figure/table (Section 6 + Appendix A).

Each bench_* function reproduces the experimental condition of the
corresponding artifact on the paper's synthetic Beta datasets (the real
video/ImageNet datasets are not redistributable; Table 2's Beta rows are
generated exactly as specified, and the noise/imbalance/drift protocols
follow Sections 6.2-6.4 verbatim). Scale knobs (N, TRIALS) are chosen so
the full suite runs on one CPU in minutes; they match the paper's regime
of budget/N ~ 1%.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, run_trials
from repro.data.synthetic import make_beta

N = 500_000
TRIALS = 25
BUDGET = 10_000


def bench_failure_precision():
    """Figures 1 & 5: U-NoCI fails the precision target; SUPG does not."""
    ds = make_beta(N, 0.01, 1.0, seed=0)
    rows = []
    for method in ("noci", "is"):
        r = run_trials(ds, "precision", method, 0.9, BUDGET, TRIALS)
        rows.append((method, r))
        emit(f"fig5_precision_{method}", r,
             f"fail={r['failure_rate']:.2f};min={r['achieved_min']:.2f}")
    return rows


def bench_failure_recall():
    """Figure 6: U-NoCI fails the recall target up to half the time."""
    ds = make_beta(N, 0.01, 1.0, seed=1)
    rows = []
    for method in ("noci", "is"):
        r = run_trials(ds, "recall", method, 0.9, BUDGET, TRIALS)
        rows.append((method, r))
        emit(f"fig6_recall_{method}", r,
             f"fail={r['failure_rate']:.2f};min={r['achieved_min']:.2f}")
    return rows


def bench_precision_target():
    """Figure 7: achieved recall at precision targets, per method."""
    rows = []
    for alpha, beta, tag in ((0.01, 1.0, "beta1"), (0.01, 2.0, "beta2")):
        ds = make_beta(N, alpha, beta, seed=2)
        for gamma in (0.75, 0.9, 0.95):
            for method, two_stage, label in (
                    ("uniform", False, "U-CI"),
                    ("is", False, "IS-onestage"),
                    ("is", True, "IS-twostage")):
                r = run_trials(ds, "precision", method, gamma, BUDGET, 8,
                               two_stage=two_stage)
                rows.append((tag, gamma, label, r))
                emit(f"fig7_{tag}_g{gamma}_{label}", r,
                     f"recall={r['quality_p50']:.3f};"
                     f"fail={r['failure_rate']:.2f}")
    return rows


def bench_recall_target():
    """Figure 8: achieved precision at recall targets; sqrt vs prop vs U."""
    rows = []
    for alpha, beta, tag in ((0.01, 1.0, "beta1"), (0.01, 2.0, "beta2")):
        ds = make_beta(N, alpha, beta, seed=3)
        for gamma in (0.5, 0.75, 0.9):
            for method, scheme, label in (
                    ("uniform", "sqrt", "U-CI"),
                    ("is", "prop", "IS-prop"),
                    ("is", "sqrt", "IS-sqrt")):
                r = run_trials(ds, "recall", method, gamma, BUDGET, 8,
                               weight_scheme=scheme)
                rows.append((tag, gamma, label, r))
                emit(f"fig8_{tag}_g{gamma}_{label}", r,
                     f"precision={r['quality_p50']:.3f};"
                     f"fail={r['failure_rate']:.2f}")
    return rows


def bench_noise():
    """Figure 9: proxy noise sweep (25..100% of the score std)."""
    base = make_beta(N, 0.01, 2.0, seed=4)
    sigma0 = float(base.scores.std())
    rows = []
    for frac in (0.25, 0.5, 0.75, 1.0):
        ds = make_beta(N, 0.01, 2.0, seed=4, noise_std=frac * sigma0)
        for target, gamma in (("precision", 0.95), ("recall", 0.9)):
            for method in ("uniform", "is"):
                r = run_trials(ds, target, method, gamma, BUDGET, 6)
                rows.append((frac, target, method, r))
                emit(f"fig9_noise{frac}_{target}_{method}", r,
                     f"quality={r['quality_p50']:.3f}")
    return rows


def bench_imbalance():
    """Figure 10: true-positive-rate sweep via the Beta beta parameter."""
    rows = []
    for beta in (0.125, 0.25, 0.5, 1.0, 2.0):
        ds = make_beta(N, 0.01, beta, seed=5)
        for target, gamma in (("precision", 0.9), ("recall", 0.9)):
            for method in ("uniform", "is"):
                r = run_trials(ds, target, method, gamma, BUDGET, 6)
                rows.append((beta, ds.tpr, target, method, r))
                emit(f"fig10_beta{beta}_{target}_{method}", r,
                     f"tpr={ds.tpr:.4f};quality={r['quality_p50']:.3f}")
    return rows


def bench_drift():
    """Table 4: fixed-threshold-from-train-data fails under drift; SUPG,
    sampling from the shifted data, holds the target."""
    import jax
    from repro.core import SUPGQuery, array_oracle, precision_of, \
        recall_of, run_query
    from repro.core.thresholds import tau_unoci_p, tau_unoci_r

    train = make_beta(N, 0.01, 1.0, seed=6)
    shifted = make_beta(N, 0.01, 2.0, seed=7)
    rows = []
    for target, gamma in (("precision", 0.95), ("recall", 0.95)):
        # naive: empirical threshold fit on the FULL training data
        fit = tau_unoci_p if target == "precision" else tau_unoci_r
        tau = float(fit(train.scores, train.labels, gamma).tau)
        sel = np.nonzero(shifted.scores >= tau)[0]
        metric = precision_of if target == "precision" else recall_of
        naive = metric(sel, shifted.truth_mask())

        # SUPG on the shifted data with a fresh budget
        vals = []
        for t in range(10):
            q = SUPGQuery(target=target, gamma=gamma, delta=0.05,
                          budget=BUDGET, method="is")
            res = run_query(jax.random.PRNGKey(100 + t), shifted.scores,
                            array_oracle(shifted.labels), q)
            vals.append(metric(res.selected, shifted.truth_mask()))
        supg = float(np.mean(vals))
        rows.append((target, naive, supg))
        emit(f"table4_{target}", {"us_per_call": 0},
             f"naive={naive:.3f};supg={supg:.3f}")
    return rows


def bench_joint():
    """Figure 12: joint-target queries — oracle usage vs target level."""
    import jax
    from repro.core import precision_of, recall_of, run_joint_query, \
        array_oracle

    ds = make_beta(200_000, 0.01, 1.0, seed=8)
    rows = []
    for gamma in (0.5, 0.7, 0.9):
        for method in ("uniform", "is"):
            calls, precs, recs = [], [], []
            for t in range(4):
                res = run_joint_query(
                    jax.random.PRNGKey(t), ds.scores,
                    array_oracle(ds.labels), gamma_recall=gamma,
                    gamma_precision=gamma, stage_budget=5000, method=method)
                calls.append(res.oracle_calls)
                precs.append(precision_of(res.selected, ds.truth_mask()))
                recs.append(recall_of(res.selected, ds.truth_mask()))
            rows.append((gamma, method, np.mean(calls)))
            emit(f"fig12_joint_g{gamma}_{method}", {"us_per_call": 0},
                 f"oracle_calls={np.mean(calls):.0f};"
                 f"recall={np.mean(recs):.3f};precision={np.mean(precs):.3f}")
    return rows


ALL = [bench_failure_precision, bench_failure_recall,
       bench_precision_target, bench_recall_target, bench_noise,
       bench_imbalance, bench_drift, bench_joint]
